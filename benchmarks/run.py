"""Benchmark harness entry point — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,table2]

Prints ``name,us_per_call,derived`` CSV.
"""

from __future__ import annotations

import argparse
import sys
import time


BENCHES = [
    ("fig3_multitask", "benchmarks.bench_multitask"),
    ("fig4_pd_disagg", "benchmarks.bench_pd_disagg"),
    ("fig5_priority_mapping", "benchmarks.bench_priority_mapping"),
    ("table2_fast_scaling", "benchmarks.bench_fast_scaling"),
    ("fig6_dynamic_slo", "benchmarks.bench_dynamic_slo"),
    ("fig7_single_task", "benchmarks.bench_single_task"),
    ("fig8_intervals", "benchmarks.bench_intervals"),
    ("appA_latency_model", "benchmarks.bench_latency_model"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("chunked_prefill", "benchmarks.bench_chunked_prefill"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sample counts (slow)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark name filter")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    print("name,us_per_call,derived")
    failures = 0
    for name, module in BENCHES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']},{derived}",
                      flush=True)
            print(f"# {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},0,ERROR {type(e).__name__}: {e}", flush=True)
            import traceback
            traceback.print_exc(file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
