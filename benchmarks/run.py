"""Benchmark harness entry point — one benchmark per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full] [--only fig3,table2]
        [--json [PATH]]

Prints ``name,us_per_call,derived`` CSV.  ``--json`` additionally
collects the machine-readable payloads some benches attach to their
rows (currently ``decode_block``: tokens/s, dispatches per token,
block-size histogram) into a JSON file — ``BENCH_decode.json`` by
default — which CI uploads as the perf-trajectory artifact.
"""

from __future__ import annotations

import argparse
import json
import sys
import time


BENCHES = [
    ("fig3_multitask", "benchmarks.bench_multitask"),
    ("fig4_pd_disagg", "benchmarks.bench_pd_disagg"),
    ("fig5_priority_mapping", "benchmarks.bench_priority_mapping"),
    ("table2_fast_scaling", "benchmarks.bench_fast_scaling"),
    ("fig6_dynamic_slo", "benchmarks.bench_dynamic_slo"),
    ("fig7_single_task", "benchmarks.bench_single_task"),
    ("fig8_intervals", "benchmarks.bench_intervals"),
    ("appA_latency_model", "benchmarks.bench_latency_model"),
    ("kernels", "benchmarks.bench_kernels"),
    ("roofline", "benchmarks.bench_roofline"),
    ("chunked_prefill", "benchmarks.bench_chunked_prefill"),
    ("decode_block", "benchmarks.bench_decode_block"),
    ("spec_decode", "benchmarks.bench_spec_decode"),
    ("online_streaming", "benchmarks.bench_online_streaming"),
    ("prefix_cache", "benchmarks.bench_prefix_cache"),
    ("live_migration", "benchmarks.bench_live_migration"),
    ("fault_recovery", "benchmarks.bench_fault_recovery"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sample counts (slow)")
    ap.add_argument("--only", default="",
                    help="comma-separated benchmark name filter")
    ap.add_argument("--json", nargs="?", const="BENCH_decode.json",
                    default=None, metavar="PATH",
                    help="write machine-readable rows (benches that "
                         "attach them) to PATH [BENCH_decode.json]")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    print("name,us_per_call,derived")
    failures = 0
    json_rows: list[dict] = []
    for name, module in BENCHES:
        if only and not any(o in name for o in only):
            continue
        t0 = time.time()
        try:
            mod = __import__(module, fromlist=["run"])
            rows = mod.run(quick=not args.full)
            for r in rows:
                derived = str(r["derived"]).replace(",", ";")
                print(f"{r['name']},{r['us_per_call']},{derived}",
                      flush=True)
                if "json" in r:
                    json_rows.append(r["json"])
            print(f"# {name} done in {time.time()-t0:.1f}s",
                  file=sys.stderr, flush=True)
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{name},0,ERROR {type(e).__name__}: {e}", flush=True)
            import traceback
            traceback.print_exc(file=sys.stderr)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(json_rows, f, indent=1)
        print(f"# wrote {len(json_rows)} rows to {args.json}",
              file=sys.stderr, flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
