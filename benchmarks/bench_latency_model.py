"""Appendix A — latency-predictor accuracy.

Fits Eq. 1/2 coefficients from the profiling sweep (batch sizes x input
lengths, 3% noise) against the analytic ground truth for each serving
model, and reports held-out relative error.  Also fits from *measured*
real-engine step times on a reduced model.
"""

from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.latency_model import AnalyticLatencyModel, FittedLatencyModel
from repro.models import build_model
from repro.core.request import Request
from repro.serving.engine import EngineConfig, InferenceEngine

from benchmarks.common import row


def run(quick: bool = True) -> list[dict]:
    rows: list[dict] = []
    rng = np.random.default_rng(0)
    for model in ("qwen7b", "qwen32b", "llama70b"):
        truth = AnalyticLatencyModel(get_config(model))
        t0 = time.perf_counter()
        fitted = FittedLatencyModel.from_profile(truth, rng)
        us = (time.perf_counter() - t0) * 1e6
        errs_p, errs_d = [], []
        for lens in ([32], [640] * 4, [120] * 16, [1024, 64, 300],
                     [2000] * 48):
            tp = truth.prefill_time(lens)
            errs_p.append(abs(fitted.prefill_time(lens) - tp) / tp)
            td = truth.decode_step_time(lens)
            errs_d.append(abs(fitted.decode_step_time(lens) - td) / td)
        rows.append(row(
            f"appA/fit/{model}", us,
            f"prefill_relerr={np.mean(errs_p)*100:.1f}% "
            f"decode_relerr={np.mean(errs_d)*100:.1f}%",
        ))

    # fit from real measured engine steps (reduced model on CPU)
    cfg = get_smoke_config("qwen7b")
    m = build_model(cfg)
    params = m.init(jax.random.key(0))
    eng = InferenceEngine(m, params, EngineConfig(n_slots=4, max_len=48,
                                                  prefill_batch=2))
    for i in range(10):
        eng.submit(Request.from_prompt(
            i,
            rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(4, 24))).astype(np.int32),
            max_new=8))
    eng.run_until_done()
    ok = eng.fit_profiler()
    c = eng.profiler.coeffs
    rows.append(row(
        "appA/fit-from-real-engine", eng.clock * 1e6 / 10,
        f"fitted={ok} a={c.a:.4f} b={c.b:.2e} a'={c.a_d:.4f} "
        f"b'={c.b_d:.2e}",
    ))
    return rows
