"""Table 2 — Fast Scaling: weight-provisioning latency by strategy.

Three views:
1. analytic (paper-scale): D2D / CPU-offload / disk times for Qwen7B,
   Qwen32B (TP=2), Llama70B (TP=8) from the TLManager cost model;
2. measured (container-scale): real numpy weight movement for a reduced
   model — disk round-trip vs in-memory (host) copy vs jax.device_put
   ("D2D" transport on this host);
3. measured ENGINE variant: cold-start-to-first-token per strategy on
   a real scaled-out replica — WeightManager provisions the new
   replica's own params tree (d2d pull from a live donor / host
   offload / checkpoint load), then the engine runs the same prompt to
   its first token.  Token identity vs the seed replica is checked.
   Rows carry a machine-readable ``json`` payload that
   ``benchmarks/run.py --json`` collects into ``BENCH_scaling.json``
   (uploaded as a CI artifact alongside ``BENCH_decode.json``).
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.latency_model import ASCEND_910
from repro.core.tlmanager import TLManager
from repro.models import build_model

from benchmarks.common import row

# paper Table 2 (seconds): fast / cpu / disk
PAPER_T2 = {
    "qwen7b": (0.89, 2.73, 4.14),
    "qwen32b": (2.05, 19.41, 28.84),
    "llama70b": (1.16, 11.50, 22.58),
}


def run(quick: bool = True) -> list[dict]:
    rows: list[dict] = []
    tl = TLManager(hw=ASCEND_910)
    results = {}
    for model, tp in (("qwen7b", 1), ("qwen32b", 2), ("llama70b", 8)):
        cfg = get_config(model)
        times = {
            s: tl.weight_load_time(cfg, s, tp=tp)
            for s in ("d2d", "cpu", "disk")
        }
        results[model] = times
        pf, pc, pd = PAPER_T2[model]
        rows.append(row(
            f"table2/analytic/{model}", 0.0,
            f"d2d={times['d2d']:.2f}s (paper {pf}) "
            f"cpu={times['cpu']:.2f}s (paper {pc}) "
            f"disk={times['disk']:.2f}s (paper {pd}) "
            f"speedup_disk/d2d={times['disk']/times['d2d']:.2f}x",
        ))
    worst = max(v["disk"] / v["d2d"] for v in results.values())
    worst_cpu = max(v["cpu"] / v["d2d"] for v in results.values())
    rows.append(row(
        "table2/summary", 0.0,
        f"max_cold_start_speedup disk/d2d={worst:.2f}x "
        f"cpu/d2d={worst_cpu:.2f}x (paper: 19.39x / 9.88x)",
    ))

    # measured small-scale transfer (real arrays)
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    flat = {str(i): np.asarray(x)
            for i, x in enumerate(jax.tree.leaves(params))}
    nbytes = sum(a.nbytes for a in flat.values())

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.npz")
        np.savez(path, **flat)
        t0 = time.perf_counter()
        with np.load(path) as z:
            loaded = {k: z[k] for k in z.files}
            _ = [jax.device_put(v) for v in loaded.values()]
        t_disk = time.perf_counter() - t0
    t0 = time.perf_counter()
    host_copies = {k: v.copy() for k, v in flat.items()}
    _ = [jax.device_put(v) for v in host_copies.values()]
    t_cpu = time.perf_counter() - t0
    dev = [jax.device_put(v) for v in flat.values()]
    jax.block_until_ready(dev)
    t0 = time.perf_counter()
    d2d = [jax.device_put(v, jax.devices()[0]) for v in dev]
    jax.block_until_ready(d2d)
    t_d2d = time.perf_counter() - t0
    rows.append(row(
        "table2/measured-small", t_d2d * 1e6,
        f"bytes={nbytes/1e6:.1f}MB disk={t_disk*1e3:.1f}ms "
        f"host={t_cpu*1e3:.1f}ms d2d={t_d2d*1e3:.1f}ms "
        f"ordering={'ok' if t_d2d <= t_disk else 'inverted'}",
    ))
    rows.extend(_engine_cold_start(quick))
    return rows


def _engine_cold_start(quick: bool) -> list[dict]:
    """Measured engine-plane scale-out: provision a NEW replica's own
    weights through each Table-2 transport, then time to first token."""
    from repro.core.request import Request
    from repro.core.tlmanager import TLManager
    from repro.serving.engine import EngineConfig, InferenceEngine
    from repro.serving.weights import STRATEGIES, WeightManager

    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    tl = TLManager()
    seed_params = model.init(jax.random.key(0))
    wm = WeightManager(seed_params, tl=tl)
    ecfg = EngineConfig.smoke()
    fn_cache: dict = {}
    prompt = (np.arange(1, 13, dtype=np.int32) * 7) % cfg.vocab_size

    # seed replica: owns the seed tree, warms the shared jit cache so
    # XLA compile time never lands inside a measured cold start
    seed = InferenceEngine(model, seed_params, ecfg, fn_cache=fn_cache)
    wm.adopt(0, seed_params)
    r0 = Request.from_prompt(0, prompt, max_new=6)
    seed.submit(r0)
    seed.run_until_done()
    seed.warm_decode_blocks()
    ref_tokens = list(r0.generated)

    results: dict[str, dict] = {}
    n_trials = 2 if quick else 4
    wid = 1
    for strategy in STRATEGIES:
        best = None
        for _ in range(n_trials):
            params, t_prov = wm.provision(
                wid, strategy, donor=0 if strategy == "d2d" else None
            )
            eng = InferenceEngine(model, params, ecfg,
                                  fn_cache=fn_cache)
            r = Request.from_prompt(wid, prompt, max_new=6)
            eng.submit(r)
            while r.first_token_time is None:
                eng.step()
            ttft = float(r.first_token_time)  # measured step wall time
            eng.run_until_done()
            trial = {
                "provision_s": t_prov,
                "ttft_s": ttft,
                "cold_start_s": t_prov + ttft,
                "token_identical": list(r.generated) == ref_tokens,
            }
            wm.release(wid)
            wid += 1
            if best is None or trial["cold_start_s"] < best["cold_start_s"]:
                best = trial
        results[strategy] = best

    # the measured transfers feed the TLManager's observed model —
    # these are the costs the Scaler's next tick would decide from
    predicted = {
        s: tl.weight_load_time(cfg, s, nbytes=wm.nbytes)
        for s in STRATEGIES
    }
    d2d, disk = results["d2d"], results["disk"]
    ok = d2d["cold_start_s"] < disk["cold_start_s"]
    ident = all(v["token_identical"] for v in results.values())
    rows = [row(
        f"table2/engine-cold-start/{s}", v["cold_start_s"] * 1e6,
        f"provision={v['provision_s']*1e3:.1f}ms "
        f"ttft={v['ttft_s']*1e3:.1f}ms "
        f"cold_start={v['cold_start_s']*1e3:.1f}ms "
        f"tokens={'identical' if v['token_identical'] else 'DIVERGED'}",
    ) for s, v in results.items()]
    summary = row(
        "table2/engine-summary", 0.0,
        f"bytes={wm.nbytes/1e6:.1f}MB "
        f"disk/d2d={disk['cold_start_s']/d2d['cold_start_s']:.2f}x "
        f"ordering={'ok' if ok else 'inverted'} "
        f"token_identity={'ok' if ident else 'FAILED'}",
    )
    summary["json"] = {
        "bench": "fast_scaling_engine",
        "nbytes": wm.nbytes,
        "strategies": results,
        "predicted_from_measured_s": predicted,
        "measured_bw": {s: tl.measured_weight_bw(s) for s in STRATEGIES},
        "weight_bytes_ici": tl.weight_bytes_ici,
        "weight_bytes_host": tl.weight_bytes_host,
        "d2d_faster_than_disk": ok,
        "token_identical": ident,
        "cold_start_speedup_disk_over_d2d":
            disk["cold_start_s"] / d2d["cold_start_s"],
    }
    rows.append(summary)
    return rows
