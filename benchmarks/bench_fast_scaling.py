"""Table 2 — Fast Scaling: weight-provisioning latency by strategy.

Two views:
1. analytic (paper-scale): D2D / CPU-offload / disk times for Qwen7B,
   Qwen32B (TP=2), Llama70B (TP=8) from the TLManager cost model;
2. measured (container-scale): real numpy weight movement for a reduced
   model — disk round-trip vs in-memory (host) copy vs jax.device_put
   ("D2D" transport on this host).
"""

from __future__ import annotations

import os
import tempfile
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.latency_model import ASCEND_910
from repro.core.tlmanager import TLManager
from repro.models import build_model

from benchmarks.common import row

# paper Table 2 (seconds): fast / cpu / disk
PAPER_T2 = {
    "qwen7b": (0.89, 2.73, 4.14),
    "qwen32b": (2.05, 19.41, 28.84),
    "llama70b": (1.16, 11.50, 22.58),
}


def run(quick: bool = True) -> list[dict]:
    rows: list[dict] = []
    tl = TLManager(hw=ASCEND_910)
    results = {}
    for model, tp in (("qwen7b", 1), ("qwen32b", 2), ("llama70b", 8)):
        cfg = get_config(model)
        times = {
            s: tl.weight_load_time(cfg, s, tp=tp)
            for s in ("d2d", "cpu", "disk")
        }
        results[model] = times
        pf, pc, pd = PAPER_T2[model]
        rows.append(row(
            f"table2/analytic/{model}", 0.0,
            f"d2d={times['d2d']:.2f}s (paper {pf}) "
            f"cpu={times['cpu']:.2f}s (paper {pc}) "
            f"disk={times['disk']:.2f}s (paper {pd}) "
            f"speedup_disk/d2d={times['disk']/times['d2d']:.2f}x",
        ))
    worst = max(v["disk"] / v["d2d"] for v in results.values())
    worst_cpu = max(v["cpu"] / v["d2d"] for v in results.values())
    rows.append(row(
        "table2/summary", 0.0,
        f"max_cold_start_speedup disk/d2d={worst:.2f}x "
        f"cpu/d2d={worst_cpu:.2f}x (paper: 19.39x / 9.88x)",
    ))

    # measured small-scale transfer (real arrays)
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    flat = {str(i): np.asarray(x)
            for i, x in enumerate(jax.tree.leaves(params))}
    nbytes = sum(a.nbytes for a in flat.values())

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "w.npz")
        np.savez(path, **flat)
        t0 = time.perf_counter()
        with np.load(path) as z:
            loaded = {k: z[k] for k in z.files}
            _ = [jax.device_put(v) for v in loaded.values()]
        t_disk = time.perf_counter() - t0
    t0 = time.perf_counter()
    host_copies = {k: v.copy() for k, v in flat.items()}
    _ = [jax.device_put(v) for v in host_copies.values()]
    t_cpu = time.perf_counter() - t0
    dev = [jax.device_put(v) for v in flat.values()]
    jax.block_until_ready(dev)
    t0 = time.perf_counter()
    d2d = [jax.device_put(v, jax.devices()[0]) for v in dev]
    jax.block_until_ready(d2d)
    t_d2d = time.perf_counter() - t0
    rows.append(row(
        "table2/measured-small", t_d2d * 1e6,
        f"bytes={nbytes/1e6:.1f}MB disk={t_disk*1e3:.1f}ms "
        f"host={t_cpu*1e3:.1f}ms d2d={t_d2d*1e3:.1f}ms "
        f"ordering={'ok' if t_d2d <= t_disk else 'inverted'}",
    ))
    return rows
