"""Live decode-to-decode migration — SLO rescue and migrate-then-flip.

Two controlled scenarios, each run with live migration off (baseline)
and on:

A. **Bursty ramp rescue** — one worker, a burst of tight-TPOT
   interactive streams, scaler ramping replicas in.  Without live
   migration the resident batch stays pinned to the overloaded seed
   worker and blows its TPOT budget; with it the MigrationCoordinator
   sheds loose-SLO victims onto the fresh replicas mid-stream.
   Metric: SLO attainment (must be higher with migration on).

B. **Role-flip commit latency** — P/D cluster whose decode workers
   hold long lingering streams when a prompt-heavy burst arrives and
   a decode->prefill flip is requested.  Drain-and-flip must wait for
   the streams to end naturally; migrate-then-flip evacuates the
   residents to the peer decode worker and commits immediately.
   Metric: seconds from flip request to role-flip commit (must be
   lower with migration on), plus burst TTFT attainment downstream.

The summary row attaches a machine-readable payload collected by
``benchmarks.run --json`` into ``BENCH_migration.json`` (CI artifact).

    PYTHONPATH=src python -m benchmarks.bench_live_migration
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.core.request import Request, RequestState
from repro.core.scaler import ScaleAction, ScalerConfig
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.session import ServingSession

from benchmarks.common import row


# -- scenario A: bursty ramp, rescue migrations ------------------------------

def _ramp_workload(n: int, seed: int) -> list[Request]:
    """Tight-TPOT interactive streams arriving inside ~1.2 s."""
    rng = np.random.default_rng(seed)
    reqs = [
        Request(rid=i, task="interactive",
                arrival=float(rng.uniform(0.0, 1.2)),
                l_in=int(rng.integers(250, 450)), l_out=120,
                ttft_slo=8.0, tpot_slo=0.06)
        for i in range(n)
    ]
    return sorted(reqs, key=lambda r: r.arrival)


def _run_ramp(live: bool, n: int, seed: int = 1):
    reqs = _ramp_workload(n, seed)
    cfg = ClusterConfig(
        model=get_config("qwen7b"), n_workers=1, policy="rr",
        scaling=True,
        scaler=ScalerConfig(tau=0.25, max_workers=3,
                            weight_strategy="d2d"),
        live_migration=live, seed=seed,
    )
    t0 = time.perf_counter()
    res = Cluster(cfg).run(reqs)
    us = (time.perf_counter() - t0) * 1e6 / max(len(reqs), 1)
    return res, us


# -- scenario B: flip-commit latency under lingering streams -----------------

def _flip_workload(seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = [
        # phase 1: long loose-TPOT streams that linger on both decode
        # workers far past the flip request at t=2
        Request(rid=i, task="stream",
                arrival=float(rng.uniform(0.0, 0.3)),
                l_in=100, l_out=400, ttft_slo=4.0, tpot_slo=0.5)
        for i in range(6)
    ] + [
        # phase 2: prompt-heavy burst that wants the flipped prefill
        # capacity — arrives after the flip request
        Request(rid=100 + i, task="burst",
                arrival=3.0 + float(rng.uniform(0.0, 0.5)),
                l_in=1500, l_out=2, ttft_slo=2.5, tpot_slo=1.0)
        for i in range(30)
    ]
    return sorted(reqs, key=lambda r: r.arrival)


def _run_flip(live: bool, seed: int = 2, t_flip: float = 2.0,
              max_events: int = 500_000):
    """Drive the flip decision directly: at ``t_flip`` request that one
    decode worker become a prefill worker.  Baseline semantics are
    drain-and-flip (commit the moment the worker drains naturally);
    live semantics are migrate-then-flip via ``_begin_evacuation``."""
    reqs = _flip_workload(seed)
    c = Cluster(ClusterConfig(
        model=get_config("qwen7b"), policy="hyperflexis", mode="pd",
        n_prefill=1, n_decode=2, live_migration=live, seed=seed,
    ))
    s = ServingSession(c, admission="none")
    for r in reqs:
        s.submit_request(r)
    target = 2  # a decode worker (wid 0 = prefill, 1/2 = decode)
    requested = committed = None
    for _ in range(max_events):
        if c.process_next() is None:
            break
        w = c._by_wid[target]
        if requested is None and c.now >= t_flip:
            requested = c.now
            if live:
                c._begin_evacuation(
                    w, ScaleAction("role", "prefill", 0.08,
                                   worker_id=target), c.now)
        if requested is not None and committed is None:
            if live:
                flips = [t for t, wid, ev in c.timeline
                         if wid == target and ev.startswith("role:")]
                if flips:
                    committed = flips[0]
            elif w.role == "decode" and w.is_drained():
                c._apply_role_flip(w, "prefill", c.now)
                committed = c.now
        if (all(r.state == RequestState.FINISHED for r in reqs)
                and not c._evac):
            break
    res = s.close(requests=reqs)
    burst = [r for r in reqs if r.task == "burst"]
    burst_att = sum(1 for r in burst if r.ttft_ok()) / len(burst)
    flip_lat = (committed - requested) if committed is not None \
        else float("inf")
    return res, flip_lat, burst_att


# -- harness entry -----------------------------------------------------------

def run(quick: bool = True) -> list[dict]:
    n_ramp = 40 if quick else 120
    rows: list[dict] = []

    ramp = {}
    for live in (False, True):
        res, us = _run_ramp(live, n_ramp)
        m = res.metrics
        ramp[live] = (res, m)
        rows.append(row(
            f"migration/ramp/{'live' if live else 'baseline'}", us,
            f"att={m.attainment:.3f} tpot_att={m.tpot_attainment:.3f} "
            f"moves={res.n_live_migrations} rescues={res.n_rescues} "
            f"scaled_out={res.n_scale_out} mk={m.makespan:.1f}s",
        ))

    flip = {}
    for live in (False, True):
        t0 = time.perf_counter()
        res, flip_lat, burst_att = _run_flip(live)
        us = (time.perf_counter() - t0) * 1e6 / max(res.metrics.n_total, 1)
        flip[live] = (res, flip_lat, burst_att)
        rows.append(row(
            f"migration/flip/{'evacuate' if live else 'drain'}", us,
            f"flip_latency={flip_lat:.2f}s burst_ttft_att={burst_att:.3f} "
            f"att={res.metrics.attainment:.3f} "
            f"moves={res.n_live_migrations} evac={res.n_evacuations}",
        ))

    att_off = ramp[False][1].attainment
    att_on = ramp[True][1].attainment
    lat_drain = flip[False][1]
    lat_evac = flip[True][1]
    payload = {
        "bench": "live_migration",
        "ramp_attainment_baseline": round(att_off, 4),
        "ramp_attainment_live": round(att_on, 4),
        "ramp_tpot_attainment_baseline":
            round(ramp[False][1].tpot_attainment, 4),
        "ramp_tpot_attainment_live":
            round(ramp[True][1].tpot_attainment, 4),
        "ramp_live_migrations": ramp[True][0].n_live_migrations,
        "ramp_rescues": ramp[True][0].n_rescues,
        "flip_latency_drain_s": round(lat_drain, 4),
        "flip_latency_evacuate_s": round(lat_evac, 4),
        "flip_burst_ttft_att_drain": round(flip[False][2], 4),
        "flip_burst_ttft_att_evacuate": round(flip[True][2], 4),
        "flip_evacuation_moves": flip[True][0].n_live_migrations,
    }
    summary = row(
        "migration/summary", 0.0,
        f"attainment {att_off:.3f}->{att_on:.3f} "
        f"flip_latency {lat_drain:.2f}s->{lat_evac:.2f}s "
        f"(live migration on)",
    )
    summary["json"] = payload
    rows.append(summary)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(quick=not args.full):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
