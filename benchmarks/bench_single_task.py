"""Fig. 7 — single-task setting (WikiSQL) vs ALADDIN and SA.

TTFT/TPOT SLOs fixed at 0.7 s / 0.5 s; QPS sweep around the knee.
HyperFlexis must remain at least competitive in the single-task case.
"""

from __future__ import annotations

import time

from repro.configs import get_config
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import single_task_workload

from benchmarks.common import row


def run(quick: bool = True) -> list[dict]:
    n = 60 if quick else 300
    rows: list[dict] = []
    summary = {}
    for model in (("qwen7b",) if quick else ("qwen7b", "qwen32b")):
        for qps in (16, 28, 40):
            res = {}
            for policy in ("hyperflexis", "aladdin", "sa"):
                reqs = single_task_workload("wikisql", qps=qps, n=n,
                                            ttft=0.7, tpot=0.5, seed=0)
                cfg = ClusterConfig(model=get_config(model),
                                    n_workers=2, policy=policy, seed=0)
                t0 = time.perf_counter()
                r = Cluster(cfg).run(reqs)
                us = (time.perf_counter() - t0) * 1e6 / n
                m = r.metrics
                res[policy] = m
                rows.append(row(
                    f"fig7/{model}/qps{qps}/{policy}", us,
                    f"att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s "
                    f"p99={m.p99_e2e:.2f}s",
                ))
            summary[(model, qps)] = res
    worst_margin = min(
        (res["hyperflexis"].attainment
         - max(res["aladdin"].attainment, res["sa"].attainment))
        for res in summary.values()
    )
    rows.append(row(
        "fig7/summary", 0.0,
        f"min_attainment_margin_vs_best_baseline={worst_margin:+.3f} "
        f"(paper: HFX at least competitive in single-task)",
    ))
    return rows
