"""Fig. 3 — multi-task performance on 2-task and 4-task workloads.

HyperFlexis / HyperFlexis-Scaling vs RR and SCORPIO: SLO attainment,
E2E latency, cost.  Two workers, scaling up to four.
"""

from __future__ import annotations

from repro.core.request import FOUR_TASK_SET, TWO_TASK_SET
from repro.core.scaler import ScalerConfig

from benchmarks.common import row, run_sim


def run(quick: bool = True) -> list[dict]:
    n = 50 if quick else 300
    seeds = (0, 1) if quick else (0, 1, 2)
    rows: list[dict] = []
    best_gain_rr = best_gain_sc = 0.0
    best_lat_red = 0.0
    for tasks, tag in ((TWO_TASK_SET, "2task"), (FOUR_TASK_SET, "4task")):
        qps_list = (112, 144, 176) if tag == "2task" else (80, 112, 144)
        for qps in qps_list:
            res = {}
            for policy, label, kw in (
                ("hyperflexis", "hfx", {}),
                ("rr", "rr", {}),
                ("scorpio", "scorpio", {}),
                ("hyperflexis", "hfx-scaling",
                 dict(scaling=True,
                      scaler=ScalerConfig(max_workers=4))),
            ):
                att = e2e = cost = us = 0.0
                for s in seeds:
                    r, u = run_sim("qwen7b", policy, qps, tasks, n,
                                   seed=s, n_workers=2, **kw)
                    att += r.metrics.attainment
                    e2e += r.metrics.mean_e2e
                    cost += r.metrics.cost_units
                    us += u
                k = len(seeds)
                res[label] = (att / k, e2e / k, cost / k)
                rows.append(row(
                    f"fig3/{tag}/qps{qps}/{label}", us / k,
                    f"att={att/k:.3f} e2e={e2e/k:.2f}s "
                    f"cost={cost/k:.0f}",
                ))
            if res["rr"][0] > 0:
                best_gain_rr = max(best_gain_rr,
                                   res["hfx-scaling"][0] / res["rr"][0])
            if res["scorpio"][0] > 0:
                best_gain_sc = max(
                    best_gain_sc,
                    res["hfx-scaling"][0] / res["scorpio"][0],
                )
            if res["scorpio"][1] > 0:
                best_lat_red = max(
                    best_lat_red,
                    1 - res["hfx-scaling"][1] / res["scorpio"][1],
                )
    rows.append(row(
        "fig3/summary", 0.0,
        f"attainment_gain_vs_rr={best_gain_rr:.2f}x "
        f"vs_scorpio={best_gain_sc:.2f}x "
        f"latency_reduction_vs_scorpio={best_lat_red*100:.1f}% "
        f"(paper: 4.44x / 2.59x / 65.82%)",
    ))
    return rows
