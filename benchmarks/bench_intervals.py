"""Fig. 8 — sensitivity to monitor and scaling intervals.

Monitor interval in {50 ms, 1 s, 5 s}; scaling interval in
{0.5 s, 1 s, 2 s}; Qwen32B-style 4-task workload.  Expectation:
performance largely insensitive within the tested range.
"""

from __future__ import annotations

from repro.core.request import FOUR_TASK_SET
from repro.core.scaler import ScalerConfig

from benchmarks.common import row, run_sim


def run(quick: bool = True) -> list[dict]:
    n = 40 if quick else 300
    model = "qwen7b" if quick else "qwen32b"
    qps = 80 if quick else 32
    rows: list[dict] = []
    atts = []
    for mi in (0.05, 1.0, 5.0):
        r, us = run_sim(model, "hyperflexis", qps, FOUR_TASK_SET, n,
                        seed=0, n_workers=2, monitor_interval=mi)
        m = r.metrics
        atts.append(m.attainment)
        rows.append(row(
            f"fig8/monitor/{mi}s", us,
            f"att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s",
        ))
    for si in (0.5, 1.0, 2.0):
        r, us = run_sim(model, "hyperflexis", qps, FOUR_TASK_SET, n,
                        seed=0, n_workers=2, scaling=True,
                        scaler=ScalerConfig(tau=si, max_workers=4))
        m = r.metrics
        atts.append(m.attainment)
        rows.append(row(
            f"fig8/scaler/{si}s", us,
            f"att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s "
            f"out={r.n_scale_out}",
        ))
    spread = max(atts) - min(atts)
    rows.append(row(
        "fig8/summary", 0.0,
        f"attainment_spread_across_intervals={spread:.3f} "
        f"(paper: largely insensitive)",
    ))
    return rows
