"""Fig. 5 — HyperFlexis-PM: priority-based dynamic SLO mapping.

Requests arrive with priorities only; Algorithm 2 derives TTFT/TPOT from
sliding windows within ±25% bands around the Table-1 medians.
"""

from __future__ import annotations

from repro.core.request import FOUR_TASK_SET, TASKS, TWO_TASK_SET
from repro.core.slo_mapper import PrioritySLOMapper, bands_from_tasks

from benchmarks.common import row, run_sim


def run(quick: bool = True) -> list[dict]:
    n = 50 if quick else 300
    rows: list[dict] = []
    best = 0.0
    for tasks, tag in ((TWO_TASK_SET, "2task"), (FOUR_TASK_SET, "4task")):
        for qps in (96, 144):
            res = {}
            for policy, label in (("hyperflexis", "hfx-pm"),
                                  ("rr", "rr")):
                mapper = (PrioritySLOMapper(
                    bands_from_tasks([TASKS[t] for t in tasks]))
                    if policy == "hyperflexis" else None)
                r, us = run_sim(
                    "qwen7b", policy, qps, tasks, n, seed=0,
                    n_workers=2, slo_mapper=mapper, use_priority=True,
                )
                m = r.metrics
                res[label] = m
                rows.append(row(
                    f"fig5/{tag}/qps{qps}/{label}", us,
                    f"att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s",
                ))
            if res["rr"].attainment > 0:
                best = max(best,
                           res["hfx-pm"].attainment
                           / res["rr"].attainment)
    rows.append(row(
        "fig5/summary", 0.0,
        f"pm_attainment_gain_vs_rr={best:.2f}x (paper: up to 7.02x)",
    ))
    return rows
