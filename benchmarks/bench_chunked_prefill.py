"""Chunked prefill + paged KV — TPOT/TTFT under mixed long-prompt +
decode load, in both execution planes.

Simulator sweep: a decode-heavy chat stream with long document prompts
landing mid-stream, monolithic vs chunked prefill at several chunk
sizes.  Chunking bounds the head-of-line prefill stall each decode
iteration absorbs (the slack Eq. 5 budgets), trading a little long-job
TTFT for short-job TPOT.

Real-engine micro-bench: the same contrast on the actual JAX engine
(reduced config, CPU) — paged/chunked plane vs monolithic slots.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.request import Request
from repro.core.token_budget import chunk_schedule
from repro.serving.cluster import Cluster, ClusterConfig

from benchmarks.common import row


def _mixed_requests(n_chat: int, n_doc: int, l_doc: int):
    reqs = [Request(rid=i, task="chat", arrival=i * 0.05, l_in=64,
                    l_out=60, ttft_slo=2.0, tpot_slo=0.2)
            for i in range(n_chat)]
    reqs += [Request(rid=10_000 + i, task="doc", arrival=0.2 + i * 0.25,
                     l_in=l_doc, l_out=20, ttft_slo=30.0, tpot_slo=1.0)
             for i in range(n_doc)]
    return sorted(reqs, key=lambda r: r.arrival)


def _sim_rows(quick: bool) -> list[dict]:
    n_chat = 20 if quick else 120
    n_doc = 4 if quick else 16
    l_doc = 8000
    rows = []
    for chunk in (None, 256, 512, 2048):
        reqs = _mixed_requests(n_chat, n_doc, l_doc)
        cfg = ClusterConfig(model=get_config("qwen7b"), n_workers=1,
                            policy="hyperflexis", seed=3,
                            chunk_tokens=chunk)
        t0 = time.perf_counter()
        res = Cluster(cfg).run(reqs)
        us = (time.perf_counter() - t0) * 1e6 / len(reqs)
        chat = [r for r in res.requests if r.task == "chat"]
        doc = [r for r in res.requests if r.task == "doc"]
        max_tpot = max(r.tpot for r in chat)
        mean_ttft_doc = float(np.mean([r.ttft for r in doc]))
        n_chunks = sum(len(chunk_schedule(r.l_in, chunk)) for r in doc)
        rows.append(row(
            f"sim/chunk={chunk}", us,
            f"chat_max_tpot={max_tpot:.4f}s "
            f"doc_ttft={mean_ttft_doc:.2f}s "
            f"doc_prefill_steps={n_chunks} "
            f"att={res.metrics.attainment:.3f}",
        ))
    return rows


def _engine_rows(quick: bool) -> list[dict]:
    import jax

    from repro.models import build_model
    from repro.core.request import Request
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    n_short = 4 if quick else 8
    l_long = 96

    def requests():
        shorts = [Request.from_prompt(
            i, rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new=16) for i in range(n_short)]
        longs = [Request.from_prompt(
            100, rng.integers(0, cfg.vocab_size, size=l_long)
            .astype(np.int32), max_new=4)]
        return shorts + longs

    rows = []
    for label, kw in (
        ("monolithic", dict(paged=False)),
        ("paged/chunk=16", dict(paged=True, chunk_size=16, page_size=8)),
    ):
        reqs = requests()
        eng = InferenceEngine(model, params, EngineConfig(
            n_slots=4, max_len=160, prefill_batch=2, **kw))
        # warm the jits + profiler so Eq. 5 admission is live
        warm = Request.from_prompt(-1, np.arange(8, dtype=np.int32),
                                   max_new=4)
        eng.submit(warm)
        eng.run_until_done()
        eng.fit_profiler()
        t0 = time.perf_counter()
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        wall = time.perf_counter() - t0
        # max inter-token gap on short requests = the decode stall the
        # long prompt's prefill induces
        gaps = []
        for r in reqs:
            if r.rid < 100 and r.first_token_time and r.finish_time:
                gaps.append((r.finish_time - r.first_token_time)
                            / max(len(r.generated) - 1, 1))
        long_req = [r for r in reqs if r.rid == 100][0]
        long_ttft = long_req.first_token_time - long_req.arrival
        rows.append(row(
            f"engine/{label}", wall * 1e6 / len(reqs),
            f"short_mean_tpot={float(np.mean(gaps)):.4f}s "
            f"short_max_tpot={float(np.max(gaps)):.4f}s "
            f"long_ttft={long_ttft:.3f}s",
        ))
    return rows


def run(quick: bool = True) -> list[dict]:
    rows = _sim_rows(quick)
    rows += _engine_rows(quick)
    return rows
