"""SLO-customized speculative decoding — accepted tokens per dispatch.

Pure-decode micro-bench on the real engine (CPU smoke config):
drafter-friendly looping prompts decode with ``spec_decode`` on, split
across two SLO tiers whose TPOT targets are derived FROM the fitted
latency model — ``tight`` leaves ~2.5 verify lanes of slack, ``loose``
~100 — so the Eq. 5 controller picks visibly different speculation
depths per tier.  Reports accepted-tokens per propose-verify dispatch
(the speculation win: > 1.0 means the verify pass emitted more than
the one token a plain step would), decode tokens/s vs a plain
K-block engine on the same workload, greedy token-identity, and the
per-tier depth/acceptance split.

Rows carry a machine-readable ``json`` payload that
``benchmarks/run.py --json`` collects into ``BENCH_spec.json``.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row

# looping token patterns: once the greedy stream goes periodic the
# n-gram drafter's proposals verify near-perfectly — the regime
# speculation targets (agentic / templated decode).  Periods are LONG
# so the drafter can fill a deep controller budget; each tier runs the
# SAME patterns, isolating the SLO as the only depth driver.
_PATTERNS = (
    [3, 5, 7, 11, 13, 17] * 4,
    [2, 4, 6, 8, 10, 12, 14, 16] * 3,
)


def _requests(tiers, n_new):
    from repro.core.request import Request

    reqs = []
    i = 0
    for task, tpot in tiers:
        for pat in _PATTERNS:
            reqs.append(Request.from_prompt(
                i, np.array(pat, np.int32), max_new=n_new,
                task=task, tpot_slo=tpot))
            i += 1
    return reqs


def _drain_prefill(eng):
    for _ in range(10_000):
        if not eng.queue and not eng.prefilling:
            break
        eng.step()


def _measure(eng, reqs):
    for r in reqs:
        eng.submit(r)
    # timed region is pure decode (under queue pressure the engine
    # collapses to the plain path by design)
    _drain_prefill(eng)
    tok0, disp0 = eng.n_decode_tokens, eng.n_dispatches
    t0 = time.perf_counter()
    eng.run_until_done()
    wall = time.perf_counter() - t0
    assert all(r.finish_time is not None for r in reqs)
    return {
        "tokens": eng.n_decode_tokens - tok0,
        "dispatches": eng.n_dispatches - disp0,
        "wall_s": wall,
        "generated": [list(r.generated) for r in reqs],
    }


def run(quick: bool = True) -> list[dict]:
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model
    from repro.serving.engine import EngineConfig, InferenceEngine

    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    n_new = 24 if quick else 64
    max_spec = 6
    ecfg_kw = dict(n_slots=4, max_len=24 + n_new + 8, prefill_batch=4,
                   page_size=8, chunk_size=16)
    fn_cache: dict = {}

    # -- plain K-block baseline on the same workload (tier labels are
    # placeholders: SLOs don't steer the non-speculative path) --------------
    plain = InferenceEngine(model, params, EngineConfig(
        decode_block=4, **ecfg_kw), fn_cache=fn_cache)
    plain.warm_decode_blocks()
    base = _measure(plain, _requests([("tight", 1.0), ("loose", 1.0)],
                                     n_new))

    # -- speculative engine -------------------------------------------------
    eng = InferenceEngine(model, params, EngineConfig(
        spec_decode=True, max_spec_len=max_spec, **ecfg_kw),
        fn_cache=fn_cache)
    eng.warm_decode_blocks()

    # calibrate: throwaway streams with VARIED prompt lengths feed the
    # profiler, then the tier TPOTs derive from the FITTED
    # coefficients.  CPU smoke prefill is overhead-dominated, so floor
    # b: the controller divides TPOT slack by it and an exactly-zero
    # fit would erase the tier split this bench exists to show.
    from repro.core.request import Request
    for j, n_warm in enumerate((8, 20, 28)):
        warm = Request.from_prompt(
            -1 - j, np.array([1, 2] * (n_warm // 2), np.int32),
            max_new=8)
        eng.submit(warm)
        eng.run_until_done()
    prof = eng.profiler
    assert prof.fit(min_samples=2), "calibration failed to fit"
    prof.coeffs.b = max(prof.coeffs.b, 1e-6)
    e_d = prof.decode_step_time([32] * (2 * len(_PATTERNS)))
    tiers = [("tight", e_d + 2.5 * prof.b),
             ("loose", e_d + 100.0 * prof.b)]

    res = _measure(eng, _requests(tiers, n_new))

    identical = res["generated"] == base["generated"]
    sd = max(eng.n_spec_dispatches, 1)
    tok_per_spec = 1.0 + eng.n_spec_accepted / sd
    accept_rate = eng.n_spec_accepted / max(eng.n_spec_proposed, 1)
    tok_s = res["tokens"] / max(res["wall_s"], 1e-9)
    base_tok_s = base["tokens"] / max(base["wall_s"], 1e-9)

    payload = {
        "bench": "spec_decode",
        "tier": "all",
        "spec_dispatches": eng.n_spec_dispatches,
        "proposed": eng.n_spec_proposed,
        "accepted": eng.n_spec_accepted,
        "accept_rate": round(accept_rate, 3),
        "tokens_per_spec_dispatch": round(tok_per_spec, 3),
        "dispatches_per_token": round(
            res["dispatches"] / max(res["tokens"], 1), 4),
        "tokens_per_s": round(tok_s, 2),
        "plain_k4_tokens_per_s": round(base_tok_s, 2),
        "speedup_vs_plain_k4": round(tok_s / max(base_tok_s, 1e-9), 3),
        "identical_to_plain": identical,
    }
    rows = [{
        **row(
            "spec_decode/all",
            res["wall_s"] * 1e6 / max(res["tokens"], 1),
            f"tok_per_spec_dispatch={tok_per_spec:.2f} "
            f"accept_rate={accept_rate:.2f} tok_s={tok_s:.1f} "
            f"plain_k4_tok_s={base_tok_s:.1f} identical={identical}",
        ),
        "json": payload,
    }]

    # per-SLO-tier depth split: the controller gives the tight tier
    # shallower proposals than the loose one
    for tier, tpot in tiers:
        st = eng.spec_task_stats.get(
            tier, {"lanes": 0, "sum_want": 0, "sum_k": 0, "accepted": 0})
        mean_want = st["sum_want"] / max(st["lanes"], 1)
        mean_k = st["sum_k"] / max(st["lanes"], 1)
        t_rate = st["accepted"] / max(st["sum_k"], 1)
        rows.append({
            **row(
                f"spec_decode/tier={tier}",
                mean_k,
                f"tpot_slo={tpot:.4f}s planned_depth={mean_want:.2f} "
                f"drafted_depth={mean_k:.2f} proposed={st['sum_k']} "
                f"accepted={st['accepted']} accept_rate={t_rate:.2f}",
            ),
            "json": {
                "bench": "spec_decode",
                "tier": tier,
                "tpot_slo_s": round(tpot, 6),
                "planned_depth": round(mean_want, 3),
                "drafted_depth": round(mean_k, 3),
                "proposed": st["sum_k"],
                "accepted": st["accepted"],
                "accept_rate": round(t_rate, 3),
            },
        })
    return rows
