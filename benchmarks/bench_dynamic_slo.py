"""Fig. 6 — responsiveness to dynamic workloads.

Priority classes join every 20 s (P3 first at 15 QPS, then P2, P1, P0
up to 60 QPS).  With priority mapping, HyperFlexis tightens low-priority
SLOs when underloaded and relaxes them (up to the band max) under
contention; RR violates the high-priority TTFT in the 60-90 s window.
Derived: per-phase TTFT-SLO compliance of the highest-priority class.
"""

from __future__ import annotations

import time

from repro.configs import get_config
from repro.core.request import FOUR_TASK_SET, TASKS
from repro.core.slo_mapper import PrioritySLOMapper, bands_from_tasks
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import ramp_workload

from benchmarks.common import row


def _phase_compliance(requests, lo, hi, priority=0):
    sel = [r for r in requests
           if r.priority == priority and lo <= r.arrival < hi
           and r.first_token_time is not None]
    if not sel:
        return None
    return sum(1 for r in sel if r.ttft_ok()) / len(sel)


def run(quick: bool = True) -> list[dict]:
    rows: list[dict] = []
    out = {}
    for policy in ("hyperflexis", "rr"):
        mapper = None
        if policy == "hyperflexis":
            mapper = PrioritySLOMapper(
                bands_from_tasks([TASKS[t] for t in FOUR_TASK_SET])
            )
        join = 15.0 if quick else 20.0
        duration = 75.0 if quick else 100.0
        reqs = ramp_workload(
            FOUR_TASK_SET, qps_per_class=20.0, join_every=join,
            duration=duration, seed=0,
        )
        cfg = ClusterConfig(model=get_config("qwen7b"), n_workers=2,
                            policy=policy, seed=0, slo_mapper=mapper)
        t0 = time.perf_counter()
        res = Cluster(cfg).run(reqs)
        us = (time.perf_counter() - t0) * 1e6 / len(reqs)
        # the contention window: all four classes active
        c_low = _phase_compliance(res.requests, 0.0, join, priority=3)
        c_high = _phase_compliance(res.requests, 3 * join, duration,
                                   priority=0)
        att = res.metrics.attainment
        out[policy] = c_high
        rows.append(row(
            f"fig6/{policy}", us,
            f"att={att:.3f} "
            f"p3_early_ttft_ok={c_low if c_low is not None else -1:.2f} "
            f"p0_contended_ttft_ok="
            f"{c_high if c_high is not None else -1:.2f}",
        ))
    hfx = out.get("hyperflexis") or 0.0
    rr = out.get("rr") or 0.0
    rows.append(row(
        "fig6/summary", 0.0,
        f"contended_P0_ttft_compliance hfx={hfx:.2f} rr={rr:.2f} "
        f"(paper: HFX preserves P0 under contention, RR violates)",
    ))
    return rows
