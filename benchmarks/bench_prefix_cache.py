"""Prefix cache — prefill-FLOPs reduction and TTFT under Zipfian
shared-prefix load, cache on vs off.

Engine plane (the headline): the same shared-prefix workload runs
twice on real jitted compute — cold (no cache) and with the page-level
prefix cache — and we compare

- ``n_prefill_tokens``: prompt tokens that actually ran prefill
  compute.  The drop IS the FLOPs saving (attention prefill cost is
  superlinear in the chunk, so wall-time savings are at least as big).
- mean TTFT at equal attainment, and
- token identity: generation must be bit-identical either way — the
  cache returns the same KV the prompt would have produced.

Two workload shapes: ``chat`` (hot system prompts, Zipf-distributed)
and ``agent`` (sessions whose shared history grows per turn).

A sim-plane pair runs the same contrast through the discrete-event
mirror (SimPrefixIndex), so scheduler-level numbers are available
without JAX in the loop.
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import shared_prefix_workload

from benchmarks.common import row


def _workload(shape: str, quick: bool):
    n = 16 if quick else 48
    if shape == "chat":
        return shared_prefix_workload(
            task="gsm8k", n=n, qps=16.0, seed=7, n_groups=4,
            shape="chat", prefix_len=16, suffix_len=6, l_out=4,
        )
    return shared_prefix_workload(
        task="gsm8k", n=n, qps=16.0, seed=11, n_groups=3,
        shape="agent", prefix_len=8, suffix_len=6, turn_growth=8,
        max_turns=4, l_out=4,
    )


def _engine_cfg(prefix_cache: bool) -> ClusterConfig:
    from repro.serving.engine import EngineConfig

    return ClusterConfig(
        model=get_smoke_config("qwen7b"), n_workers=1,
        backend="engine", policy="hyperflexis", seed=0,
        engine=EngineConfig.smoke(n_pages=48),
        prefix_cache=prefix_cache,
    )


def _engine_rows(quick: bool) -> list[dict]:
    rows = []
    for shape in ("chat", "agent"):
        runs = {}
        for on in (False, True):
            reqs = _workload(shape, quick)
            t0 = time.perf_counter()
            res = Cluster(_engine_cfg(on)).run(reqs)
            wall = time.perf_counter() - t0
            runs[on] = (res, wall, reqs)
        (off_res, off_wall, off_reqs) = runs[False]
        (on_res, on_wall, on_reqs) = runs[True]
        identical = all(
            a.generated == b.generated
            for a, b in zip(off_reqs, on_reqs)
        )
        reduction = 1.0 - (on_res.n_prefill_tokens
                           / max(off_res.n_prefill_tokens, 1))
        m_on, m_off = on_res.metrics, off_res.metrics
        rows.append({
            **row(
                f"engine/{shape}", on_wall * 1e6 / len(on_reqs),
                f"prefill_tok {off_res.n_prefill_tokens}->"
                f"{on_res.n_prefill_tokens} "
                f"(-{reduction:.0%}) hit_rate={m_on.prefix_hit_rate:.3f} "
                f"ttft {m_off.mean_ttft:.3f}s->{m_on.mean_ttft:.3f}s "
                f"att {m_off.attainment:.2f}->{m_on.attainment:.2f} "
                f"tokens_identical={identical}",
            ),
            "json": {
                "bench": "prefix_cache", "plane": "engine",
                "shape": shape,
                "prefill_tokens_off": off_res.n_prefill_tokens,
                "prefill_tokens_on": on_res.n_prefill_tokens,
                "prefill_token_reduction": round(reduction, 4),
                "prefix_hit_rate": round(m_on.prefix_hit_rate, 4),
                "prefix_hit_tokens": m_on.prefix_hit_tokens,
                "mean_ttft_off": round(m_off.mean_ttft, 5),
                "mean_ttft_on": round(m_on.mean_ttft, 5),
                "attainment_off": round(m_off.attainment, 4),
                "attainment_on": round(m_on.attainment, 4),
                "tokens_identical": identical,
                "prefix_stats": on_res.prefix_stats,
            },
        })
    return rows


def _sim_rows(quick: bool) -> list[dict]:
    n = 64 if quick else 400
    rows = []
    for on in (False, True):
        reqs = shared_prefix_workload(
            task="gsm8k", n=n, qps=48.0, seed=5, n_groups=8,
            shape="chat", prefix_len=512, suffix_len=64,
        )
        cfg = ClusterConfig(
            model=get_config("qwen7b"), n_workers=1, seed=0,
            policy="hyperflexis", chunk_tokens=256,
            prefix_cache=on,
        )
        t0 = time.perf_counter()
        res = Cluster(cfg).run(reqs)
        us = (time.perf_counter() - t0) * 1e6 / len(reqs)
        m = res.metrics
        rows.append(row(
            f"sim/prefix_cache={on}", us,
            f"hit_rate={m.prefix_hit_rate:.3f} "
            f"mean_ttft={m.mean_ttft:.4f}s att={m.attainment:.3f}",
        ))
    return rows


def run(quick: bool = True) -> list[dict]:
    rows = _sim_rows(quick)
    rows += _engine_rows(quick)
    return rows
