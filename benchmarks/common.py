"""Shared helpers for the benchmark harness.

Every benchmark module exposes ``run(quick=True) -> list[dict]`` with
rows ``{"name": str, "us_per_call": float, "derived": str}`` — one
benchmark per paper table/figure.
"""

from __future__ import annotations

import time
from contextlib import contextmanager

from repro.configs import get_config
from repro.core.request import FOUR_TASK_SET, TWO_TASK_SET
from repro.core.scaler import ScalerConfig
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import poisson_workload


def row(name: str, us_per_call: float, derived: str) -> dict:
    return {"name": name, "us_per_call": round(us_per_call, 2),
            "derived": derived}


@contextmanager
def timer():
    t = {}
    t0 = time.perf_counter()
    yield t
    t["s"] = time.perf_counter() - t0


def run_sim(model_name: str, policy: str, qps: float, tasks,
            n_per_task: int, seed: int = 0, **cluster_kw):
    reqs = poisson_workload(tasks, qps=qps, n_per_task=n_per_task,
                            seed=seed,
                            use_priority=cluster_kw.pop(
                                "use_priority", False))
    cfg = ClusterConfig(model=get_config(model_name), policy=policy,
                        seed=seed, **cluster_kw)
    t0 = time.perf_counter()
    res = Cluster(cfg).run(reqs)
    wall = time.perf_counter() - t0
    return res, wall * 1e6 / max(len(reqs), 1)


def mean_over_seeds(fn, seeds=(0, 1, 2)):
    vals = [fn(s) for s in seeds]
    return sum(vals) / len(vals)
