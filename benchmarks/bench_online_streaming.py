"""Online streaming front door — TTFB / inter-token latency / admission.

Replays an open-loop Poisson workload through the ServingSession
(submit-time Eq. 5 admission, per-token stream events) on both planes:

- ``streaming_sim_online``: the simulator under a load near the knee,
  with ``admission="reject"`` — measures how many doomed requests the
  proactive verdict refuses at the front door and the stream-observed
  TTFB / ITL percentiles of what it admits.
- ``streaming_engine_online``: the reduced CPU engine — real jitted
  compute, token stamps interpolated inside fused decode blocks.

Rows carry a machine-readable ``json`` payload that
``benchmarks/run.py --json`` collects into ``BENCH_streaming.json``
(uploaded as a CI artifact alongside ``BENCH_decode.json``).
"""

from __future__ import annotations

import time

from benchmarks.common import row
from repro.configs import get_config, get_smoke_config
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.session import ServingSession
from repro.serving.workload import engine_smoke_workload, poisson_workload


def _replay(cfg, reqs, admission="reject"):
    session = ServingSession(Cluster(cfg), admission=admission)
    t0 = time.perf_counter()
    for r in reqs:
        session.run_until(r.arrival)  # verdict sees the state at arrival
        session.submit_request(r)
    session.drain()
    wall = time.perf_counter() - t0
    res = session.close()
    return session, res, wall


def run(quick: bool = True) -> list[dict]:
    rows = []

    # -- sim plane: admission control under knee-load ------------------------
    n = 20 if quick else 150
    reqs = poisson_workload(["gsm8k", "sharegpt"], qps=48, n_per_task=n,
                            seed=0)
    cfg = ClusterConfig(model=get_config("qwen7b"), n_workers=2,
                        policy="hyperflexis", seed=0)
    session, res, wall = _replay(cfg, reqs)
    s = session.streaming.row()
    payload = {"bench": "online_streaming", "backend": "sim",
               "attainment": res.metrics.row()["attainment"], **s}
    rows.append({**row(
        "streaming_sim_online", wall * 1e6 / max(len(reqs), 1),
        f"ttfb_p99={s['p99_ttfb']}s itl_p99={s['p99_itl']}s "
        f"admitted={s['n_admitted']} rejected={s['n_rejected']}"),
        "json": payload})

    # -- engine plane: real compute, interpolated block stamps ----------------
    from repro.serving.engine import EngineConfig

    ereqs = engine_smoke_workload(n=6 if quick else 16)
    ecfg = ClusterConfig(model=get_smoke_config("qwen7b"),
                         backend="engine", n_workers=1, seed=0,
                         engine=EngineConfig.smoke())
    session, res, wall = _replay(ecfg, ereqs)
    s = session.streaming.row()
    payload = {"bench": "online_streaming", "backend": "engine",
               "attainment": res.metrics.row()["attainment"], **s}
    rows.append({**row(
        "streaming_engine_online", wall * 1e6 / max(len(ereqs), 1),
        f"ttfb_p99={s['p99_ttfb']}s itl_p99={s['p99_itl']}s "
        f"admitted={s['n_admitted']} rejected={s['n_rejected']}"),
        "json": payload})
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
