"""Fault recovery — crash-during-ramp attainment and transfer retry.

Two controlled scenarios over the deterministic FaultInjector:

A. **Crash during ramp** — one seed worker plus a scaled-out replica
   serving a bursty ramp of interactive streams; the replica crashes
   mid-burst.  Run three ways: fault-free reference, crash with
   recovery ON (residents re-queued SLO-aware, scaler replaces the
   capacity), crash with recovery OFF (residents shed as FAILED).
   Metric: SLO attainment — recovery ON must beat recovery OFF, since
   every shed request is an attainment miss by definition.

B. **KV-transfer drops** — P/D cluster with a lossy interconnect
   (seeded Bernoulli drops, capped); dropped hand-offs retry with
   backoff on alternate destinations.  Metric: all requests still
   finish, and the retry count matches the injection count.

The summary row attaches a machine-readable payload collected by
``benchmarks.run --json`` into ``BENCH_faults.json`` (CI artifact).

    PYTHONPATH=src python -m benchmarks.bench_fault_recovery
"""

from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.core.faults import FaultInjector
from repro.core.request import Request
from repro.core.scaler import ScalerConfig
from repro.serving.cluster import Cluster, ClusterConfig

from benchmarks.common import row


# -- scenario A: replica crash during a bursty ramp ---------------------------

def _ramp_workload(n: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    # SLOs sized so the fault-free run attains ~1.0: the gap between
    # the recovery arms then isolates shed-vs-recovered, not a
    # universally-blown TPOT budget
    reqs = [
        Request(rid=i, task="interactive",
                arrival=float(rng.uniform(0.0, 1.2)),
                l_in=int(rng.integers(250, 450)), l_out=120,
                ttft_slo=10.0, tpot_slo=0.3)
        for i in range(n)
    ]
    return sorted(reqs, key=lambda r: r.arrival)


def _run_crash(n: int, seed: int = 1, *, fault: bool,
               recovery: bool = True):
    """Ramp with scaling; wid=1 (the first scale-out replica) dies at
    t=1.0, in the middle of the burst."""
    reqs = _ramp_workload(n, seed)
    faults = (FaultInjector.from_spec("crash:wid=1,t=1.0", seed=seed)
              if fault else None)
    cfg = ClusterConfig(
        model=get_config("qwen7b"), n_workers=1, policy="rr",
        scaling=True,
        scaler=ScalerConfig(tau=0.25, max_workers=3,
                            weight_strategy="d2d"),
        seed=seed, faults=faults, recovery=recovery,
    )
    t0 = time.perf_counter()
    res = Cluster(cfg).run(reqs)
    us = (time.perf_counter() - t0) * 1e6 / max(len(reqs), 1)
    return res, us


# -- scenario B: lossy KV transfers on the P/D plane --------------------------

def _pd_workload(n: int, seed: int) -> list[Request]:
    rng = np.random.default_rng(seed)
    reqs = [
        Request(rid=i, task="pd",
                arrival=float(rng.uniform(0.0, 2.0)),
                l_in=int(rng.integers(200, 400)), l_out=60,
                ttft_slo=6.0, tpot_slo=0.2)
        for i in range(n)
    ]
    return sorted(reqs, key=lambda r: r.arrival)


def _run_lossy(n: int, seed: int = 2, drop_p: float = 0.3,
               drop_max: int = 8):
    reqs = _pd_workload(n, seed)
    faults = FaultInjector.from_spec(
        f"kv_drop:p={drop_p},max={drop_max}", seed=seed
    )
    cfg = ClusterConfig(
        model=get_config("qwen7b"), policy="hyperflexis", mode="pd",
        n_prefill=1, n_decode=2, seed=seed, faults=faults,
    )
    t0 = time.perf_counter()
    res = Cluster(cfg).run(reqs)
    us = (time.perf_counter() - t0) * 1e6 / max(len(reqs), 1)
    return res, us


# -- harness entry -----------------------------------------------------------

def run(quick: bool = True) -> list[dict]:
    n_ramp = 40 if quick else 120
    n_pd = 30 if quick else 100
    rows: list[dict] = []

    arms = {}
    for label, fault, rec in (("ref", False, True),
                              ("recovery_on", True, True),
                              ("recovery_off", True, False)):
        res, us = _run_crash(n_ramp, fault=fault, recovery=rec)
        m = res.metrics
        arms[label] = res
        rows.append(row(
            f"faults/crash/{label}", us,
            f"att={m.attainment:.3f} fin={m.n_finished} "
            f"failed={m.n_failed} recovered={res.n_recovered} "
            f"lost={res.n_lost} scaled_out={res.n_scale_out} "
            f"mk={m.makespan:.1f}s",
        ))

    lossy, us = _run_lossy(n_pd)
    rows.append(row(
        "faults/kv_drop/retry", us,
        f"fin={lossy.metrics.n_finished}/{lossy.metrics.n_total} "
        f"drops={lossy.n_faults} retries={lossy.n_transfer_retries} "
        f"lost={lossy.n_lost}",
    ))

    ref, on, off = arms["ref"], arms["recovery_on"], arms["recovery_off"]
    payload = {
        "bench": "fault_recovery",
        "crash_attainment_ref": round(ref.metrics.attainment, 4),
        "crash_attainment_recovery_on": round(on.metrics.attainment, 4),
        "crash_attainment_recovery_off":
            round(off.metrics.attainment, 4),
        "crash_recovered": on.n_recovered,
        "crash_lost_recovery_on": on.n_lost,
        "crash_lost_recovery_off": off.n_lost,
        "crash_recovery_latency_s": round(on.recovery_latency_s, 4),
        "kv_drops_injected": lossy.n_faults,
        "kv_transfer_retries": lossy.n_transfer_retries,
        "kv_lost": lossy.n_lost,
        "kv_finished": lossy.metrics.n_finished,
        "kv_total": lossy.metrics.n_total,
    }
    summary = row(
        "faults/summary", 0.0,
        f"crash attainment ref={ref.metrics.attainment:.3f} "
        f"on={on.metrics.attainment:.3f} "
        f"off={off.metrics.attainment:.3f}; "
        f"kv retries={lossy.n_transfer_retries} "
        f"lost={lossy.n_lost}",
    )
    summary["json"] = payload
    rows.append(summary)
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    for r in run(quick=not args.full):
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
