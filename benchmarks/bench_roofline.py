"""Roofline summary rows from the dry-run artifact (§Roofline).

Reads runs/dryrun.jsonl (written by repro.launch.dryrun) and emits one
row per (arch x shape x mesh) with the three terms and bottleneck.
"""

from __future__ import annotations

import os

from repro.launch.roofline import is_baseline, load

from benchmarks.common import row


def run(quick: bool = True) -> list[dict]:
    path = os.environ.get("DRYRUN_JSONL", "runs/dryrun.jsonl")
    rows: list[dict] = []
    if not os.path.exists(path):
        rows.append(row("roofline/missing", 0.0,
                        f"no {path}; run python -m repro.launch.dryrun"))
        return rows
    recs = load(path)
    n_ok = 0
    for r in recs:
        if r.get("status") != "ok":
            rows.append(row(
                f"roofline/{r.get('arch')}/{r.get('shape')}/"
                f"{r.get('mesh')}", 0.0,
                f"FAIL {r.get('error', '')[:80]}",
            ))
            continue
        if not is_baseline(r):
            # hillclimb variants reported in EXPERIMENTS.md §Perf
            continue
        n_ok += 1
        rows.append(row(
            f"roofline/{r['arch']}/{r['shape']}/{r['mesh']}",
            r.get("total_s", 0) * 1e6,
            f"c={r['compute_term_s']:.3e}s m={r['memory_term_s']:.3e}s "
            f"x={r['collective_term_s']:.3e}s "
            f"bottleneck={r['bottleneck']} "
            f"useful={r['useful_flops_ratio']:.2f} "
            f"frac={r['roofline_fraction']:.2f}",
        ))
    rows.append(row("roofline/summary", 0.0, f"cells_ok={n_ok}"))
    return rows
