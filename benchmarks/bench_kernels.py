"""Kernel micro-bench: jnp oracle wall-time on CPU (the only honest
timing this container can produce) + interpret-mode Pallas parity checks
at production-relevant tile shapes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd import ssd

from benchmarks.common import row


def _time(fn, *args, iters=3):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(quick: bool = True) -> list[dict]:
    rows: list[dict] = []
    key = jax.random.key(0)
    ks = jax.random.split(key, 8)

    # flash attention (prefill tile): B1 H8 S2048 D128
    s = 1024 if quick else 4096
    q = jax.random.normal(ks[0], (1, 8, s, 128), jnp.bfloat16)
    jitted_ref = jax.jit(
        lambda q, k, v: ref.flash_attention_ref(q, k, v, causal=True)
    )
    us = _time(jitted_ref, q, q, q)
    got = flash_attention(q, q, q, causal=True, block_q=256, block_k=256)
    err = float(jnp.max(jnp.abs(
        got.astype(jnp.float32)
        - jitted_ref(q, q, q).astype(jnp.float32))))
    rows.append(row(f"kernels/flash_attn/S{s}", us,
                    f"pallas_interpret_maxerr={err:.3e}"))

    # decode attention: B8 H8 S8192 D128
    sd = 2048 if quick else 8192
    qd = jax.random.normal(ks[1], (8, 8, 128), jnp.bfloat16)
    kc = jax.random.normal(ks[2], (8, 8, sd, 128), jnp.bfloat16)
    kv_len = jnp.full((8,), sd, jnp.int32)
    jit_dec = jax.jit(ref.decode_attention_ref)
    us = _time(jit_dec, qd, kc, kc, kv_len)
    got = decode_attention(qd, kc, kc, kv_len, block_k=256)
    err = float(jnp.max(jnp.abs(
        got.astype(jnp.float32)
        - jit_dec(qd, kc, kc, kv_len).astype(jnp.float32))))
    rows.append(row(f"kernels/decode_attn/S{sd}", us,
                    f"pallas_interpret_maxerr={err:.3e}"))

    # ssd chunk scan: B2 S1024 H8 P64 N128
    ss = 512 if quick else 2048
    x = jax.random.normal(ks[3], (2, ss, 8, 64))
    dt = jax.nn.softplus(jax.random.normal(ks[4], (2, ss, 8)))
    a = -jnp.exp(jax.random.normal(ks[5], (8,)) * 0.5)
    bm = jax.random.normal(ks[6], (2, ss, 128))
    cm = jax.random.normal(ks[7], (2, ss, 128))
    jit_ssd = jax.jit(ref.ssd_ref)
    us = _time(jit_ssd, x, dt, a, bm, cm)
    y1, s1 = ssd(x, dt, a, bm, cm, chunk=256)
    y2, s2 = jit_ssd(x, dt, a, bm, cm)
    err = float(jnp.max(jnp.abs(y1 - y2)))
    rows.append(row(f"kernels/ssd/S{ss}", us,
                    f"pallas_interpret_maxerr={err:.3e}"))
    return rows
