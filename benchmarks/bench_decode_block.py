"""Fused decode blocks — dispatch-amortized decode throughput.

Pure-decode micro-bench on the real engine (CPU smoke config): short
prompts decode long outputs with an empty queue, swept over the
``decode_block`` ceiling K.  K=1 is the per-token baseline — every
generated token pays one jit dispatch, one host sync, and (without the
device-resident mirrors) a pos/last_token/page-table upload; a K-block
pays all of that once per K tokens.

Reports decode tokens/s, jitted dispatches (= host syncs) per token,
the block-size histogram, and greedy token-identity vs the K=1 run.
Rows carry a machine-readable ``json`` payload that
``benchmarks/run.py --json`` collects into ``BENCH_decode.json`` (the
perf-trajectory artifact CI uploads).
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks.common import row


def _run_engine(model, params, ecfg_kw, k, prompts, n_new, fn_cache):
    from repro.core.request import Request
    from repro.serving.engine import EngineConfig, InferenceEngine

    reqs = [Request.from_prompt(i, p, max_new=n_new)
            for i, p in enumerate(prompts)]
    # one fn_cache across the K sweep: chunk/prefill jits are identical
    # at every K (block fns key per K), so compile cost is paid once
    eng = InferenceEngine(model, params, EngineConfig(
        decode_block=k, **ecfg_kw), fn_cache=fn_cache)
    eng.warm_decode_blocks()
    warm = Request.from_prompt(-1, np.arange(1, 9, dtype=np.int32),
                               max_new=3)
    eng.submit(warm)
    eng.run_until_done()
    for r in reqs:
        eng.submit(r)
    # drain prefill so the timed region is pure decode (the regime
    # blocks target; under queue pressure K collapses to 1 by design)
    for _ in range(10_000):
        if not eng.queue and not eng.prefilling:
            break
        eng.step()
    tok0, disp0 = eng.n_decode_tokens, eng.n_dispatches
    hist0 = dict(eng.decode_block_hist)
    t0 = time.perf_counter()
    eng.run_until_done()
    wall = time.perf_counter() - t0
    tokens = eng.n_decode_tokens - tok0
    disp = eng.n_dispatches - disp0
    hist = {b: n - hist0.get(b, 0)
            for b, n in eng.decode_block_hist.items()
            if n - hist0.get(b, 0) > 0}
    assert all(r.finish_time is not None for r in reqs)
    return {
        "tokens": tokens,
        "wall_s": wall,
        "tokens_per_s": tokens / max(wall, 1e-9),
        "dispatches": disp,
        "dispatches_per_token": disp / max(tokens, 1),
        "block_hist": hist,
        "generated": [list(r.generated) for r in reqs],
    }


def run(quick: bool = True) -> list[dict]:
    import jax

    from repro.configs import get_smoke_config
    from repro.models import build_model

    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(0)
    n_new = 32 if quick else 96
    ecfg_kw = dict(n_slots=4, max_len=16 + n_new + 8, prefill_batch=4,
                   page_size=8, chunk_size=16)
    prompts = [rng.integers(0, cfg.vocab_size, size=8).astype(np.int32)
               for _ in range(4)]

    rows = []
    base = None
    fn_cache: dict = {}
    for k in (1, 2, 4, 8):
        res = _run_engine(model, params, ecfg_kw, k, prompts, n_new,
                          fn_cache)
        if base is None:
            base = res
        identical = res["generated"] == base["generated"]
        speedup = res["tokens_per_s"] / base["tokens_per_s"]
        payload = {
            "bench": "decode_block",
            "k": k,
            "tokens": res["tokens"],
            "tokens_per_s": round(res["tokens_per_s"], 2),
            "dispatches_per_token": round(res["dispatches_per_token"], 4),
            "block_hist": res["block_hist"],
            "speedup_vs_k1": round(speedup, 3),
            "identical_to_k1": identical,
        }
        rows.append({
            **row(
                f"decode_block/K={k}",
                res["wall_s"] * 1e6 / max(res["tokens"], 1),
                f"tok_s={res['tokens_per_s']:.1f} "
                f"disp_per_tok={res['dispatches_per_token']:.3f} "
                f"speedup={speedup:.2f}x identical={identical} "
                f"hist={res['block_hist']}",
            ),
            "json": payload,
        })
    return rows
