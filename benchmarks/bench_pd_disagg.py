"""Fig. 4 — P/D-disaggregated mode, 4-task workloads, Qwen7B & Qwen32B.

HyperFlexis-PD (two-stage Dispatcher+Migrator) and
HyperFlexis-PD-Scaling (4 -> up to 8 instances) vs one-shot RR-PD.
Qwen32B runs TP=2 (the paper's cross-node configuration).
"""

from __future__ import annotations

from repro.core.request import FOUR_TASK_SET
from repro.core.scaler import ScalerConfig

from benchmarks.common import row, run_sim


def run(quick: bool = True) -> list[dict]:
    n = 50 if quick else 300
    rows: list[dict] = []
    best_gain = 0.0
    best_lat = 0.0
    for model, tp, qps_list in (
        ("qwen7b", 1, (96, 128)),
        ("qwen32b", 2, (40, 56)),
    ):
        for qps in qps_list:
            res = {}
            for label, kw in (
                ("hfx-pd", dict(policy="hyperflexis", mode="pd",
                                n_prefill=2, n_decode=2)),
                ("rr-pd", dict(policy="rr", mode="pd", n_prefill=2,
                               n_decode=2, one_shot_pd=True)),
                ("hfx-pd-scaling",
                 dict(policy="hyperflexis", mode="pd", n_prefill=2,
                      n_decode=2, scaling=True,
                      scaler=ScalerConfig(max_workers=8))),
            ):
                r, us = run_sim(model, kw.pop("policy"), qps,
                                FOUR_TASK_SET, n, seed=1, tp=tp, **kw)
                m = r.metrics
                res[label] = m
                rows.append(row(
                    f"fig4/{model}/qps{qps}/{label}", us,
                    f"att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s "
                    f"cost={m.cost_units:.0f} "
                    f"kvx={r.kv_transfers} flips={r.n_role_flips}",
                ))
            if res["rr-pd"].attainment > 0:
                best_gain = max(
                    best_gain,
                    res["hfx-pd-scaling"].attainment
                    / res["rr-pd"].attainment,
                )
            if res["rr-pd"].mean_e2e > 0:
                best_lat = max(
                    best_lat,
                    1 - res["hfx-pd"].mean_e2e / res["rr-pd"].mean_e2e,
                )
    rows.append(row(
        "fig4/summary", 0.0,
        f"pd_attainment_gain_vs_rr={best_gain:.2f}x "
        f"latency_reduction={best_lat*100:.1f}% "
        f"(paper: 2.54x / 31.82%)",
    ))
    return rows
