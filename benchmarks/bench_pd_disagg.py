"""Fig. 4 — P/D-disaggregated mode, 4-task workloads, Qwen7B & Qwen32B.

HyperFlexis-PD (two-stage Dispatcher+Migrator) and
HyperFlexis-PD-Scaling (4 -> up to 8 instances) vs one-shot RR-PD.
Qwen32B runs TP=2 (the paper's cross-node configuration).

The sweep ends with an engine-plane smoke row (real paged-KV hand-off
between InferenceEngine replicas — jit-compiles a reduced model, adds
~1 min to the sweep); run just that row standalone with:

    PYTHONPATH=src python -m benchmarks.bench_pd_disagg --backend engine
"""

from __future__ import annotations

import time

from repro.core.request import FOUR_TASK_SET
from repro.core.scaler import ScalerConfig

from benchmarks.common import row, run_sim


def run_engine(n: int = 8) -> list[dict]:
    """Engine-plane P/D smoke: the same Dispatcher+Migrator over real
    engines; every migration exports/installs an actual KV payload."""
    from repro.configs import get_smoke_config
    from repro.serving.cluster import Cluster, ClusterConfig
    from repro.serving.engine import EngineConfig
    from repro.serving.workload import engine_smoke_workload

    reqs = engine_smoke_workload(n=n, seed=1)
    cfg = ClusterConfig(
        model=get_smoke_config("qwen7b"), backend="engine",
        policy="hyperflexis", mode="pd", n_prefill=1, n_decode=1, seed=1,
        engine=EngineConfig.smoke(),
    )
    t0 = time.perf_counter()
    cluster = Cluster(cfg)
    res = cluster.run(reqs)
    us = (time.perf_counter() - t0) * 1e6 / max(len(reqs), 1)
    m = res.metrics
    return [row(
        "fig4/engine-pd-smoke", us,
        f"finished={m.n_finished}/{m.n_total} kvx={res.kv_transfers} "
        f"kv_bytes={cluster.tl.kv_bytes_moved:.0f} (real paged-KV "
        f"hand-off, measured-bytes costing)",
    )]


def run(quick: bool = True, engine_row: bool = True) -> list[dict]:
    n = 50 if quick else 300
    rows: list[dict] = []
    best_gain = 0.0
    best_lat = 0.0
    for model, tp, qps_list in (
        ("qwen7b", 1, (96, 128)),
        ("qwen32b", 2, (40, 56)),
    ):
        for qps in qps_list:
            res = {}
            for label, kw in (
                ("hfx-pd", dict(policy="hyperflexis", mode="pd",
                                n_prefill=2, n_decode=2)),
                ("rr-pd", dict(policy="rr", mode="pd", n_prefill=2,
                               n_decode=2, one_shot_pd=True)),
                ("hfx-pd-scaling",
                 dict(policy="hyperflexis", mode="pd", n_prefill=2,
                      n_decode=2, scaling=True,
                      scaler=ScalerConfig(max_workers=8))),
            ):
                r, us = run_sim(model, kw.pop("policy"), qps,
                                FOUR_TASK_SET, n, seed=1, tp=tp, **kw)
                m = r.metrics
                res[label] = m
                rows.append(row(
                    f"fig4/{model}/qps{qps}/{label}", us,
                    f"att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s "
                    f"cost={m.cost_units:.0f} "
                    f"kvx={r.kv_transfers} flips={r.n_role_flips}",
                ))
            if res["rr-pd"].attainment > 0:
                best_gain = max(
                    best_gain,
                    res["hfx-pd-scaling"].attainment
                    / res["rr-pd"].attainment,
                )
            if res["rr-pd"].mean_e2e > 0:
                best_lat = max(
                    best_lat,
                    1 - res["hfx-pd"].mean_e2e / res["rr-pd"].mean_e2e,
                )
    rows.append(row(
        "fig4/summary", 0.0,
        f"pd_attainment_gain_vs_rr={best_gain:.2f}x "
        f"latency_reduction={best_lat*100:.1f}% "
        f"(paper: 2.54x / 31.82%)",
    ))
    if engine_row:
        rows.extend(run_engine())
    return rows


def main() -> None:
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "engine"],
                    help="engine: just the real-engine smoke row; "
                         "sim: the discrete-event sweep only")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()
    rows = (run_engine() if args.backend == "engine"
            else run(quick=not args.full, engine_row=False))
    for r in rows:
        print(f"{r['name']},{r['us_per_call']},{r['derived']}")


if __name__ == "__main__":
    main()
