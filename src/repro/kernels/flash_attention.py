"""Pallas TPU flash attention (prefill), causal + sliding-window.

Tiling: grid (batch*heads, num_q_blocks, num_kv_blocks) with the kv axis
innermost ("arbitrary" = sequential on TPU), so the VMEM working set per
step is one (Bq, D) query block, one (Bk, D) key/value block and the
(Bq, D) f32 accumulator + (Bq,) running max/sum — the classic online
softmax.  Block sizes default to 128/256: multiples of the 128-wide MXU
and small enough that Bq*D + 2*Bk*D + Bq*Bk floats stay well under the
~16 MiB/core VMEM budget at D=128.

Fully-masked kv blocks (above the causal diagonal or outside the local
window) are skipped with ``pl.when`` — on real TPU this halves causal
prefill work; the jnp fallback cannot skip, which is exactly the gap the
roofline's MODEL_FLOPS/HLO_FLOPs ratio exposes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, n_kv: int, causal: bool, window: int,
                  scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = qi * bq
    k_start = ki * bk
    # block-level reachability: any (q, k) pair in range?
    reachable = True
    if causal:
        reachable = k_start <= q_start + bq - 1
    if window > 0:
        reachable = jnp.logical_and(
            reachable, q_start - (k_start + bk - 1) < window
        )

    @pl.when(reachable)
    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, d)
        k = k_ref[0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (bq, bk)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= kpos <= qpos
        if window > 0:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        l_prev = l_ref[...]
        m_cur = jnp.max(s, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D)."""
    b, h, s, d = q.shape
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    bh = b * h
    qf = q.reshape(bh, s, d)
    kf = k.reshape(bh, s, d)
    vf = v.reshape(bh, s, d)
    n_q = s // block_q
    n_kv = s // block_k
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(
        _flash_kernel, bq=block_q, bk=block_k, n_kv=n_kv, causal=causal,
        window=window, scale=scale,
    )
    out = pl.pallas_call(
        kernel,
        grid=(bh, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda g, qi, ki: (g, qi, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, qi, ki: (g, ki, 0)),
            pl.BlockSpec((1, block_k, d), lambda g, qi, ki: (g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda g, qi, ki: (g, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
        ],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(b, h, s, d)
