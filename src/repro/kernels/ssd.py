"""Pallas TPU kernel for the Mamba-2 SSD chunk recurrence.

The SSD computation is a sequential scan over chunks with a per-(batch,
head) state matrix (P, N).  On TPU we map grid = (B, H, num_chunks) with
the chunk axis innermost/sequential and keep the running state in a VMEM
scratch that persists across chunk steps (it is reset at chunk 0).  Per
step the working set is the (Q, P) x-chunk, (Q, N) B/C chunks, the
(Q, Q) intra-chunk decay matrix and the (P, N) state — for the
production config (Q=256, P=64, N=128) that is ~1 MiB, comfortably
inside VMEM, and every matmul dim is a multiple of 64/128 (MXU aligned).

This is the TPU-native adaptation of the paper-adjacent GPU SSD kernel:
instead of warp-level parallel prefix sums, the intra-chunk term is a
dense (Q, Q) matmul on the MXU and the inter-chunk recurrence rides the
sequential grid axis.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref,
                state_ref, *, q: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0, 0].astype(jnp.float32)  # (1, Q) -> (Q,)
    dt = dt.reshape(q)
    a = a_ref[0, 0]  # scalar
    b = b_ref[0, 0].astype(jnp.float32)  # (Q, N)
    c = c_ref[0, 0].astype(jnp.float32)  # (Q, N)

    da = dt * a  # (Q,) negative decay exponents
    cum = jnp.cumsum(da)  # (Q,)

    # ---- intra-chunk (quadratic) term ----
    diff = cum[:, None] - cum[None, :]  # (Q, Q)
    iq = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    ik = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    l_mat = jnp.where(ik <= iq, jnp.exp(diff), 0.0)
    cb = jax.lax.dot_general(
        c, b, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q)
    m = cb * l_mat * dt[None, :]
    y = jax.lax.dot_general(
        m, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    # ---- carried-state contribution ----
    state = state_ref[...]  # (P, N)
    y_off = jax.lax.dot_general(
        c, state, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (Q, P)
    y = y + y_off * jnp.exp(cum)[:, None]

    # ---- state update ----
    decay_out = jnp.exp(cum[-1] - cum)  # (Q,)
    xw = x * (dt * decay_out)[:, None]  # (Q, P)
    new_state = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (P, N)
    state_ref[...] = new_state

    y_ref[0, 0, 0] = y.astype(y_ref.dtype)

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        state_out_ref[0, 0] = new_state


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, a, b_mat, c_mat, *, chunk: int = 256,
        interpret: bool = True):
    """Chunked SSD, single B/C group.

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative;
    b_mat, c_mat: (B, S, N).
    Returns (y: (B, S, H, P), final_state: (B, H, P, N) f32).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    # layout for clean blocking: (B, H, NC, Q, ...)
    xk = x.transpose(0, 2, 1, 3).reshape(bsz, h, nc, chunk, p)
    dtk = dt.transpose(0, 2, 1).reshape(bsz, h, nc, 1, chunk)
    bk = b_mat.reshape(bsz, nc, chunk, n)
    ck = c_mat.reshape(bsz, nc, chunk, n)
    a2 = a.reshape(h, 1).astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, q=chunk, n_chunks=nc)
    y, final_state = pl.pallas_call(
        kernel,
        grid=(bsz, h, nc),
        in_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, 1, 1, chunk),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi, ci: (hi, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, ci, 0, 0)),
            pl.BlockSpec((1, 1, chunk, n),
                         lambda bi, hi, ci: (bi, ci, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, 1, chunk, p),
                         lambda bi, hi, ci: (bi, hi, ci, 0, 0)),
            pl.BlockSpec((1, 1, p, n), lambda bi, hi, ci: (bi, hi, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, h, nc, chunk, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, p, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(xk, dtk, a2, bk, ck)
    y = y.reshape(bsz, h, s, p).transpose(0, 2, 1, 3)
    return y, final_state
