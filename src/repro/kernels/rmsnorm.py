"""Pallas TPU fused RMSNorm.

Trivial but load-bearing: RMSNorm appears 2x per layer and in the jnp
path costs three HBM round-trips (square-mean, rsqrt-scale, affine).
The kernel fuses them into one read + one write per (rows, D) tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + s_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype
    )


@functools.partial(
    jax.jit, static_argnames=("eps", "block_rows", "interpret")
)
def rmsnorm(x, scale, *, eps: float = 1e-5, block_rows: int = 256,
            interpret: bool = True) -> jax.Array:
    """x: (..., D); scale: (D,)."""
    orig_shape = x.shape
    d = x.shape[-1]
    rows = 1
    for dim in x.shape[:-1]:
        rows *= dim
    x2 = x.reshape(rows, d)
    br = min(block_rows, rows)
    assert rows % br == 0, (rows, br)

    out = pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(rows // br,),
        in_specs=[
            pl.BlockSpec((br, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, d), x.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(x2, scale)
    return out.reshape(orig_shape)
