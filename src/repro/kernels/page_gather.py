"""Pallas TPU page gather: linearize one sequence's paged KV cache.

P/D disaggregation moves a request's KV cache from the prefill engine
to the decode engine (paper §6).  The source cache lives scattered
across a shared page pool, so the export path must first materialize
the sequence contiguously — a pure data-movement kernel: grid (H, M)
with the page id for step ``mi`` scalar-prefetched, so each grid step
DMAs one physical (ps, D) page tile straight into its logical position
of the output.  No compute, one pass over the payload; the transfer
then streams the contiguous buffer over ICI.

The inverse (scatter into the destination pool) is a jnp ``.at[].set``
on the allocator-chosen pages — see
:func:`repro.serving.kv_manager.scatter_slot_kv`.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params


def _gather_kernel(pt_ref, pages_ref, o_ref):
    # the index maps did all the work: copy one page tile through VMEM
    o_ref[...] = pages_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def page_gather(pages, page_ids, *, interpret: bool = True) -> jax.Array:
    """pages: (NP, H, ps, D); page_ids: (M,) int32 (-1 = unallocated,
    clamped — callers slice the output to the valid token count).
    Returns the sequence's cache linearized to (H, M*ps, D)."""
    n_pages, h, ps, d = pages.shape
    m = page_ids.shape[0]
    pt = jnp.clip(page_ids, 0, n_pages - 1).astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(h, m),
        in_specs=[
            pl.BlockSpec(
                (1, 1, ps, d), lambda hi, mi, pt: (pt[mi], hi, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, ps, d), lambda hi, mi, pt: (hi, mi, 0, 0)
        ),
    )
    out = pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((h, m, ps, d), pages.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pt, pages)
    return out.reshape(h, m * ps, d)
