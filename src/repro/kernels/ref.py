"""Pure-jnp oracles for every Pallas kernel.

These are the ground truth the kernels are validated against
(``assert_allclose`` sweeps in tests/test_kernels_*.py) and the
implementation used on non-TPU backends.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q, k, v, *, causal: bool = True,
                        window: int = 0) -> jax.Array:
    """q, k, v: (B, H, S, D) -> (B, H, S, D); plain softmax attention."""
    s = q.shape[2]
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum(
        "bhqd,bhkd->bhqk", q, k, preferred_element_type=jnp.float32
    ) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= ki <= qi
    if window > 0:
        mask &= (qi - ki) < window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs.astype(v.dtype), v)


def decode_attention_ref(q, k_cache, v_cache, kv_len) -> jax.Array:
    """q: (B, H, D); caches: (B, H, S, D); kv_len: (B,) -> (B, H, D)."""
    scale = 1.0 / (q.shape[-1] ** 0.5)
    scores = jnp.einsum(
        "bhd,bhkd->bhk", q, k_cache, preferred_element_type=jnp.float32
    ) * scale
    mask = jnp.arange(k_cache.shape[2])[None, :] < kv_len[:, None]
    scores = jnp.where(mask[:, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", probs.astype(v_cache.dtype), v_cache)


def paged_gather(pages, page_table) -> jax.Array:
    """Linearize a paged KV pool through a page table.

    pages: (NP, H, ps, D); page_table: (B, MP) int32, -1 = unallocated.
    Returns (B, H, MP*ps, D).  Unallocated entries gather page 0 — the
    caller masks them via kv_len, exactly like right-padding.
    """
    pt = jnp.clip(page_table, 0, pages.shape[0] - 1)
    g = pages[pt]  # (B, MP, H, ps, D)
    b, mp, h, ps, d = g.shape
    return g.transpose(0, 2, 1, 3, 4).reshape(b, h, mp * ps, d)


def page_gather_ref(pages, page_ids) -> jax.Array:
    """Linearize ONE sequence's pages (P/D export path): the B == 1
    case of :func:`paged_gather`, sharing its clamp + token-major
    layout invariant.

    pages: (NP, H, ps, D); page_ids: (M,) int32, -1 = unallocated
    (clamped; callers slice to the valid token count).
    Returns (H, M*ps, D) — the sequence's cache, contiguous.
    """
    return paged_gather(pages, page_ids[None])[0]


def paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                               kv_len) -> jax.Array:
    """Gather-then-attend oracle for the paged kernel (GQA-aware:
    pages carry Hkv heads, broadcast to q's Hq after the gather)."""
    k = paged_gather(k_pages, page_table)
    v = paged_gather(v_pages, page_table)
    g = q.shape[1] // k.shape[1]
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    return decode_attention_ref(q, k, v, kv_len)


def ssd_ref(x, dt, a, b_mat, c_mat) -> tuple[jax.Array, jax.Array]:
    """Naive sequential SSD recurrence (the definitional oracle).

    x: (B, S, H, P); dt: (B, S, H); a: (H,) negative;
    b_mat, c_mat: (B, S, N)  (single group, broadcast over heads).
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    n = b_mat.shape[-1]

    def step(state, t):
        xt, dtt, bt, ct = t  # (B,H,P), (B,H), (B,N), (B,N)
        da = jnp.exp(dtt * a)  # (B, H)
        state = state * da[..., None, None] + (
            dtt[..., None, None]
            * bt[:, None, None, :]
            * xt[..., None].astype(jnp.float32)
        )
        y = jnp.einsum("bhpn,bn->bhp", state, ct.astype(jnp.float32))
        return state, y

    init = jnp.zeros((bsz, h, p, n), jnp.float32)
    xs = (
        x.swapaxes(0, 1),
        dt.swapaxes(0, 1),
        b_mat.swapaxes(0, 1).astype(jnp.float32),
        c_mat.swapaxes(0, 1).astype(jnp.float32),
    )
    final, ys = jax.lax.scan(step, init, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), final


def rmsnorm_ref(x, scale, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)
