"""Dispatching wrappers: Pallas kernel on TPU (or interpret mode for
validation), pure-jnp oracle otherwise.

``set_backend("pallas")`` routes the model hot-spots through the
kernels; the default "jnp" keeps CPU dry-runs and tests on the oracle.
"""

from __future__ import annotations

import jax

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention as _pl_decode
from repro.kernels.decode_attention import (
    paged_decode_attention as _pl_paged_decode,
)
from repro.kernels.flash_attention import flash_attention as _pl_flash
from repro.kernels.page_gather import page_gather as _pl_page_gather
from repro.kernels.rmsnorm import rmsnorm as _pl_rmsnorm
from repro.kernels.ssd import ssd as _pl_ssd

_BACKEND = "jnp"


def set_backend(name: str) -> None:
    global _BACKEND
    assert name in ("jnp", "pallas", "pallas_interpret")
    _BACKEND = name


def get_backend() -> str:
    return _BACKEND


def _interpret() -> bool:
    if _BACKEND == "pallas_interpret":
        return True
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal=True, window=0, **kw):
    if _BACKEND == "jnp":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    return _pl_flash(
        q, k, v, causal=causal, window=window, interpret=_interpret(), **kw
    )


def decode_attention(q, k_cache, v_cache, kv_len, **kw):
    if _BACKEND == "jnp":
        return ref.decode_attention_ref(q, k_cache, v_cache, kv_len)
    return _pl_decode(q, k_cache, v_cache, kv_len, interpret=_interpret(),
                      **kw)


def paged_decode_attention(q, k_pages, v_pages, page_table, kv_len, **kw):
    if _BACKEND == "jnp":
        return ref.paged_decode_attention_ref(
            q, k_pages, v_pages, page_table, kv_len
        )
    return _pl_paged_decode(q, k_pages, v_pages, page_table, kv_len,
                            interpret=_interpret(), **kw)


def page_gather(pages, page_ids, **kw):
    if _BACKEND == "jnp":
        return ref.page_gather_ref(pages, page_ids)
    return _pl_page_gather(pages, page_ids, interpret=_interpret(), **kw)


def ssd(x, dt, a, b_mat, c_mat, *, chunk=256, **kw):
    if _BACKEND == "jnp":
        return ref.ssd_ref(x, dt, a, b_mat, c_mat)
    return _pl_ssd(x, dt, a, b_mat, c_mat, chunk=chunk,
                   interpret=_interpret(), **kw)


def rmsnorm(x, scale, *, eps=1e-5, **kw):
    if _BACKEND == "jnp":
        return ref.rmsnorm_ref(x, scale, eps=eps)
    return _pl_rmsnorm(x, scale, eps=eps, interpret=_interpret(), **kw)
