"""Version tolerance for the Pallas TPU API.

JAX renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``
(and at various points moved ``dimension_semantics`` between the two).
Every kernel goes through :func:`compiler_params` so the repo runs on
any JAX from 0.4.3x up without per-call-site version checks.
"""

from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

_CLS = getattr(pltpu, "CompilerParams", None) or getattr(
    pltpu, "TPUCompilerParams"
)


def compiler_params(**kw):
    """Build the TPU compiler-params struct under either name.

    Unknown keywords are dropped (older JAX structs accept fewer
    fields) rather than raised, so call sites can pass the newest
    vocabulary unconditionally.
    """
    try:
        return _CLS(**kw)
    except TypeError:
        fields = getattr(_CLS, "__dataclass_fields__", {})
        return _CLS(**{k: v for k, v in kw.items() if k in fields})
