"""Pallas TPU decode attention: one query token vs. a long KV cache.

Decode is memory-bound (arithmetic intensity ~1 flop/byte over the
cache), so the kernel's job is to stream the cache through VMEM in
(block_k, D) tiles exactly once while keeping the online-softmax state
(1, D) accumulator + running max/sum in VMEM.  Grid: (B, H, num_kv)
with the kv axis sequential.  Per-sequence valid length arrives via a
scalar-prefetch operand (SMEM) so masked tail blocks are skipped.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import compiler_params

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   bk: int, n_kv: int, scale: float):
    b = pl.program_id(0)
    ki = pl.program_id(2)
    kv_len = len_ref[b]

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(ki * bk < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (1, bk)
        kpos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(ki == n_kv - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_k", "interpret")
)
def decode_attention(q, k_cache, v_cache, kv_len, *, block_k: int = 256,
                     interpret: bool = True) -> jax.Array:
    """q: (B, H, D); caches: (B, H, S, D); kv_len: (B,) -> (B, H, D)."""
    b, h, s, d = k_cache.shape
    assert s % block_k == 0, (s, block_k)
    n_kv = s // block_k
    scale = 1.0 / (d ** 0.5)
    q4 = q[:, :, None, :]  # (B, H, 1, D)

    kernel = functools.partial(
        _decode_kernel, bk=block_k, n_kv=n_kv, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, h, n_kv),
        in_specs=[
            pl.BlockSpec((1, 1, 1, d), lambda bi, hi, ki, lens: (bi, hi, 0, 0)),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, ki, lens: (bi, hi, ki, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, d), lambda bi, hi, ki, lens: (bi, hi, ki, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d), lambda bi, hi, ki, lens: (bi, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kv_len.astype(jnp.int32), q4, k_cache, v_cache)
    return out[:, :, 0, :]


# ---------------------------------------------------------------------------
# Paged decode attention (block/page KV layout)
# ---------------------------------------------------------------------------
#
# The KV cache lives in a pool of fixed-size pages (n_pages, H, ps, D);
# request b's logical token t sits at page_table[b, t // ps], offset
# t % ps.  The page table is a scalar-prefetch operand: the *index map*
# reads it to pick which physical page each grid step streams through
# VMEM, so the kernel never materializes a gathered contiguous cache.


def _paged_decode_kernel(pt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *,
                         ps: int, n_pg: int, scale: float):
    b = pl.program_id(0)
    pi = pl.program_id(2)
    kv_len = len_ref[b]

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    @pl.when(pi * ps < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (1, d)
        k = k_ref[0, 0].astype(jnp.float32)  # (ps, d)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (1, ps)
        kpos = pi * ps + jax.lax.broadcasted_iota(jnp.int32, (1, ps), 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )

    @pl.when(pi == n_pg - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_decode_attention(q, k_pages, v_pages, page_table, kv_len, *,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Hq, D); k/v_pages: (NP, Hkv, ps, D) with Hq % Hkv == 0
    (GQA: query head hi reads kv head hi // g through the index map —
    the shared pool is never replicated); page_table: (B, MP) int32
    (-1 = unallocated); kv_len: (B,).  Returns (B, Hq, D)."""
    b, h, d = q.shape
    n_pages, hkv, ps, _ = k_pages.shape
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    mp = page_table.shape[1]
    scale = 1.0 / (d ** 0.5)
    q4 = q[:, :, None, :]  # (B, Hq, 1, D)
    # Unallocated entries are masked via kv_len; clamp so the index map
    # still names a real page.
    pt = jnp.clip(page_table, 0, n_pages - 1).astype(jnp.int32)

    kernel = functools.partial(
        _paged_decode_kernel, ps=ps, n_pg=mp, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, h, mp),
        in_specs=[
            pl.BlockSpec(
                (1, 1, 1, d), lambda bi, hi, pi, pt, lens: (bi, hi, 0, 0)
            ),
            pl.BlockSpec(
                (1, 1, ps, d),
                lambda bi, hi, pi, pt, lens: (pt[bi, pi], hi // g, 0, 0),
            ),
            pl.BlockSpec(
                (1, 1, ps, d),
                lambda bi, hi, pi, pt, lens: (pt[bi, pi], hi // g, 0, 0),
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, d), lambda bi, hi, pi, pt, lens: (bi, hi, 0, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((1, d), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, 1, d), q.dtype),
        compiler_params=compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(pt, kv_len.astype(jnp.int32), q4, k_pages, v_pages)
    return out[:, :, 0, :]
