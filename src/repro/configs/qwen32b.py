"""qwen32b — the paper's mid-size serving model (TP=2 in the paper)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen2.5-32B (paper serving model)",
)
