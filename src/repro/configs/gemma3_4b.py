"""gemma3-4b — 5:1 local:global attention, 128k context, huge vocab.

[hf:google/gemma-3-1b-pt; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144.  head_dim=256 (gemma-style, decoupled from
d_model/n_heads).  Sliding window 1024 for local layers.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262144,
    tie_embeddings=True,
    window=1024,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
    source="hf:google/gemma-3-1b-pt",
)
