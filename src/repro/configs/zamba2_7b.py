"""zamba2-7b — hybrid Mamba2 + weight-shared attention blocks.

[arXiv:2411.15242; unverified] 81L d_model=3584 32H (kv=32 — MHA)
d_ff=14336 vocab=32000, ssm_state=64.

Layer pattern: groups of 5 Mamba-2 layers followed by one invocation of a
single *weight-shared* full-attention block (13 invocations), plus a
3-layer Mamba tail: 13*(5+1) + 3 = 81 layers total.  The shared block's
concat-with-embedding input and per-invocation LoRA deltas from the
published model are simplified to a plain shared attention block
(documented in DESIGN.md §Hardware-adaptation).
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    d_ff=14336,
    vocab_size=32000,
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk_size=256),
    shared_attn_period=5,
    source="arXiv:2411.15242",
)
