"""llama70b — the paper's largest serving model (TP=8 in the paper)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    rope_theta=500_000.0,
    source="hf:meta-llama/Llama-3-70B (paper serving model)",
)
