"""qwen7b — the paper's smallest serving model (TP=1 in the paper)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen7b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab_size=151936,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen-7B (paper serving model)",
)
