"""Architecture registry.

``get_config(name)`` resolves any assigned architecture id (or paper
serving model) to its :class:`~repro.configs.base.ModelConfig`;
``get_smoke_config(name)`` returns the reduced CPU-runnable variant.
"""

from __future__ import annotations

from repro.configs.base import (
    SHAPES,
    ModelConfig,
    MoEConfig,
    ShapeSpec,
    SSMConfig,
    mfu_flops,
    reduce_config,
)

from repro.configs import (  # noqa: E402
    chameleon_34b,
    command_r_plus_104b,
    gemma3_4b,
    hubert_xlarge,
    internlm2_20b,
    llama70b,
    mamba2_2p7b,
    olmoe_1b_7b,
    phi3p5_moe_42b,
    qwen2p5_14b,
    qwen32b,
    qwen7b,
    zamba2_7b,
)

# Assigned architecture pool (graded): 10 archs x their shape suites.
ASSIGNED_ARCHS: dict[str, ModelConfig] = {
    "mamba2-2.7b": mamba2_2p7b.CONFIG,
    "olmoe-1b-7b": olmoe_1b_7b.CONFIG,
    "phi3.5-moe-42b-a6.6b": phi3p5_moe_42b.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
    "gemma3-4b": gemma3_4b.CONFIG,
    "command-r-plus-104b": command_r_plus_104b.CONFIG,
    "qwen2.5-14b": qwen2p5_14b.CONFIG,
    "internlm2-20b": internlm2_20b.CONFIG,
    "hubert-xlarge": hubert_xlarge.CONFIG,
    "zamba2-7b": zamba2_7b.CONFIG,
}

# The paper's own serving models (used by the HyperFlexis benchmarks).
PAPER_MODELS: dict[str, ModelConfig] = {
    "qwen7b": qwen7b.CONFIG,
    "qwen32b": qwen32b.CONFIG,
    "llama70b": llama70b.CONFIG,
}

REGISTRY: dict[str, ModelConfig] = {**ASSIGNED_ARCHS, **PAPER_MODELS}


def get_config(name: str) -> ModelConfig:
    try:
        return REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown architecture {name!r}; known: {sorted(REGISTRY)}"
        ) from None


def get_smoke_config(name: str) -> ModelConfig:
    return reduce_config(get_config(name))


__all__ = [
    "ASSIGNED_ARCHS",
    "PAPER_MODELS",
    "REGISTRY",
    "SHAPES",
    "ModelConfig",
    "MoEConfig",
    "SSMConfig",
    "ShapeSpec",
    "get_config",
    "get_smoke_config",
    "mfu_flops",
    "reduce_config",
]
