"""hubert-xlarge — encoder-only audio transformer (w2v2 backbone).

[arXiv:2106.07447; unverified] 48L d_model=1280 16H (kv=16) d_ff=5120
vocab=504 (masked-prediction codebook targets).

The audio conv frontend is a STUB per the assignment: ``input_specs()``
provides precomputed frame embeddings of shape (batch, frames, d_model);
the model is the bidirectional transformer encoder + codebook head.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    d_ff=5120,
    vocab_size=504,
    causal=False,
    frontend="frames",
    source="arXiv:2106.07447",
)
