"""olmoe-1b-7b — 64 experts, top-8 MoE.

[arXiv:2409.02060; hf] 16L d_model=2048 16H (kv=16) expert_d_ff=1024
vocab=50304, MoE 64e top-8.
"""

from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b",
    family="moe",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1024,
    vocab_size=50304,
    moe=MoEConfig(num_experts=64, top_k=8, expert_d_ff=1024),
    source="arXiv:2409.02060",
)
