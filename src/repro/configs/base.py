"""Model / shape / mesh configuration for the HyperFlexis reproduction.

Every assigned architecture is expressed as a :class:`ModelConfig`.  The
config captures the exact published hyper-parameters plus the *layer
pattern* used by the generic model builders in ``repro.models``:

- ``dense``        — standard pre-norm GQA transformer block
- ``moe``          — GQA attention + top-k mixture-of-experts FFN
- ``mamba``        — Mamba-2 SSD block (attention free)
- ``local``        — sliding-window (local) GQA attention block
- ``global``       — full (global) GQA attention block
- ``shared_attn``  — a *weight-shared* attention block (Zamba-2 style)
- ``encoder``      — bidirectional (non-causal) attention block

A model is a sequence of *segments* ``(kind, count)``; homogeneous
segments are stacked and executed with ``jax.lax.scan`` so the lowered
HLO stays compact even for 64+ layer models.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Shapes (assigned input-shape suite, identical for every LM architecture)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One (seq_len, global_batch) evaluation cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    expert_d_ff: int
    capacity_factor: float = 1.25
    # 0 = global sort-based dispatch; >0 = dispatch within token groups
    # (groups aligned to the data shards), which removes the global
    # argsort/gather collectives at the cost of per-group capacity
    # imbalance — the classic grouped-MoE trade.
    dispatch_groups: int = 0


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    """Mamba-2 (SSD) block hyper-parameters."""

    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    n_groups: int = 1
    chunk_size: int = 256

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    causal: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    # Sliding-window attention (gemma3-style local:global interleave).
    window: int = 0  # 0 -> no local attention
    local_global_ratio: int = 0  # e.g. 5 -> 5 local : 1 global
    # MoE / SSM extensions.
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # Hybrid (zamba2): one weight-shared attention block invoked every
    # ``shared_attn_period`` SSM layers.
    shared_attn_period: int = 0
    # Modality frontend stub: "token" (LM), "frames" (audio encoder
    # consumes precomputed frame embeddings), "token+vq" (chameleon:
    # early-fusion VQ image tokens share the text vocab).
    frontend: str = "token"
    source: str = ""
    # Direct layer-pattern override (dry-run block-cost measurement
    # builds 0- and 1-layer variants of the same config).
    pattern_override: Optional[Tuple[Tuple[str, int], ...]] = None

    # -- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_heads if self.n_heads else 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_encoder_only(self) -> bool:
        return not self.causal

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports very-long-context decode (long_500k).

        SSM and hybrid architectures keep O(1) state per token; gemma3's
        5:1 local:global pattern bounds the quadratic portion to 1/6 of
        layers, so we run it too.  Pure full-attention archs are skipped
        (documented in DESIGN.md §Arch-applicability).
        """
        return self.family in ("ssm", "hybrid") or self.local_global_ratio > 0

    def layer_pattern(self) -> Tuple[Tuple[str, int], ...]:
        """Return the segment list ``((kind, count), ...)``."""
        if self.pattern_override is not None:
            return self.pattern_override
        if self.family == "ssm":
            return (("mamba", self.n_layers),)
        if self.family == "hybrid":
            # zamba2: groups of `shared_attn_period` mamba layers followed by
            # one shared attention invocation; remainder mamba layers at the
            # end so the *total* (mamba + shared invocations) == n_layers.
            p = self.shared_attn_period
            n_groups = self.n_layers // (p + 1)
            tail = self.n_layers - n_groups * (p + 1)
            segs: list[Tuple[str, int]] = []
            for _ in range(n_groups):
                segs.append(("mamba", p))
                segs.append(("shared_attn", 1))
            if tail:
                segs.append(("mamba", tail))
            return tuple(segs)
        if self.local_global_ratio > 0:
            r = self.local_global_ratio
            n_groups = self.n_layers // (r + 1)
            tail = self.n_layers - n_groups * (r + 1)
            segs = []
            for _ in range(n_groups):
                segs.append(("local", r))
                segs.append(("global", 1))
            if tail:
                segs.append(("local", tail))
            return tuple(segs)
        if self.moe is not None:
            return (("moe", self.n_layers),)
        if self.is_encoder_only:
            return (("encoder", self.n_layers),)
        return (("dense", self.n_layers),)

    # -- parameter count (exact, from shapes) ------------------------------
    def param_count(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q_dim = self.n_heads * hd
        kv_dim = self.n_kv_heads * hd
        attn = d * q_dim + 2 * d * kv_dim + q_dim * d  # wq, wk, wv, wo
        if self.qkv_bias:
            attn += q_dim + 2 * kv_dim
        if self.moe is not None:
            m = self.moe
            ffn = d * m.num_experts + m.num_experts * 3 * d * m.expert_d_ff
        else:
            ffn = 3 * d * self.d_ff  # swiglu: w_gate, w_up, w_down
        norms = 2 * d
        per_attn_layer = attn + ffn + norms

        if self.family == "ssm":
            per_layer = self._mamba_params() + d
            body = self.n_layers * per_layer
        elif self.family == "hybrid":
            pattern = self.layer_pattern()
            n_mamba = sum(c for k, c in pattern if k == "mamba")
            body = n_mamba * (self._mamba_params() + d) + per_attn_layer
        else:
            body = self.n_layers * per_attn_layer

        embed = self.vocab_size * d
        head = 0 if self.tie_embeddings else self.vocab_size * d
        final_norm = d
        return body + embed + head + final_norm

    def _mamba_params(self) -> int:
        assert self.ssm is not None
        s, d = self.ssm, self.d_model
        di, n, h = s.d_inner(d), s.d_state, s.n_heads(d)
        conv_ch = di + 2 * s.n_groups * n
        return (
            d * di  # z (gate) proj
            + d * di  # x proj
            + 2 * d * s.n_groups * n  # B, C proj
            + d * h  # dt proj
            + conv_ch * s.conv_width  # depthwise conv
            + 3 * h  # A_log, D, dt_bias
            + di  # gated rmsnorm
            + di * d  # out proj
        )

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k instead of all experts)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        d = self.d_model
        full_ffn = self.n_layers * m.num_experts * 3 * d * m.expert_d_ff
        act_ffn = self.n_layers * m.top_k * 3 * d * m.expert_d_ff
        return self.param_count() - full_ffn + act_ffn

    def shapes(self) -> Sequence[ShapeSpec]:
        """The shape cells that apply to this architecture (with skips)."""
        out = [SHAPES["train_4k"], SHAPES["prefill_32k"]]
        if not self.is_encoder_only:
            out.append(SHAPES["decode_32k"])
            if self.sub_quadratic:
                out.append(SHAPES["long_500k"])
        return out

    def skipped_shapes(self) -> Sequence[Tuple[str, str]]:
        out = []
        if self.is_encoder_only:
            out.append(("decode_32k", "encoder-only: no decode step"))
            out.append(("long_500k", "encoder-only: no decode step"))
        elif not self.sub_quadratic:
            out.append(
                ("long_500k", "pure full-attention arch: 500k decode skipped")
            )
        return out


# ---------------------------------------------------------------------------
# Reduced ("smoke") configs — same family, tiny sizes, runnable on CPU.
# ---------------------------------------------------------------------------


def reduce_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to a CPU-runnable smoke variant of the same family."""
    moe = None
    if cfg.moe is not None:
        moe = MoEConfig(
            num_experts=min(cfg.moe.num_experts, 8),
            top_k=min(cfg.moe.top_k, 2),
            expert_d_ff=64,
            capacity_factor=cfg.moe.capacity_factor,
        )
    ssm = None
    if cfg.ssm is not None:
        ssm = SSMConfig(
            d_state=16, head_dim=8, expand=2, conv_width=cfg.ssm.conv_width,
            n_groups=1, chunk_size=16,
        )
    n_heads = min(cfg.n_heads, 4)
    n_kv = min(cfg.n_kv_heads, n_heads)
    if cfg.n_kv_heads == cfg.n_heads:
        n_kv = n_heads  # keep MHA archs MHA
    # keep the local:global / shared-attn structure but fewer layers
    if cfg.family == "hybrid":
        n_layers, period = 7, 2  # 2 groups of (2 mamba + 1 shared) + 1 tail
    elif cfg.local_global_ratio > 0:
        n_layers = (cfg.local_global_ratio + 1) + 1  # one group + tail
    else:
        n_layers = 2
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers,
        d_model=64,
        n_heads=n_heads,
        n_kv_heads=n_kv,
        head_dim=16 if cfg.head_dim else 0,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=256,
        window=8 if cfg.window else 0,
        moe=moe,
        ssm=ssm,
        shared_attn_period=2 if cfg.family == "hybrid" else 0,
    )


def mfu_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS for the roofline 'useful flops' ratio.

    train:   6 * N_active * tokens  (+3x fwd attention flops)
    prefill: 2 * N_active * tokens  (+1x fwd attention flops)
    decode:  2 * N_active * batch   (one token per sequence)
    """
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        base = 6.0 * n_active * shape.tokens
        attn_mult = 3.0
    elif shape.kind == "prefill":
        base = 2.0 * n_active * shape.tokens
        attn_mult = 1.0
    else:
        base = 2.0 * n_active * shape.global_batch
        attn_mult = 1.0

    # attention flops (QK^T + PV), causal halving for causal archs
    attn = 0.0
    hd = cfg.resolved_head_dim
    for kind, count in cfg.layer_pattern():
        if kind in ("dense", "moe", "global", "encoder", "shared_attn"):
            s_eff = shape.seq_len
        elif kind == "local":
            s_eff = min(cfg.window, shape.seq_len)
        else:  # mamba: linear state update, counted via param flops + state
            continue
        if shape.kind == "decode":
            # one query token attends to the full cache
            flops = 4.0 * shape.global_batch * shape.seq_len * cfg.n_heads * hd
        else:
            causal = 0.5 if cfg.causal else 1.0
            flops = (
                4.0 * shape.global_batch * shape.seq_len * s_eff
                * cfg.n_heads * hd * causal
            )
        attn += count * flops
    return base + attn_mult * attn
