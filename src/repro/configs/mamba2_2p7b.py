"""mamba2-2.7b — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 64L d_model=2560 vocab=50280 ssm_state=128.
"""

from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4,
                  n_groups=1, chunk_size=256),
    source="arXiv:2405.21060",
)
