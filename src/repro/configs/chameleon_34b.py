"""chameleon-34b — early-fusion VLM, VQ image tokens in a shared vocab.

[arXiv:2405.09818; unverified] 48L d_model=8192 64H (GQA kv=8)
d_ff=22016 vocab=65536.

The modality frontend is a STUB per the assignment: image patches are
pre-quantized to VQ token ids living in the same 65,536-entry vocabulary,
so ``input_specs()`` provides ordinary int32 token streams (mixed
text + image-token spans) and the backbone is a standard causal LM.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab_size=65536,
    frontend="token+vq",
    source="arXiv:2405.09818",
)
