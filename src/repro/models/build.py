"""Generic segment-based model builder.

A model is a sequence of *segments*; each uniform segment stacks its
layers' params with a leading layer dim and executes with ``lax.scan``
(compact HLO even for 81-layer models).  Periodic patterns (gemma3's
5-local:1-global, zamba2's 5-mamba:1-shared-attn) collapse into a
``group`` segment — an outer scan over groups whose body runs the inner
segments (the weight-shared attention block's params are closed over as
scan constants, which is exactly weight sharing).

Block kinds: dense / moe / mamba / encoder / local / global /
shared_attn.  One code path serves all ten assigned architectures.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import mamba2, moe
from repro.models.common import (
    cross_entropy,
    embed,
    init_dense,
    rms_norm,
    split_keys,
    swiglu,
    unembed,
)

ATTN_KINDS = ("dense", "moe", "encoder", "local", "global", "shared_attn")


@dataclasses.dataclass(frozen=True)
class SegSpec:
    kind: str  # block kind, or "group"
    count: int
    inner: Optional[tuple] = None  # for groups: ((kind, count), ...)


def build_segments(cfg: ModelConfig) -> list[SegSpec]:
    pattern = list(cfg.layer_pattern())
    if len(pattern) >= 4 and pattern[0][0] != pattern[1][0]:
        pair = (pattern[0], pattern[1])
        n_rep = 0
        while (
            2 * n_rep + 1 < len(pattern)
            and (pattern[2 * n_rep], pattern[2 * n_rep + 1]) == pair
        ):
            n_rep += 1
        if n_rep >= 2:
            segs = [SegSpec("group", n_rep, inner=pair)]
            segs += [SegSpec(k, c) for k, c in pattern[2 * n_rep:]]
            return segs
    return [SegSpec(k, c) for k, c in pattern]


# ---------------------------------------------------------------------------
# Per-block params
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, key, kind: str, dtype):
    if kind == "mamba":
        k1, = split_keys(key, 1)
        return {
            "mamba": mamba2.init_mamba(cfg, k1, dtype),
            "ln": jnp.zeros((cfg.d_model,), dtype),
        }
    k1, k2, k3, k4, k5 = split_keys(key, 5)
    p = {
        "attn": attn.init_attn(cfg, k1, dtype),
        "ln1": jnp.zeros((cfg.d_model,), dtype),
        "ln2": jnp.zeros((cfg.d_model,), dtype),
    }
    if kind == "moe":
        p["moe"] = moe.init_moe(cfg, k2, dtype)
    else:
        d, f = cfg.d_model, cfg.d_ff
        p["ffn"] = {
            "w_gate": init_dense(k3, (d, f), dtype=dtype),
            "w_up": init_dense(k4, (d, f), dtype=dtype),
            "w_down": init_dense(k5, (f, d), dtype=dtype),
        }
    return p


def _block_axes(cfg: ModelConfig, kind: str, n_lead: int):
    """Logical-axis tree matching _init_block's param tree."""
    lead = ("layers",) * n_lead
    if kind == "mamba":
        return {
            "mamba": {
                k: lead + tuple(v)
                for k, v in mamba2.MAMBA_PARAM_AXES.items()
            },
            "ln": lead + (None,),
        }
    out = {
        "attn": {
            k: lead + tuple(v)
            for k, v in attn.ATTN_PARAM_AXES.items()
            if cfg.qkv_bias or not k.startswith("b")
        },
        "ln1": lead + (None,),
        "ln2": lead + (None,),
    }
    if kind == "moe":
        out["moe"] = {
            k: lead + tuple(v) for k, v in moe.MOE_PARAM_AXES.items()
        }
    else:
        out["ffn"] = {
            "w_gate": lead + ("fsdp", "ff"),
            "w_up": lead + ("fsdp", "ff"),
            "w_down": lead + ("ff", "fsdp"),
        }
    return out


def _stack_init(cfg, key, kind, dtype, lead: tuple[int, ...]):
    """Init `prod(lead)` blocks and reshape leading dims to `lead`."""
    n = 1
    for x in lead:
        n *= x
    keys = jnp.stack(split_keys(key, n))
    flat = jax.vmap(lambda k: _init_block(cfg, k, kind, dtype))(keys)
    if len(lead) == 1:
        return flat
    return jax.tree.map(
        lambda a: a.reshape(lead + a.shape[1:]), flat
    )


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.float32
    kv_repeat: int = 1
    remat: bool = False
    q_chunk: int = 512
    # Route attention/SSD hot-spots through the Pallas kernels
    # (interpret-mode on CPU).  Requires kernel-aligned shapes:
    # S % block == 0 and no right-padding (full lens).
    use_kernels: bool = False
    # Pad the embedding/vocab dim (Megatron-style) so it shards over the
    # model axis; labels never index the pad ids.
    vocab_pad: int = 0
    # Unroll layer stacks instead of lax.scan.  Scan keeps HLO compact
    # for real runs; the dry-run unrolls so cost_analysis() and the
    # collective-bytes parse see every layer (XLA's cost model counts a
    # loop body once, not trip_count times).
    unroll: bool = False

    def __post_init__(self):
        self.segments = build_segments(self.cfg)
        self.has_shared = any(
            s.kind == "shared_attn"
            or (s.inner and any(k == "shared_attn" for k, _ in s.inner))
            for s in self.segments
        )

    # -- init ---------------------------------------------------------------
    def init(self, key) -> dict:
        cfg, dt = self.cfg, self.param_dtype
        keys = split_keys(key, len(self.segments) + 3)
        v = cfg.vocab_size + self.vocab_pad
        params: dict[str, Any] = {
            "embed": init_dense(keys[0], (v, cfg.d_model), dtype=dt),
            "final_norm": jnp.zeros((cfg.d_model,), dt),
        }
        if not cfg.tie_embeddings:
            params["head"] = init_dense(keys[1], (v, cfg.d_model), dtype=dt)
        if self.has_shared:
            params["shared"] = _init_block(cfg, keys[2], "dense", dt)
        seg_params = []
        for spec, k in zip(self.segments, keys[3:]):
            if spec.kind == "group":
                sub = {}
                sks = split_keys(k, len(spec.inner))
                for (ikind, icount), sk in zip(spec.inner, sks):
                    if ikind == "shared_attn":
                        continue
                    sub[ikind] = _stack_init(
                        cfg, sk, ikind, dt, (spec.count, icount)
                    )
                seg_params.append(sub)
            elif spec.kind == "shared_attn":
                seg_params.append({})
            else:
                seg_params.append(
                    _stack_init(cfg, k, spec.kind, dt, (spec.count,))
                )
        params["segments"] = seg_params
        return params

    def param_axes(self) -> dict:
        cfg = self.cfg
        axes: dict[str, Any] = {
            "embed": ("vocab", "fsdp"),
            "final_norm": (None,),
        }
        if not cfg.tie_embeddings:
            axes["head"] = ("vocab", "fsdp")
        if self.has_shared:
            axes["shared"] = _block_axes(cfg, "dense", 0)
        seg_axes = []
        for spec in self.segments:
            if spec.kind == "group":
                seg_axes.append({
                    ikind: _block_axes(cfg, ikind, 2)
                    for ikind, _ in spec.inner
                    if ikind != "shared_attn"
                })
            elif spec.kind == "shared_attn":
                seg_axes.append({})
            else:
                seg_axes.append(_block_axes(cfg, spec.kind, 1))
        axes["segments"] = seg_axes
        return axes

    def abstract_params(self) -> dict:
        return jax.eval_shape(self.init, jax.random.key(0))

    # -- block bodies ---------------------------------------------------------
    def _attn_block(self, bp, x, kind, *, positions, lens, cache,
                    make_cache, cache_len, decode, chunked=False,
                    page_table=None):
        cfg = self.cfg
        window = cfg.window if kind == "local" else 0
        causal = cfg.causal
        use_rope = cfg.frontend != "frames"
        h = rms_norm(x, bp["ln1"], cfg.norm_eps)
        q, k, v = attn.project_qkv(
            bp["attn"], h, cfg, positions=positions,
            kv_repeat=self.kv_repeat, use_rope=use_rope,
        )
        new_cache = None
        if chunked:
            # paged plane: write the chunk's K/V into the page pool,
            # then attend causally over [0, start + chunk_len)
            b, s = x.shape[:2]
            valid = jnp.arange(s)[None, :] < lens[:, None]
            kp, vp = attn.update_paged_cache(
                cache["k_pages"], cache["v_pages"], page_table, k, v,
                positions, valid,
            )
            kv_len = positions[:, 0] + lens
            if self.use_kernels and s == 1:
                from repro.kernels import ops
                # GQA handled inside the kernel's index map — the page
                # pool stays at Hkv heads, never replicated
                ctx = ops.paged_decode_attention(
                    q[:, :, 0, :], kp, vp, page_table, kv_len,
                )[:, :, None, :]
            else:
                ctx = attn.paged_chunk_attention(
                    q, kp, vp, page_table, q_pos=positions,
                    kv_len=kv_len, causal=causal,
                )
            new_cache = {"k_pages": kp, "v_pages": vp}
        elif decode:
            kc, vc, kv_pos = attn.update_cache(
                cache["k"], cache["v"], cache["pos"], k, v, positions[:, 0],
                window=window,
            )
            if self.use_kernels and window == 0:
                from repro.kernels import ops
                g = q.shape[1] // kc.shape[1]
                ctx = ops.decode_attention(
                    q[:, :, 0, :],
                    jnp.repeat(kc, g, axis=1),
                    jnp.repeat(vc, g, axis=1),
                    lens,
                )[:, :, None, :]
            else:
                ctx = attn.decode_attention(
                    q, kc, vc, q_pos=positions[:, 0], kv_pos=kv_pos,
                    kv_len=lens, causal=causal, window=window,
                )
            new_cache = {"k": kc, "v": vc, "pos": kv_pos}
        else:
            if self.use_kernels:
                from repro.kernels import ops
                g = q.shape[1] // k.shape[1]
                ctx = ops.flash_attention(
                    q, jnp.repeat(k, g, axis=1), jnp.repeat(v, g, axis=1),
                    causal=causal, window=window,
                )
            else:
                ctx = attn.chunked_attention(
                    q, k, v, lens=lens, causal=causal, window=window,
                    q_chunk=self.q_chunk, unroll=self.unroll,
                )
            if make_cache:
                new_cache = self._build_cache(k, v, lens, window, cache_len)
        b, s = x.shape[:2]
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b, s, -1)
        o = ctx @ bp["attn"]["wo"].astype(x.dtype)
        x = x + o
        h = rms_norm(x, bp["ln2"], cfg.norm_eps)
        aux = jnp.zeros((), jnp.float32)
        if kind == "moe":
            y, moe_aux = moe.moe_ffn(bp["moe"], h, cfg)
            aux = moe_aux["lb_loss"]
        else:
            f = bp["ffn"]
            y = swiglu(h, f["w_gate"].astype(x.dtype),
                       f["w_up"].astype(x.dtype),
                       f["w_down"].astype(x.dtype))
        y = constrain(y, "batch", "seq", "embed")
        out = constrain(x + y, "batch", "seq", "residual")
        return out, new_cache, aux

    def _build_cache(self, k, v, lens, window, cache_len):
        if window > 0:
            kc, vc, pos = attn.build_local_cache(k, v, lens, window)
            return {"k": kc, "v": vc, "pos": pos}
        b, h, s, hd = k.shape
        pos = jnp.where(
            jnp.arange(s)[None, :] < lens[:, None],
            jnp.arange(s)[None, :], -1
        )
        pos = jnp.broadcast_to(pos, (b, s))
        if cache_len > s:
            padw = cache_len - s
            k = jnp.pad(k, ((0, 0), (0, 0), (0, padw), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, padw), (0, 0)))
            pos = jnp.pad(pos, ((0, 0), (0, padw)), constant_values=-1)
        return {"k": k, "v": v, "pos": pos}

    def _mamba_block(self, bp, x, *, cache, make_cache, decode,
                     lens=None):
        cfg = self.cfg
        h = rms_norm(x, bp["ln"], cfg.norm_eps)
        conv_state = cache["conv"] if cache is not None else None
        ssm_state = cache["ssm"] if cache is not None else None
        y, (new_conv, new_ssm) = mamba2.mamba_block(
            bp["mamba"], h, cfg, conv_state=conv_state, ssm_state=ssm_state,
            decode=decode, use_kernels=self.use_kernels,
            unroll=self.unroll, lens=lens if make_cache else None,
        )
        new_cache = None
        if make_cache or decode:
            new_cache = {"conv": new_conv, "ssm": new_ssm}
        out = constrain(x + y, "batch", "seq", "residual")
        return out, new_cache, jnp.zeros((), jnp.float32)

    def _block(self, kind, bp, shared, x, *, positions, lens, cache,
               make_cache, cache_len, decode, chunked=False,
               page_table=None):
        if kind == "mamba":
            return self._mamba_block(
                bp, x, cache=cache, make_cache=make_cache, decode=decode,
                lens=lens,
            )
        if kind == "shared_attn":
            bp = shared
            kind = "dense"
        return self._attn_block(
            bp, x, kind, positions=positions, lens=lens, cache=cache,
            make_cache=make_cache, cache_len=cache_len, decode=decode,
            chunked=chunked, page_table=page_table,
        )

    # -- segment runners ------------------------------------------------------
    def _run_uniform(self, spec, seg_params, shared, x, *, positions, lens,
                     cache, make_cache, cache_len, decode, chunked=False,
                     page_table=None):
        if spec.kind == "shared_attn":
            x, new_cache, aux = self._block(
                "shared_attn", None, shared, x, positions=positions,
                lens=lens, cache=cache, make_cache=make_cache,
                cache_len=cache_len, decode=decode, chunked=chunked,
                page_table=page_table,
            )
            return x, new_cache, aux

        def layer(carry, xs):
            bp = xs[0]
            c = xs[1] if len(xs) > 1 else None
            y, new_c, aux = self._block(
                spec.kind, bp, shared, carry, positions=positions, lens=lens,
                cache=c, make_cache=make_cache, cache_len=cache_len,
                decode=decode, chunked=chunked, page_table=page_table,
            )
            outs = (aux,) if new_c is None else (aux, new_c)
            return y, outs

        if self.remat:
            layer = jax.checkpoint(
                layer, policy=jax.checkpoint_policies.nothing_saveable
            )
        xs = (seg_params,) if cache is None else (seg_params, cache)
        if self.unroll:
            outs_list = []
            for i in range(spec.count):
                xs_i = jax.tree.map(lambda a: a[i], xs)
                x, outs_i = layer(x, xs_i)
                outs_list.append(outs_i)
            aux = jnp.sum(jnp.stack([o[0] for o in outs_list]))
            if len(outs_list[0]) > 1:
                new_cache = jax.tree.map(
                    lambda *leaves: jnp.stack(leaves),
                    *[o[1] for o in outs_list],
                )
            else:
                new_cache = None
            return x, new_cache, aux
        x, outs = jax.lax.scan(layer, x, xs)
        aux = jnp.sum(outs[0])
        new_cache = outs[1] if len(outs) > 1 else None
        return x, new_cache, aux

    def _run_group(self, spec, seg_params, shared, x, *, positions, lens,
                   cache, make_cache, cache_len, decode, chunked=False,
                   page_table=None):
        inner = spec.inner

        def group_body(carry, xs):
            gp, gcache = xs
            y = carry
            auxes = []
            new_caches = {}
            for ikind, icount in inner:
                sub_spec = SegSpec(ikind, icount)
                sub_params = None if ikind == "shared_attn" else gp[ikind]
                sub_cache = None if gcache is None else gcache.get(ikind)
                y, nc, aux = self._run_uniform(
                    sub_spec, sub_params, shared, y, positions=positions,
                    lens=lens, cache=sub_cache, make_cache=make_cache,
                    cache_len=cache_len, decode=decode, chunked=chunked,
                    page_table=page_table,
                )
                auxes.append(aux)
                if nc is not None:
                    new_caches[ikind] = nc
            outs = (sum(auxes),)
            if new_caches:
                outs = outs + (new_caches,)
            return y, outs

        if self.unroll:
            outs_list = []
            for i in range(spec.count):
                gp_i = jax.tree.map(lambda a: a[i], seg_params)
                gc_i = (None if cache is None
                        else jax.tree.map(lambda a: a[i], cache))
                x, outs_i = group_body(x, (gp_i, gc_i))
                outs_list.append(outs_i)
            aux = jnp.sum(jnp.stack([o[0] for o in outs_list]))
            if len(outs_list[0]) > 1:
                new_cache = jax.tree.map(
                    lambda *leaves: jnp.stack(leaves),
                    *[o[1] for o in outs_list],
                )
            else:
                new_cache = None
            return x, new_cache, aux
        if cache is None:
            def body_nc(carry, gp):
                return group_body(carry, (gp, None))
            x, outs = jax.lax.scan(body_nc, x, seg_params)
        else:
            x, outs = jax.lax.scan(group_body, x, (seg_params, cache))
        aux = jnp.sum(outs[0])
        new_cache = outs[1] if len(outs) > 1 else None
        return x, new_cache, aux

    def _run_segments(self, params, x, *, positions, lens, caches,
                      make_cache, cache_len, decode, chunked=False,
                      page_table=None):
        shared = params.get("shared")
        new_caches = []
        aux_total = jnp.zeros((), jnp.float32)
        for i, spec in enumerate(self.segments):
            seg_p = params["segments"][i]
            seg_c = caches[i] if caches is not None else None
            runner = self._run_group if spec.kind == "group" else (
                self._run_uniform
            )
            x, nc, aux = runner(
                spec, seg_p, shared, x, positions=positions, lens=lens,
                cache=seg_c, make_cache=make_cache, cache_len=cache_len,
                decode=decode, chunked=chunked, page_table=page_table,
            )
            new_caches.append(nc)
            aux_total = aux_total + aux
        return x, new_caches, aux_total

    # -- public API -----------------------------------------------------------
    def _embed_in(self, params, batch):
        cfg = self.cfg
        if cfg.frontend == "frames":
            x = batch["frames"].astype(self.compute_dtype)
        else:
            x = embed(batch["tokens"], params["embed"], self.compute_dtype)
        return x

    def forward(self, params, batch, return_aux: bool = False):
        """Full-sequence forward -> logits (B, S, V)."""
        cfg = self.cfg
        x = self._embed_in(params, batch)
        b, s = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        lens = batch.get("lens", jnp.full((b,), s, jnp.int32))
        x, _, aux = self._run_segments(
            params, x, positions=positions, lens=lens, caches=None,
            make_cache=False, cache_len=s, decode=False,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = unembed(x, table)
        if return_aux:
            return logits, aux
        return logits

    def loss(self, params, batch):
        """Next-token (or masked-prediction) CE + MoE balance aux."""
        logits, aux = self.forward(params, batch, return_aux=True)
        labels = batch["labels"]
        mask = batch.get("mask")
        logits32 = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits32, axis=-1)
        ll = jnp.take_along_axis(logits32, labels[..., None], axis=-1)[..., 0]
        tok_loss = lse - ll
        if mask is not None:
            mask = mask.astype(jnp.float32)
            ce = jnp.sum(tok_loss * mask) / jnp.maximum(jnp.sum(mask), 1.0)
        else:
            ce = jnp.mean(tok_loss)
        return ce + 0.01 * aux

    def prefill(self, params, tokens_or_frames, lens, *,
                cache_len: Optional[int] = None):
        """Process prompts, return (last-token logits (B, V), caches)."""
        cfg = self.cfg
        if cfg.frontend == "frames":
            batch = {"frames": tokens_or_frames}
        else:
            batch = {"tokens": tokens_or_frames}
        x = self._embed_in(params, batch)
        b, s = x.shape[:2]
        cache_len = cache_len or s
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
        x, caches, _ = self._run_segments(
            params, x, positions=positions, lens=lens, caches=None,
            make_cache=not cfg.is_encoder_only, cache_len=cache_len,
            decode=False,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        idx = jnp.clip(lens - 1, 0, s - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = x_last @ table.T.astype(x_last.dtype)
        return logits, caches

    @property
    def supports_chunked(self) -> bool:
        """Chunked prefill over paged caches handles every block kind
        except sliding-window rings (bounded anyway) and encoder-only /
        frame-frontend models (never served incrementally)."""
        if self.cfg.is_encoder_only or self.cfg.frontend == "frames":
            return False
        kinds = set()
        for s in self.segments:
            if s.kind == "group":
                kinds.update(k for k, _ in s.inner)
            else:
                kinds.add(s.kind)
        return kinds <= {"dense", "moe", "mamba", "global", "shared_attn"}

    @property
    def supports_prefix_cache(self) -> bool:
        """Page-level prefix reuse is exact only when ALL per-token
        state lives in paged K/V.  Mamba blocks keep SSM/conv state
        slot-resident (see ``init_paged_cache``), so a shared page
        cannot reproduce the recurrent state the skipped prefill would
        have produced — prefix caching must refuse such models."""
        if not self.supports_chunked:
            return False
        kinds = set()
        for s in self.segments:
            if s.kind == "group":
                kinds.update(k for k, _ in s.inner)
            else:
                kinds.add(s.kind)
        return "mamba" not in kinds

    @property
    def supports_spec_decode(self) -> bool:
        """Speculative rollback is page-table truncation, which can
        only restore state that lives in paged K/V.  Mamba/hybrid
        blocks mutate slot-resident SSM/conv state sequentially with
        no per-position record to truncate back to, so speculation is
        refused for them — mirroring ``supports_prefix_cache``."""
        return self.supports_prefix_cache

    def chunk_step(self, params, caches, page_table, tokens, start,
                   chunk_lens):
        """Unified chunked-prefill / decode step over *paged* caches.

        tokens: (B, C) right-padded chunk tokens; start: (B,) absolute
        position of each row's first token; chunk_lens: (B,) valid
        counts — 0 freezes a row (writes dropped, SSM state held), so
        idle decode slots ride along in the same jitted call.
        page_table: (B, MP) int32.  Returns (logits (B, V) at each
        row's last valid token, new caches); decode is the C == 1
        special case.
        """
        cfg = self.cfg
        x = embed(tokens, params["embed"], self.compute_dtype)
        b, c = tokens.shape
        positions = start[:, None] + jnp.arange(c)[None, :]
        x, new_caches, _ = self._run_segments(
            params, x, positions=positions, lens=chunk_lens, caches=caches,
            make_cache=True, cache_len=0, decode=False, chunked=True,
            page_table=page_table,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        idx = jnp.clip(chunk_lens - 1, 0, c - 1)
        x_last = jnp.take_along_axis(x, idx[:, None, None], axis=1)[:, 0]
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = x_last @ table.T.astype(x_last.dtype)
        return logits, new_caches

    # -- fused decode blocks ---------------------------------------------------
    #
    # One jitted dispatch runs K greedy decode iterations in a
    # ``lax.scan`` whose carry holds the caches AND the generation
    # state (last token, position, alive mask, remaining-output
    # budget), so the per-token host round-trip — upload pos/token,
    # dispatch, block, download logits — is paid once per K tokens.
    # Stopping (EOS, max-len, per-request l_out) is evaluated on
    # device: a row that finishes mid-block freezes (its chunk length
    # drops to 0, so cache writes are dropped / become idempotent and
    # its later lanes are marked invalid), mirroring the host-side
    # ``InferenceEngine._is_done`` predicate exactly.

    def _decode_block_body(self, last, pos, alive, rem, eos, max_len,
                           logits):
        """Shared post-logits state transition for both block planes."""
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        step = alive.astype(jnp.int32)
        tok = jnp.where(alive, nxt, last)      # frozen rows keep state
        new_pos = pos + step
        new_rem = rem - step
        # same predicate as the per-token path applies after appending
        # a token: output cap hit, EOS emitted, or no room for another
        # token's KV within max_len
        done = (new_rem <= 0) | (tok == eos) | (new_pos + 1 >= max_len)
        new_alive = alive & ~done
        return tok, new_pos, new_alive, new_rem

    def decode_block(self, params, caches, page_table, last, pos, alive,
                     rem, eos, max_len, *, k: int):
        """K fused greedy decode iterations over *paged* caches.

        last/pos/rem: (B,) int32 device state; alive: (B,) bool (False
        rows — idle or mid-prefill slots — are frozen: zero chunk
        length drops their writes); eos: scalar int32 (-1 disables);
        max_len: scalar int32; ``k`` is static (jit per block size).
        Returns ``(tokens (B, K), valid (B, K), last, pos), caches`` —
        ``valid[b, i]`` marks lanes that really emitted a token, so a
        row stopping mid-block yields a partially-consumed block.
        """
        def body(carry, _):
            caches, last, pos, alive, rem = carry
            logits, caches = self.chunk_step(
                params, caches, page_table, last[:, None], pos,
                alive.astype(jnp.int32),
            )
            tok, new_pos, new_alive, new_rem = self._decode_block_body(
                last, pos, alive, rem, eos, max_len, logits,
            )
            return (caches, tok, new_pos, new_alive, new_rem), (tok, alive)

        init = (caches, last, pos, alive, rem)
        (caches, last, pos, alive, rem), (toks, valid) = jax.lax.scan(
            body, init, None, length=k
        )
        return (toks.T, valid.T, last, pos), caches

    def decode_block_slots(self, params, caches, last, pos, alive, rem,
                           eos, max_len, *, k: int):
        """Slot-plane (contiguous-row caches) twin of
        :meth:`decode_block`: same fused scan over ``decode_step``.

        The slot plane has no chunk-length freeze, so a finished row
        keeps re-running its *last* token at its *frozen* position —
        attention cache writes become idempotent overwrites and the
        row's lanes are marked invalid (its SSM state self-pollutes
        harmlessly: the engine clears the row at retire, exactly as the
        per-token path does).
        """
        def body(carry, _):
            caches, last, pos, alive, rem = carry
            logits, caches = self.decode_step(params, caches, last, pos)
            tok, new_pos, new_alive, new_rem = self._decode_block_body(
                last, pos, alive, rem, eos, max_len, logits,
            )
            return (caches, tok, new_pos, new_alive, new_rem), (tok, alive)

        init = (caches, last, pos, alive, rem)
        (caches, last, pos, alive, rem), (toks, valid) = jax.lax.scan(
            body, init, None, length=k
        )
        return (toks.T, valid.T, last, pos), caches

    def spec_decode_block(self, params, caches, page_table, last, pos,
                          alive, rem, eos, max_len, props, prop_lens,
                          *, k: int):
        """One propose-verify-accept speculative dispatch over *paged*
        caches: score ``last`` plus up to ``k`` drafted tokens in a
        single forward pass, then accept the longest prefix of the
        proposal that greedy decode would have produced itself.

        props: (B, K) drafted continuations; prop_lens: (B,) valid
        draft counts (0 rides along as a plain 1-token decode).  Lane
        ``i`` of the verify chunk holds the token whose KV lands at
        position ``pos + i`` and whose logits greedily pick the token
        for position ``pos + i + 1`` — so ``t[:, i]`` is exactly what
        ``i`` plain decode steps would emit, as long as every earlier
        proposal matched.  The same on-device stopping predicate as
        :meth:`decode_block` runs per lane, so EOS / l_out / max_len
        cut the accepted span exactly where per-token decode would
        stop.  Returns ``(tokens (B, K+1), valid (B, K+1), last, pos),
        caches``; ``valid`` is prefix-contiguous per row and the
        caller rolls rejected lanes' KV back by truncating the page
        table to the returned ``pos``.
        """
        cfg = self.cfg
        b = last.shape[0]
        tokens = jnp.concatenate([last[:, None], props], axis=1)
        chunk_lens = jnp.where(alive, 1 + prop_lens, 0).astype(jnp.int32)
        x = embed(tokens, params["embed"], self.compute_dtype)
        positions = pos[:, None] + jnp.arange(k + 1)[None, :]
        x, new_caches, _ = self._run_segments(
            params, x, positions=positions, lens=chunk_lens, caches=caches,
            make_cache=True, cache_len=0, decode=False, chunked=True,
            page_table=page_table,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = x @ table.T.astype(x.dtype)          # (B, K+1, V)
        t = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        idx = jnp.arange(k + 1)[None, :]
        # lane i+1 is reachable iff proposal i matched the greedy pick
        # of lane i (and was a real draft); lane 0 always is
        match = (props == t[:, :k]) & (jnp.arange(k)[None, :]
                                       < prop_lens[:, None])
        reach = jnp.concatenate(
            [jnp.ones((b, 1), bool),
             jnp.cumprod(match.astype(jnp.int32), axis=1).astype(bool)],
            axis=1,
        )
        # per-lane stopping, evaluated as if the lane's token had been
        # appended by a plain decode step (mirrors _decode_block_body)
        new_pos_i = pos[:, None] + idx + 1
        new_rem_i = rem[:, None] - (idx + 1)
        done_i = (new_rem_i <= 0) | (t == eos) | (new_pos_i + 1 >= max_len)
        stopped_before = jnp.concatenate(
            [jnp.zeros((b, 1), bool),
             jnp.cumsum(done_i.astype(jnp.int32), axis=1)[:, :-1] > 0],
            axis=1,
        )
        valid = alive[:, None] & reach & ~stopped_before
        emitted = jnp.sum(valid.astype(jnp.int32), axis=1)
        new_pos = pos + emitted
        pick = jnp.clip(emitted - 1, 0, k)
        last_tok = jnp.take_along_axis(t, pick[:, None], axis=1)[:, 0]
        new_last = jnp.where(emitted > 0, last_tok, last)
        return (t, valid, new_last, new_pos), new_caches

    def decode_step(self, params, caches, tokens, pos):
        """tokens: (B,) int32 last sampled; pos: (B,) their positions.

        Returns (logits (B, V), new caches).
        """
        cfg = self.cfg
        x = embed(tokens[:, None], params["embed"], self.compute_dtype)
        b = x.shape[0]
        positions = pos[:, None]
        lens = pos + 1
        x, new_caches, _ = self._run_segments(
            params, x, positions=positions, lens=lens, caches=caches,
            make_cache=False, cache_len=0, decode=True,
        )
        x = rms_norm(x, params["final_norm"], cfg.norm_eps)
        table = params["embed"] if cfg.tie_embeddings else params["head"]
        logits = x[:, 0] @ table.T.astype(x.dtype)
        return logits, new_caches

    # -- cache allocation (for the real serving engine & dry-run specs) -------
    def init_cache(self, batch_size: int, max_len: int):
        """Zero caches with static shapes (dtype = compute_dtype)."""
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        hkv = cfg.n_kv_heads * self.kv_repeat

        def attn_cache(n_lead, window):
            slen = min(window, max_len) if window else max_len
            shape = (batch_size, hkv, slen, hd)
            lead = tuple(n_lead)
            return {
                "k": jnp.zeros(lead + shape, self.compute_dtype),
                "v": jnp.zeros(lead + shape, self.compute_dtype),
                "pos": jnp.full(lead + (batch_size, slen), -1, jnp.int32),
            }

        mamba_cache = partial(self._mamba_cache, batch_size)

        def seg_cache(spec: SegSpec, lead=()):
            if spec.kind == "group":
                return {
                    ikind: seg_cache(
                        SegSpec(ikind, icount), lead + (spec.count,)
                    )
                    for ikind, icount in spec.inner
                }
            if spec.kind == "mamba":
                return mamba_cache(lead + (spec.count,))
            if spec.kind == "shared_attn":
                return attn_cache(lead, 0)
            window = cfg.window if spec.kind == "local" else 0
            return attn_cache(lead + (spec.count,), window)

        return [seg_cache(s) for s in self.segments]

    def _mamba_cache(self, batch_size: int, n_lead):
        cfg = self.cfg
        di, h, n, g, p, cw = mamba2.mamba_dims(cfg)
        lead = tuple(n_lead)
        return {
            "conv": {
                "x": jnp.zeros(
                    lead + (batch_size, cw - 1, di), self.compute_dtype
                ),
                "bc": jnp.zeros(
                    lead + (batch_size, cw - 1, 2 * g * n),
                    self.compute_dtype,
                ),
            },
            "ssm": jnp.zeros(
                lead + (batch_size, h, p, n), jnp.float32
            ),
        }

    def init_paged_cache(self, n_slots: int, max_len: int,
                         page_size: int, n_pages: Optional[int] = None):
        """Paged-plane caches: attention K/V live in a shared pool of
        `n_pages` fixed-size pages (indexed through the engine's page
        table); O(1)-per-sequence SSM/conv state stays slot-indexed.
        """
        assert self.supports_chunked, (
            "paged caches need chunk-capable segments (no local windows "
            "/ encoder frontends); use init_cache"
        )
        cfg = self.cfg
        hd = cfg.resolved_head_dim
        hkv = cfg.n_kv_heads * self.kv_repeat
        if n_pages is None:
            n_pages = n_slots * (-(-max_len // page_size))

        def paged_attn(n_lead):
            shape = (n_pages, hkv, page_size, hd)
            lead = tuple(n_lead)
            return {
                "k_pages": jnp.zeros(lead + shape, self.compute_dtype),
                "v_pages": jnp.zeros(lead + shape, self.compute_dtype),
            }

        def seg_cache(spec: SegSpec, lead=()):
            if spec.kind == "group":
                return {
                    ikind: seg_cache(
                        SegSpec(ikind, icount), lead + (spec.count,)
                    )
                    for ikind, icount in spec.inner
                }
            if spec.kind == "mamba":
                return self._mamba_cache(n_slots, lead + (spec.count,))
            if spec.kind == "shared_attn":
                return paged_attn(lead)
            return paged_attn(lead + (spec.count,))

        return [seg_cache(s) for s in self.segments]

    def paged_cache_axes(self):
        """Batch-axis pytree for init_paged_cache (matches cache_axes
        semantics); paged K/V pools get None — they are reclaimed by
        the page allocator, never by row surgery."""
        def seg_axes(spec: SegSpec, lead=()):
            if spec.kind == "group":
                return {
                    ikind: seg_axes(SegSpec(ikind, icount),
                                    lead + (spec.count,))
                    for ikind, icount in spec.inner
                }
            if spec.kind == "mamba":
                b = len(lead) + 1
                return {"conv": {"x": b, "bc": b}, "ssm": b}
            return {"k_pages": None, "v_pages": None}

        return [seg_axes(s) for s in self.segments]

    def cache_logical_axes(self):
        """Pytree (same structure as init_cache) of logical-axis tuples,
        for building NamedShardings of decode caches in the launcher."""
        def attn_axes(n_lead):
            lead = ("layers",) * len(n_lead)
            return {
                "k": lead + ("batch", "kv_heads", "cache_seq", None),
                "v": lead + ("batch", "kv_heads", "cache_seq", None),
                "pos": lead + ("batch", "cache_seq"),
            }

        def mamba_axes(n_lead):
            lead = ("layers",) * len(n_lead)
            return {
                "conv": {
                    "x": lead + ("batch", None, "ssm_inner"),
                    "bc": lead + ("batch", None, None),
                },
                "ssm": lead + ("batch", "ssm_heads", None, None),
            }

        def seg_axes(spec: SegSpec, lead=()):
            if spec.kind == "group":
                return {
                    ikind: seg_axes(SegSpec(ikind, icount),
                                    lead + (spec.count,))
                    for ikind, icount in spec.inner
                }
            if spec.kind == "mamba":
                return mamba_axes(lead + (spec.count,))
            if spec.kind == "shared_attn":
                return attn_axes(lead)
            return attn_axes(lead + (spec.count,))

        return [seg_axes(s) for s in self.segments]

    def cache_axes(self):
        """Pytree (same structure as init_cache) of batch-axis indices.

        Lets the serving engine insert/extract per-sequence cache rows
        without hard-coding each leaf's layout.
        """
        def attn_axes(n_lead):
            b = len(n_lead)
            return {"k": b, "v": b, "pos": b}

        def mamba_axes(n_lead):
            b = len(n_lead)
            return {"conv": {"x": b, "bc": b}, "ssm": b}

        def seg_axes(spec: SegSpec, lead=()):
            if spec.kind == "group":
                return {
                    ikind: seg_axes(SegSpec(ikind, icount),
                                    lead + (spec.count,))
                    for ikind, icount in spec.inner
                }
            if spec.kind == "mamba":
                return mamba_axes(lead + (spec.count,))
            if spec.kind == "shared_attn":
                return attn_axes(lead)
            return attn_axes(lead + (spec.count,))

        return [seg_axes(s) for s in self.segments]


def build_model(cfg: ModelConfig, **kw) -> Model:
    return Model(cfg, **kw)
