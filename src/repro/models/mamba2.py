"""Mamba-2 (SSD — state-space duality) block, chunked for memory.

Prefill/train runs the chunked SSD algorithm as a sequential
``lax.scan`` over chunks (the within-chunk quadratic term only ever
materializes one (B, H, Q, Q) decay matrix at a time — required for the
train_4k and 500k cells).  Decode is the O(1) recurrent state update.
The Pallas kernel in ``repro.kernels.ssd`` implements the same chunk
loop with VMEM-resident state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import init_dense, rms_norm, silu, split_keys


def mamba_dims(cfg: ModelConfig):
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    h = s.n_heads(cfg.d_model)
    return di, h, s.d_state, s.n_groups, s.head_dim, s.conv_width


def mamba_param_shapes(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di, h, n, g, _, cw = mamba_dims(cfg)
    return {
        "w_z": (d, di),
        "w_x": (d, di),
        "w_bc": (d, 2 * g * n),
        "w_dt": (d, h),
        "dt_bias": (h,),
        "conv_x": (cw, di),
        "conv_bc": (cw, 2 * g * n),
        "A_log": (h,),
        "D": (h,),
        "norm_scale": (di,),
        "w_out": (di, d),
    }


MAMBA_PARAM_AXES = {
    "w_z": ("fsdp", "ssm_inner"),
    "w_x": ("fsdp", "ssm_inner"),
    "w_bc": ("fsdp", None),
    "w_dt": ("fsdp", "ssm_heads"),
    "dt_bias": ("ssm_heads",),
    "conv_x": (None, "ssm_inner"),
    "conv_bc": (None, None),
    "A_log": ("ssm_heads",),
    "D": ("ssm_heads",),
    "norm_scale": ("ssm_inner",),
    "w_out": ("ssm_inner", "fsdp"),
}


def init_mamba(cfg: ModelConfig, key, dtype) -> dict:
    shapes = mamba_param_shapes(cfg)
    keys = split_keys(key, len(shapes))
    out = {}
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name == "A_log":
            out[name] = jnp.log(
                jax.random.uniform(k, shape, jnp.float32, 1.0, 16.0)
            ).astype(dtype)
        elif name == "dt_bias":
            # dt ~ softplus^-1 of U(1e-3, 1e-1)
            dt = jax.random.uniform(k, shape, jnp.float32, 1e-3, 1e-1)
            out[name] = (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
        elif name == "D":
            out[name] = jnp.ones(shape, dtype)
        elif name == "norm_scale":
            out[name] = jnp.zeros(shape, dtype)
        elif name.startswith("conv"):
            out[name] = init_dense(k, shape, dtype=dtype)
        else:
            out[name] = init_dense(k, shape, dtype=dtype)
    return out


# ---------------------------------------------------------------------------
# Causal depthwise conv
# ---------------------------------------------------------------------------


def causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """x: (B, S, C), w: (cw, C).  state: (B, cw-1, C) history or None.

    Returns (y: (B, S, C), new_state: (B, cw-1, C)).
    """
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+cw-1, C)
    y = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :] for i in range(cw)
    )
    new_state = xp[:, -(cw - 1):, :] if cw > 1 else state
    return y, new_state


# ---------------------------------------------------------------------------
# Chunked SSD scan (prefill / train)
# ---------------------------------------------------------------------------


def ssd_scan(x, dt, a, b_mat, c_mat, *, chunk: int, init_state=None,
             unroll: bool = False):
    """Chunked SSD.

    x: (B, S, H, P); dt: (B, S, H) (post-softplus); a: (H,) negative;
    b_mat/c_mat: (B, S, G, N) with H % G == 0.
    Returns (y: (B, S, H, P), final_state: (B, H, P, N)).
    """
    bsz, s, h, p = x.shape
    g, n = b_mat.shape[2], b_mat.shape[3]
    hg = h // g
    q = min(chunk, s)
    nc = -(-s // q)
    pad = nc * q - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        b_mat = jnp.pad(b_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))
        c_mat = jnp.pad(c_mat, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def to_chunks(t):
        return t.reshape((bsz, nc, q) + t.shape[2:]).swapaxes(0, 1)

    xc, dtc, bc, cc = map(to_chunks, (x, dt, b_mat, c_mat))
    # per-chunk leading axis nc for lax.scan
    da = dtc * a  # (nc, B, Q, H) negative decay exponents

    if init_state is None:
        init_state = jnp.zeros((bsz, h, p, n), jnp.float32)

    # pre-broadcast C from groups to heads so every einsum is head-indexed
    cc_h = jnp.repeat(cc, hg, axis=3)  # (nc, B, Q, H, N)

    def body(state, inp):
        xq, dtq, daq, bq, cqh = inp
        cum = jnp.cumsum(daq, axis=1)
        diff = cum[:, :, None, :] - cum[:, None, :, :]
        iq = jnp.arange(q)
        tri = (iq[:, None] >= iq[None, :])[None, :, :, None]
        l_mat = jnp.where(tri, jnp.exp(diff), 0.0)
        cb = jnp.einsum(
            "bqhn,bkhn->bhqk",
            cqh.astype(jnp.float32),
            jnp.repeat(bq, hg, axis=2).astype(jnp.float32),
        )
        m = cb * l_mat.transpose(0, 3, 1, 2) * dtq.transpose(0, 2, 1)[:, :, None, :]
        y_diag = jnp.einsum("bhqk,bkhp->bqhp", m, xq.astype(jnp.float32))
        y_off = jnp.einsum("bqhn,bhpn->bqhp", cqh.astype(jnp.float32), state)
        y_off = y_off * jnp.exp(cum)[..., None]
        decay_out = jnp.exp(cum[:, -1:, :] - cum)
        contrib = (dtq * decay_out)[..., None, None] * (
            jnp.repeat(bq, hg, axis=2)[:, :, :, None, :] * xq[..., :, None]
        ).astype(jnp.float32)
        new_state = state * jnp.exp(cum[:, -1, :])[:, :, None, None] + (
            contrib.sum(axis=1)
        )
        return new_state, (y_diag + y_off).astype(x.dtype)

    if unroll:
        state = init_state
        ys = []
        for i in range(nc):
            state, yi = body(
                state, (xc[i], dtc[i], da[i], bc[i], cc_h[i])
            )
            ys.append(yi)
        final_state, yc = state, jnp.stack(ys)
    else:
        final_state, yc = jax.lax.scan(
            body, init_state, (xc, dtc, da, bc, cc_h)
        )
    y = yc.swapaxes(0, 1).reshape(bsz, nc * q, h, p)
    if pad:
        y = y[:, :s]
    return y, final_state


def ssd_decode_step(state, x_t, dt_t, a, b_t, c_t):
    """One-token SSD update.

    state: (B, H, P, N) f32; x_t: (B, H, P); dt_t: (B, H);
    b_t/c_t: (B, G, N).  Returns (y: (B, H, P), new_state).
    """
    h = x_t.shape[1]
    g = b_t.shape[1]
    hg = h // g
    bh = jnp.repeat(b_t, hg, axis=1).astype(jnp.float32)  # (B, H, N)
    ch = jnp.repeat(c_t, hg, axis=1).astype(jnp.float32)
    da = jnp.exp(dt_t * a)  # (B, H)
    new_state = state * da[..., None, None] + (
        dt_t[..., None, None]
        * bh[:, :, None, :]
        * x_t.astype(jnp.float32)[..., None]
    )
    y = jnp.einsum("bhpn,bhn->bhp", new_state, ch)
    return y.astype(x_t.dtype), new_state


# ---------------------------------------------------------------------------
# Full block
# ---------------------------------------------------------------------------


def mamba_block(params: dict, x: jax.Array, cfg: ModelConfig, *,
                conv_state=None, ssm_state=None, decode: bool = False,
                use_kernels: bool = False, unroll: bool = False,
                lens=None):
    """x: (B, S, d) -> (y: (B, S, d), (conv_state, ssm_state)).

    `lens` (B,) marks right-padded prompts: pad positions get dt = 0 so
    the SSM state freezes at each sequence's true end, and the conv
    state is gathered from the last `conv_width-1` *valid* positions.
    """
    di, h, n, g, p, cw = mamba_dims(cfg)
    bsz, s, _ = x.shape
    dt_f = x @ params["w_dt"].astype(x.dtype)
    z = x @ params["w_z"].astype(x.dtype)
    xs = x @ params["w_x"].astype(x.dtype)
    bc = x @ params["w_bc"].astype(x.dtype)
    xs = constrain(xs, "batch", "seq", "ssm_inner")
    z = constrain(z, "batch", "seq", "ssm_inner")

    xs_raw, bc_raw = xs, bc
    xs, conv_x_state = causal_conv(
        xs, params["conv_x"].astype(x.dtype),
        None if conv_state is None else conv_state["x"],
    )
    bc, conv_bc_state = causal_conv(
        bc, params["conv_bc"].astype(x.dtype),
        None if conv_state is None else conv_state["bc"],
    )
    xs = silu(xs)
    bc = silu(bc)
    b_mat = bc[..., : g * n].reshape(bsz, s, g, n)
    c_mat = bc[..., g * n:].reshape(bsz, s, g, n)

    dt = jax.nn.softplus(
        dt_f.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B, S, H)
    if lens is not None and not decode:
        valid = (jnp.arange(s)[None, :] < lens[:, None])  # (B, S)
        dt = dt * valid[..., None]  # pad positions: no state update
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)
    xh = xs.reshape(bsz, s, h, p)

    if decode:
        assert s == 1
        y_t, new_ssm = ssd_decode_step(
            ssm_state, xh[:, 0], dt[:, 0], a, b_mat[:, 0], c_mat[:, 0]
        )
        y = y_t[:, None]
    elif use_kernels and g == 1 and ssm_state is None and (
        s % cfg.ssm.chunk_size == 0
    ):
        from repro.kernels import ops
        y, new_ssm = ops.ssd(
            xh, dt, a, b_mat[:, :, 0, :], c_mat[:, :, 0, :],
            chunk=cfg.ssm.chunk_size,
        )
    else:
        y, new_ssm = ssd_scan(
            xh, dt, a, b_mat, c_mat, chunk=cfg.ssm.chunk_size,
            init_state=ssm_state, unroll=unroll,
        )
    d_skip = params["D"].astype(jnp.float32)[None, None, :, None]
    y = (y.astype(jnp.float32)
         + d_skip * xh.astype(jnp.float32)).astype(x.dtype)
    y = y.reshape(bsz, s, di)
    y = constrain(y, "batch", "seq", "ssm_inner")
    y = rms_norm(y * silu(z), params["norm_scale"], cfg.norm_eps)
    out = y @ params["w_out"].astype(x.dtype)
    if lens is not None and not decode:
        conv_x_state = _gather_conv_state(
            xs_raw, lens, cw,
            None if conv_state is None else conv_state["x"],
        )
        conv_bc_state = _gather_conv_state(
            bc_raw, lens, cw,
            None if conv_state is None else conv_state["bc"],
        )
    new_conv = {"x": conv_x_state, "bc": conv_bc_state}
    return out, (new_conv, new_ssm)


def _gather_conv_state(raw: jax.Array, lens: jax.Array, cw: int,
                       prior=None):
    """Last (cw-1) *valid* pre-activation conv inputs per sequence.

    raw: (B, S, C) pre-conv projections; returns (B, cw-1, C).  For a
    continuation chunk (chunked prefill), `prior` is the previous conv
    state so short chunks (lens < cw-1) still see earlier tokens.
    """
    b, s, c = raw.shape
    front = (prior.astype(raw.dtype) if prior is not None
             else jnp.zeros((b, cw - 1, c), raw.dtype))
    xp = jnp.concatenate([front, raw], axis=1)
    idx = lens[:, None] + jnp.arange(cw - 1)[None, :]  # (B, cw-1)
    return jnp.take_along_axis(xp, idx[:, :, None], axis=1)
