"""Top-k mixture-of-experts FFN with sort-based token dispatch.

Dense one-hot dispatch (Mesh-TensorFlow style) materializes an
O(T * E * C) tensor — intractable at the train_4k cell (1M tokens,
64 experts).  We instead use the production (MaxText/vLLM-style)
sort-based formulation: flatten the (token, k) assignments, stable-sort
by expert id, compute the position-within-expert by subtracting each
run's start index, scatter into a fixed-capacity (E, C, d) buffer
(overflow tokens drop, like the paper's capacity-factor routers), run
the experts as one batched matmul, and gather/combine back.

Expert weights carry the 'experts' logical axis (→ model axis on the
production mesh): the scatter/gather across the data→expert sharding
boundary is exactly the all-to-all of classic expert parallelism, and is
inserted by the SPMD partitioner.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import init_dense, silu, split_keys


def moe_param_shapes(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d = cfg.d_model
    return {
        "w_router": (d, m.num_experts),
        "w_gate": (m.num_experts, d, m.expert_d_ff),
        "w_up": (m.num_experts, d, m.expert_d_ff),
        "w_down": (m.num_experts, m.expert_d_ff, d),
    }


MOE_PARAM_AXES = {
    "w_router": ("fsdp", None),
    "w_gate": ("experts", "fsdp", "ff"),
    "w_up": ("experts", "fsdp", "ff"),
    "w_down": ("experts", "ff", "fsdp"),
}


def init_moe(cfg: ModelConfig, key, dtype) -> dict:
    shapes = moe_param_shapes(cfg)
    keys = split_keys(key, len(shapes))
    return {
        name: init_dense(k, shape, dtype=dtype)
        for (name, shape), k in zip(sorted(shapes.items()), keys)
    }


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    m = cfg.moe
    c = int(n_tokens * m.top_k * m.capacity_factor / m.num_experts)
    return max(8, -(-c // 8) * 8)  # round up to a multiple of 8


def moe_ffn(params: dict, x: jax.Array, cfg: ModelConfig):
    """x: (B, S, d) -> (y: (B, S, d), aux: dict with load-balance loss)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xt = x.reshape(t, d)
    e, k = m.num_experts, m.top_k
    g = m.dispatch_groups if (m.dispatch_groups and
                              t % m.dispatch_groups == 0) else 1
    tg = t // g
    cap = capacity(cfg, tg)
    xg = constrain(xt.reshape(g, tg, d), "moe_group", None, "embed")

    router_logits = (xg @ params["w_router"].astype(xt.dtype)).astype(
        jnp.float32
    )
    probs = jax.nn.softmax(router_logits, axis=-1)  # (G, Tg, E)
    gate, sel = jax.lax.top_k(probs, k)  # (G, Tg, k)
    gate = gate / jnp.sum(gate, axis=-1, keepdims=True)

    # --- sort-based dispatch, vectorized over groups -----------------------
    # pin every routing tensor to group(=data) sharding so the SPMD
    # partitioner never reshards the sort/gather pipeline
    def pin(t):
        if g == 1:
            return t
        return constrain(t, "moe_group", None)

    flat_e = pin(sel.reshape(g, tg * k))
    order = pin(jnp.argsort(flat_e, axis=-1, stable=True))
    sorted_e = pin(jnp.take_along_axis(flat_e, order, axis=-1))
    # position within each expert's run of the sorted assignment list
    run_start = pin(jax.vmap(
        lambda a: jnp.searchsorted(a, a, side="left")
    )(sorted_e))
    pos_in_e = jnp.arange(tg * k)[None, :] - run_start
    keep = pos_in_e < cap
    # overflow assignments get an out-of-bounds position (mode="drop")
    pos_c = pin(jnp.where(keep, pos_in_e, cap))
    tok = pin(order // k)

    # (G, Tg*k, d) gather; rows are expert-sorted within each group, so
    # the dispatch stays group-local (groups align with data shards)
    src = jnp.take_along_axis(xg, tok[..., None], axis=1)
    if g == 1:
        src = constrain(src, None, "experts", "embed")
    gi = jnp.arange(g)[:, None]
    # 3-index scatter straight into the (G, E, C, d) buffer whose target
    # sharding is pinned on the zeros operand — the scatter then executes
    # sharded instead of materializing a replicated flat buffer.
    zeros4 = constrain(
        jnp.zeros((g, e, cap, d), xt.dtype),
        "moe_group", "experts", None, "embed",
    )
    h = zeros4.at[gi, sorted_e, pos_c].set(src, mode="drop")
    h = constrain(h, "moe_group", "experts", None, "embed")

    # --- expert swiglu ------------------------------------------------------
    wg = params["w_gate"].astype(h.dtype)
    wu = params["w_up"].astype(h.dtype)
    wd = params["w_down"].astype(h.dtype)
    act = silu(jnp.einsum("gecd,edf->gecf", h, wg))
    act = act * jnp.einsum("gecd,edf->gecf", h, wu)
    act = constrain(act, "moe_group", "experts", None, "ff")
    out = jnp.einsum("gecf,efd->gecd", act, wd)
    out = constrain(out, "moe_group", "experts", None, "embed")

    # --- combine ------------------------------------------------------------
    contrib = out.at[gi, sorted_e, pos_c].get(mode="fill", fill_value=0)
    gate_sorted = jnp.take_along_axis(
        gate.reshape(g, tg * k), order, axis=-1
    ).astype(xt.dtype)
    y = jnp.zeros((g, tg, d), xt.dtype).at[gi, tok].add(
        contrib * gate_sorted[..., None]
    )
    y = constrain(y, "moe_group", None, "embed")

    # --- aux: switch-style load-balance loss + stats ------------------------
    probs_f = probs.reshape(t, e)
    me = jnp.mean(probs_f, axis=0)  # mean router prob per expert
    ce = jnp.mean(
        jax.nn.one_hot(sel.reshape(t, k), e).sum(axis=1), axis=0
    )  # fraction routed
    lb_loss = e * jnp.sum(me * ce) / k
    dropped = jnp.sum(~keep) / (t * k)
    aux = {"lb_loss": lb_loss, "drop_frac": dropped}
    return y.reshape(b, s, d), aux
