"""GQA attention: chunked prefill, cached decode, local windows.

Memory discipline: prefill attention is computed in query *chunks* with
``lax.scan`` (flash-attention structure) so the (S, S) score matrix is
never materialized — required for the 32k/500k shape cells.  On TPU the
Pallas kernels in ``repro.kernels`` implement the same blocking in VMEM;
the jnp path here is the oracle and the CPU/dry-run implementation.

Sharding is expressed through logical axes (see
``repro.distributed.sharding``):

- archs whose head count divides the model axis shard heads (classic TP);
- small/odd-head archs (gemma3: 8 heads, qwen2.5: 40 heads vs a 16-way
  model axis) instead shard the *query-chunk rows* over the model axis
  (sequence-parallel attention) via the ``qblocks`` logical axis.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models.common import apply_rope, init_dense, split_keys

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_param_shapes(cfg: ModelConfig) -> dict:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    shapes = {
        "wq": (d, cfg.n_heads * hd),
        "wk": (d, cfg.n_kv_heads * hd),
        "wv": (d, cfg.n_kv_heads * hd),
        "wo": (cfg.n_heads * hd, d),
    }
    if cfg.qkv_bias:
        shapes["bq"] = (cfg.n_heads * hd,)
        shapes["bk"] = (cfg.n_kv_heads * hd,)
        shapes["bv"] = (cfg.n_kv_heads * hd,)
    return shapes


def init_attn(cfg: ModelConfig, key, dtype) -> dict:
    shapes = attn_param_shapes(cfg)
    keys = split_keys(key, len(shapes))
    out = {}
    for (name, shape), k in zip(sorted(shapes.items()), keys):
        if name.startswith("b"):
            out[name] = jnp.zeros(shape, dtype)
        else:
            out[name] = init_dense(k, shape, dtype=dtype)
    return out


# Logical axes for attention params: (embed-in, fused-heads-out).  The
# fused head dim shards over 'heads' when the arch's head count divides
# the model axis (rules decide), else falls back to fsdp only.
ATTN_PARAM_AXES = {
    "wq": ("fsdp", "heads_fused"),
    "wk": ("fsdp", "kv_fused"),
    "wv": ("fsdp", "kv_fused"),
    "wo": ("heads_fused", "fsdp"),
    "bq": ("heads_fused",),
    "bk": ("kv_fused",),
    "bv": ("kv_fused",),
}


# ---------------------------------------------------------------------------
# QKV projection
# ---------------------------------------------------------------------------


def project_qkv(params: dict, x: jax.Array, cfg: ModelConfig, *,
                positions: jax.Array, kv_repeat: int = 1,
                use_rope: bool = True):
    """x: (B, S, d) -> q (B, Hq, S, hd), k/v (B, Hkv_eff, S, hd)."""
    hd = cfg.resolved_head_dim
    b, s, _ = x.shape
    q = x @ params["wq"].astype(x.dtype)
    k = x @ params["wk"].astype(x.dtype)
    v = x @ params["wv"].astype(x.dtype)
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    if use_rope:
        q = apply_rope(q, positions[:, None, :], cfg.rope_theta)
        k = apply_rope(k, positions[:, None, :], cfg.rope_theta)
    if kv_repeat > 1:
        k = jnp.repeat(k, kv_repeat, axis=1)
        v = jnp.repeat(v, kv_repeat, axis=1)
    q = constrain(q, "batch", "heads", "seq", None)
    k = constrain(k, "batch", "kv_heads", "seq", None)
    v = constrain(v, "batch", "kv_heads", "seq", None)
    return q, k, v


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def _mask(q_pos, kv_pos, kv_len, *, causal: bool, window: int):
    """q_pos: (B, Q), kv_pos: (B, K), kv_len: (B,) -> bool (B, 1, Q, K)."""
    qp = q_pos[:, :, None]
    kp = kv_pos[:, None, :]
    valid = (kp >= 0) & (kp < kv_len[:, None, None])
    if causal:
        valid &= kp <= qp
    if window > 0:
        valid &= (qp - kp) < window
    return valid[:, None, :, :]


def _sdpa(q_blk, k, v, mask, scale):
    """q_blk: (B, Hkv, G, Qc, hd), k/v: (B, Hkv, K, hd), mask: (B,1,Qc,K)."""
    scores = jnp.einsum(
        "bhgqd,bhkd->bhgqk", q_blk, k, preferred_element_type=jnp.float32
    ) * scale
    scores = jnp.where(mask[:, :, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs.astype(v.dtype), v)
    return out


# ---------------------------------------------------------------------------
# Prefill / train attention (chunked over query blocks)
# ---------------------------------------------------------------------------


def chunked_attention(q, k, v, *, lens, causal: bool, window: int = 0,
                      q_chunk: int = 512,
                      unroll: bool = False) -> jax.Array:
    """Flash-structured attention.

    q: (B, Hq, S, hd); k, v: (B, Hkv_eff, S, hd); lens: (B,) valid lengths.
    Returns (B, Hq, S, hd).
    """
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    qc = min(q_chunk, s)
    n_chunks = -(-s // qc)
    pad = n_chunks * qc - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
    qg = q.reshape(b, hkv, g, n_chunks * qc, hd)
    kv_pos = jnp.broadcast_to(jnp.arange(s), (b, s))

    local = causal and window > 0 and window < s
    if local:
        # Only a (qc + window)-wide K band is relevant per chunk: padding
        # `window` zeros in front makes k_pad[start : start + band] cover
        # original positions [start - window, start + qc).
        band = qc + window
        end = n_chunks * qc - s  # keep the last chunk's slice in bounds
        k_pad = jnp.pad(k, ((0, 0), (0, 0), (window, end), (0, 0)))
        v_pad = jnp.pad(v, ((0, 0), (0, 0), (window, end), (0, 0)))
        pos_pad = jnp.pad(
            kv_pos, ((0, 0), (window, end)), constant_values=-1
        )

    def body(carry, idx):
        start = idx * qc
        q_blk = jax.lax.dynamic_slice_in_dim(qg, start, qc, axis=3)
        q_blk = constrain(q_blk, "batch", "kv_heads", None, "qblocks", None)
        q_pos = start + jnp.arange(qc)
        q_pos_b = jnp.broadcast_to(q_pos, (b, qc))
        if local:
            # K band covering [start - window, start + qc)
            k_blk = jax.lax.dynamic_slice_in_dim(k_pad, start, band, axis=2)
            v_blk = jax.lax.dynamic_slice_in_dim(v_pad, start, band, axis=2)
            pos_blk = jax.lax.dynamic_slice_in_dim(pos_pad, start, band, axis=1)
        else:
            k_blk, v_blk, pos_blk = k, v, kv_pos
        m = _mask(q_pos_b, pos_blk, lens, causal=causal, window=window)
        out = _sdpa(q_blk, k_blk, v_blk, m, scale)
        return carry, out.astype(q.dtype)

    if unroll:
        # python loop so HLO cost analysis sees every chunk (dry-run)
        chunks = [body(None, jnp.asarray(i))[1] for i in range(n_chunks)]
        outs = jnp.stack(chunks)
    else:
        _, outs = jax.lax.scan(body, None, jnp.arange(n_chunks))
    # outs: (n_chunks, B, Hkv, G, qc, hd) -> (B, Hq, S, hd)
    outs = outs.transpose(1, 2, 3, 0, 4, 5).reshape(b, hq, n_chunks * qc, hd)
    if pad:
        outs = outs[:, :, :s]
    return constrain(outs, "batch", "heads", "seq", None)


# ---------------------------------------------------------------------------
# Decode attention (one query token against a cache)
# ---------------------------------------------------------------------------


def decode_attention(q, k_cache, v_cache, *, q_pos, kv_pos, kv_len,
                     causal: bool = True, window: int = 0) -> jax.Array:
    """q: (B, Hq, 1, hd); caches: (B, Hkv_eff, S, hd); q_pos/kv_len: (B,).

    kv_pos: (B, S) absolute positions held in each cache slot (-1 = empty).
    """
    b, hq, _, hd = q.shape
    hkv = k_cache.shape[1]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    qg = q.reshape(b, hkv, g, 1, hd)
    m = _mask(q_pos[:, None], kv_pos, kv_len, causal=causal, window=window)
    out = _sdpa(qg, k_cache, v_cache, m, scale)
    return out.reshape(b, hq, 1, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Paged KV cache: scatter writes + chunk attention over a page pool
# ---------------------------------------------------------------------------


def update_paged_cache(k_pages, v_pages, page_table, k_new, v_new,
                       positions, valid):
    """Scatter a chunk of new K/V tokens into the page pool.

    k_pages/v_pages: (NP, H, ps, hd); page_table: (B, MP) int32;
    k_new/v_new: (B, H, C, hd); positions: (B, C) absolute token
    positions; valid: (B, C) bool — invalid rows (chunk padding, idle
    slots) are dropped by scattering out of bounds.
    """
    ps = k_pages.shape[2]
    logical = positions // ps                       # (B, C) page index
    off = positions % ps
    phys = jnp.take_along_axis(
        page_table, jnp.clip(logical, 0, page_table.shape[1] - 1), axis=1
    )
    # invalid writes -> page id NP (out of bounds, dropped by scatter)
    phys = jnp.where(valid & (phys >= 0), phys, k_pages.shape[0])
    kv = k_new.transpose(0, 2, 1, 3)                # (B, C, H, hd)
    vv = v_new.transpose(0, 2, 1, 3)
    k_pages = k_pages.at[phys, :, off, :].set(
        kv.astype(k_pages.dtype), mode="drop"
    )
    v_pages = v_pages.at[phys, :, off, :].set(
        vv.astype(v_pages.dtype), mode="drop"
    )
    return k_pages, v_pages


def paged_chunk_attention(q, k_pages, v_pages, page_table, *, q_pos,
                          kv_len, causal: bool = True) -> jax.Array:
    """Chunk of queries against a paged cache (gather path).

    q: (B, Hq, C, hd); pages: (NP, Hkv, ps, hd); page_table: (B, MP);
    q_pos: (B, C) absolute positions; kv_len: (B,) valid tokens
    (including this chunk).  Logical kv position of (page i, offset o)
    is i*ps + o, so masking is positional — stale data in reclaimed
    pages sits above q_pos and is masked by causality + kv_len.
    """
    from repro.kernels.ref import paged_gather
    b, hq, c, hd = q.shape
    hkv = k_pages.shape[1]
    g = hq // hkv
    scale = 1.0 / (hd ** 0.5)
    k = paged_gather(k_pages, page_table)
    v = paged_gather(v_pages, page_table)
    kv_pos = jnp.broadcast_to(jnp.arange(k.shape[2]), (b, k.shape[2]))
    m = _mask(q_pos, kv_pos, kv_len, causal=causal, window=0)
    out = _sdpa(q.reshape(b, hkv, g, c, hd), k, v, m, scale)
    return out.reshape(b, hq, c, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Local (sliding-window) ring cache helpers
# ---------------------------------------------------------------------------


def build_local_cache(k, v, lens, window: int):
    """Extract the last-`window` tokens into ring-buffer order.

    Slot i holds the most recent absolute position p < len with
    p % window == i (so decode writes at ``pos % window``).
    k, v: (B, H, S, hd) -> (B, H, window, hd); returns (k, v, pos (B, W)).
    """
    b, h, s, hd = k.shape
    w = window
    i = jnp.arange(w)
    last = lens[:, None] - 1  # (B, 1)
    p = last - ((last - i) % w)  # (B, W) candidate absolute positions
    valid = (p >= 0) & (p < lens[:, None]) & (p > last - w)
    p_gather = jnp.clip(p, 0, s - 1)
    kc = jnp.take_along_axis(k, p_gather[:, None, :, None], axis=2)
    vc = jnp.take_along_axis(v, p_gather[:, None, :, None], axis=2)
    pos = jnp.where(valid, p, -1)
    kc = jnp.where(valid[:, None, :, None], kc, 0)
    vc = jnp.where(valid[:, None, :, None], vc, 0)
    return kc, vc, pos


def update_cache(k_cache, v_cache, kv_pos, k_new, v_new, pos, *,
                 window: int = 0):
    """Insert one token per sequence into a (ring or linear) cache.

    k_cache/v_cache: (B, H, S, hd); k_new/v_new: (B, H, 1, hd);
    pos: (B,) absolute position of the new token.
    """
    b = k_cache.shape[0]
    slot = pos % window if window > 0 else pos
    bidx = jnp.arange(b)
    k_cache = k_cache.at[bidx, :, slot, :].set(k_new[:, :, 0, :])
    v_cache = v_cache.at[bidx, :, slot, :].set(v_new[:, :, 0, :])
    kv_pos = kv_pos.at[bidx, slot].set(pos)
    return k_cache, v_cache, kv_pos
