from repro.models.build import build_model  # noqa: F401
