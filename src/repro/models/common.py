"""Shared model building blocks (pure JAX, no framework)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def swiglu(x, w_gate, w_up, w_down):
    g = x @ w_gate
    u = x @ w_up
    return (silu(g) * u) @ w_down


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate pairs (x[..., :h], x[..., h:]) by position-dependent angles.

    x: (..., seq, head_dim); positions: broadcastable to (..., seq).
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = rope_freqs(head_dim, theta)  # (half,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


def embed(tokens: jax.Array, table: jax.Array, dtype) -> jax.Array:
    """Token embedding lookup; vocab may be sharded over 'model'."""
    out = jnp.take(table, tokens, axis=0).astype(dtype)
    return constrain(out, "batch", "seq", "embed")


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    """Project hidden states to (sharded) vocab logits."""
    logits = x @ table.T.astype(x.dtype)
    return constrain(logits, "batch", "seq", "vocab")


def cross_entropy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Token-mean cross entropy; stable over a (possibly sharded) vocab."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - ll)


def init_dense(key, shape, scale: float = 1.0, dtype=jnp.float32):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale / (fan_in ** 0.5)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def split_keys(key, n):
    return list(jax.random.split(key, n))
