"""Gradient compression for the cross-pod (DCN/slow-link) boundary.

Inside a pod, XLA's native reduce-scatter/all-reduce over ICI is fast;
*between* pods the links are the bottleneck, so the pod-axis gradient
sync quantizes to int8 with per-tensor scales and error feedback:

    q = round(g / s),  s = max|g| / 127          (per tensor, psum'd max)
    psum(q) over 'pod'  ->  int32, exact
    g_hat = q_sum * s / n_pods
    residual (g - q*s) feeds back into the next step's gradient.

The quantized psum moves 4x fewer bytes over the pod axis (visible in
the multi-pod dry-run's collective table).  Implemented with
``jax.shard_map`` manual over the 'pod' axis only — the data/model axes
stay under the SPMD partitioner (``axis_names`` manual subset).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _shard_map(f, mesh, in_specs, out_specs, axis_names):
    """Partial-manual shard_map across JAX versions.

    Newer JAX exposes ``jax.shard_map`` (kwargs ``axis_names`` /
    ``check_vma``); older releases only have
    ``jax.experimental.shard_map.shard_map`` (``auto`` / ``check_rep``).
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            axis_names=axis_names, check_vma=False,
        )
    from jax.experimental.shard_map import shard_map as _sm
    auto = frozenset(mesh.axis_names) - frozenset(axis_names)
    try:
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   auto=auto, check_rep=False)
    except TypeError:  # very old: no `auto` (fully-manual only)
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=False)


def quantize(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_residual(g: jax.Array) -> tuple[jax.Array, jax.Array,
                                             jax.Array]:
    """Returns (q, scale, residual) with residual = g - dequant(q)."""
    q, s = quantize(g)
    return q, s, g - dequantize(q, s)


def _psum_quantized(g: jax.Array, axis: str) -> jax.Array:
    """Exact-sum int8 quantized psum over `axis` with a shared scale."""
    g32 = g.astype(jnp.float32)
    # shared scale: the max |g| across the axis keeps the sum exact
    scale = jax.lax.pmax(jnp.max(jnp.abs(g32)), axis) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int32)
    qs = jax.lax.psum(q, axis)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis)
    return (qs.astype(jnp.float32) * scale / n).astype(g.dtype)


def pod_manual_value_and_grad(loss_fn, mesh, *, compress: bool = True):
    """Build a value_and_grad whose *pod-axis* gradient sync is manual
    (and optionally int8-compressed).

    Wraps the whole grad computation in a partial-manual ``shard_map``
    over 'pod': each pod differentiates on its own batch shard (data and
    model axes stay under the SPMD partitioner inside), then gradients
    cross the slow inter-pod links as int8.  The model must be run with
    sharding rules that exclude 'pod' (see
    ``baseline_rules(..., exclude_pod=True)``) so no in-graph constraint
    mentions the manual axis.
    """
    P = jax.sharding.PartitionSpec

    def per_pod(params, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if compress:
            grads = jax.tree.map(
                lambda g: _psum_quantized(g, "pod"), grads
            )
        else:
            n = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
            grads = jax.tree.map(
                lambda g: (jax.lax.psum(g.astype(jnp.float32), "pod")
                           / n).astype(g.dtype),
                grads,
            )
        loss = jax.lax.pmean(loss, "pod")
        return loss, grads

    if "pod" not in mesh.axis_names:
        return jax.value_and_grad(loss_fn)

    fn = _shard_map(
        per_pod, mesh,
        in_specs=(P(), P("pod")),      # params pod-replicated; batch split
        out_specs=(P(), P()),
        axis_names={"pod"},
    )
    return fn
