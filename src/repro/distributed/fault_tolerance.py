"""Fault-tolerant training runner.

- periodic atomic checkpoints (params + optimizer + step);
- auto-resume from the latest checkpoint (crash-safe: partial writes
  live in `.tmp_*` dirs that are never picked up);
- elastic restarts: checkpoints are topology-independent, so the next
  launch may use a different mesh/worker count;
- in-step anomaly guard (see AdamWConfig.skip_anomalous) protects the
  optimizer from straggler-corrupted steps;
- a `crash_after` hook lets tests inject failures deterministically.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax

from repro.distributed.checkpoint import load_latest, save_checkpoint
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import adamw_init
from repro.training.train_loop import TrainConfig, build_train_step


class InjectedFailure(RuntimeError):
    pass


@dataclasses.dataclass
class RunnerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep_last: int = 3
    log_every: int = 10
    crash_after: Optional[int] = None  # test hook: raise after N steps


class TrainRunner:
    def __init__(self, model, data_cfg: DataConfig,
                 train_cfg: TrainConfig = TrainConfig(),
                 runner_cfg: RunnerConfig = RunnerConfig(),
                 mesh=None, jit_kwargs: Optional[dict] = None):
        self.model = model
        self.data_cfg = data_cfg
        self.train_cfg = train_cfg
        self.cfg = runner_cfg
        step_fn = build_train_step(model, train_cfg, mesh)
        self.train_step = jax.jit(step_fn, **(jit_kwargs or {}))
        self.history: list[dict] = []

    def run(self, key) -> dict:
        params = self.model.init(key)
        opt_state = adamw_init(params)
        start = 0
        resumed = load_latest(self.cfg.ckpt_dir, (params, opt_state))
        if resumed is not None:
            start, (params, opt_state), _ = resumed
        steps_done = 0
        for step in range(start, self.cfg.total_steps):
            batch = {
                k: jax.numpy.asarray(v)
                for k, v in make_batch(
                    self.model.cfg, self.data_cfg, step
                ).items()
            }
            params, opt_state, metrics = self.train_step(
                params, opt_state, batch
            )
            steps_done += 1
            if (step + 1) % self.cfg.log_every == 0 or step == start:
                self.history.append(
                    {"step": step + 1,
                     "loss": float(metrics["loss"]),
                     "grad_norm": float(metrics["grad_norm"])}
                )
            if (step + 1) % self.cfg.ckpt_every == 0:
                save_checkpoint(
                    self.cfg.ckpt_dir, step + 1, (params, opt_state),
                    keep_last=self.cfg.keep_last,
                )
            if (self.cfg.crash_after is not None
                    and steps_done >= self.cfg.crash_after):
                raise InjectedFailure(f"injected crash at step {step + 1}")
        final_loss = float(metrics["loss"]) if steps_done else float("nan")
        return {
            "params": params,
            "opt_state": opt_state,
            "final_loss": final_loss,
            "resumed_from": start,
            "history": self.history,
        }
