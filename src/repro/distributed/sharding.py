"""Logical-axis sharding rules.

Models annotate activations/params with *logical* axis names; the
launcher installs an :class:`AxisRules` mapping logical names to physical
mesh axes.  Outside a rules context every annotation is a no-op, so the
same model code runs unsharded on CPU and fully sharded on the
production mesh.  Keeping the mapping in one place is also the main
hill-climbing knob: changing `batch/seq/ff/...` bindings re-shards the
whole system without touching model code.
"""

from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Sequence[str], None]

# Logical axes used across the codebase:
#   batch      — request/sequence batch dim
#   seq        — token position dim (activations)
#   heads      — attention query heads
#   kv_heads   — attention kv heads (post TP-replication)
#   embed      — d_model activation dim
#   ff         — MLP hidden dim
#   vocab      — vocabulary dim
#   experts    — MoE expert dim
#   layers     — stacked-layer leading dim of scanned params
#   fsdp       — the dim of each weight sharded ZeRO-style (params only)
#   ssm_heads  — mamba heads
#   ssm_inner  — mamba d_inner channel dim


class AxisRules:
    def __init__(self, mapping: dict[str, Axis], mesh: Optional[Mesh] = None):
        self.mapping = dict(mapping)
        self.mesh = mesh

    def spec(self, logical: Sequence[Optional[str]]) -> P:
        phys = []
        used: set[str] = set()
        for name in logical:
            ax = self.mapping.get(name) if name else None
            if ax is None:
                phys.append(None)
                continue
            axes = (ax,) if isinstance(ax, str) else tuple(ax)
            axes = tuple(a for a in axes if a not in used)
            used.update(axes)
            phys.append(axes if len(axes) > 1 else (axes[0] if axes else None))
        # trim trailing Nones for tidiness
        while phys and phys[-1] is None:
            phys.pop()
        return P(*phys)

    def sharding(self, logical: Sequence[Optional[str]]) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(logical))


_tls = threading.local()


def current_rules() -> Optional[AxisRules]:
    return getattr(_tls, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[AxisRules]):
    prev = getattr(_tls, "rules", None)
    _tls.rules = rules
    try:
        yield rules
    finally:
        _tls.rules = prev


def constrain(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """Apply a sharding constraint by logical axis names (no-op w/o rules).

    Inside a partial-manual ``shard_map`` (e.g. the compressed pod-axis
    gradient sync) the trace context carries an AbstractMesh whose
    manual axes differ from the rules' concrete mesh — constraints must
    then be expressed against the context mesh.
    """
    rules = current_rules()
    if rules is None or rules.mesh is None:
        return x
    spec = rules.spec(logical)
    try:
        ctx = jax.sharding.get_abstract_mesh()
    except Exception:  # pragma: no cover
        ctx = None
    if ctx is not None and ctx.axis_names:
        used = {a for part in spec for a in (
            (part,) if isinstance(part, str) else (part or ())
        )}
        if used <= set(ctx.axis_names):
            return jax.lax.with_sharding_constraint(
                x, NamedSharding(ctx, spec)
            )
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(rules.mesh, spec)
    )


# ---------------------------------------------------------------------------
# Canonical rule sets
# ---------------------------------------------------------------------------


def baseline_rules(mesh: Mesh, *, fsdp: bool = True,
                   shard_seq: bool = False,
                   exclude_pod: bool = False) -> AxisRules:
    """Paper-faithful baseline: DP over ('pod','data'), TP over 'model'.

    ``fsdp=True`` additionally shards one non-TP dim of every weight over
    the data axis (ZeRO-3 style); ``shard_seq`` moves activation sequence
    sharding onto the data axis (used when batch < data axis size, e.g.
    long_500k decode, and for sequence-parallel prefill).
    ``exclude_pod`` removes 'pod' from the data axes — required when the
    pod-axis gradient sync runs manually (compressed cross-pod DP).
    """
    names = ("data",) if exclude_pod else ("pod", "data")
    data_axes = tuple(a for a in names if a in mesh.axis_names)
    mapping: dict[str, Axis] = {
        "batch": data_axes,
        "seq": data_axes if shard_seq else None,
        "heads": "model",
        "kv_heads": "model",
        "embed": None,
        "residual": None,
        "ff": "model",
        "vocab": "model",
        "experts": "model",
        "layers": None,
        "fsdp": data_axes if fsdp else None,
        "ssm_heads": "model",
        "ssm_inner": "model",
        "heads_fused": "model",
        "kv_fused": "model",
        "qblocks": None,
        "cache_seq": None,
        "moe_group": None,
    }
    return AxisRules(mapping, mesh)


def _axis_size(mesh: Mesh, names) -> int:
    if names is None:
        return 1
    names = (names,) if isinstance(names, str) else names
    n = 1
    for a in names:
        n *= mesh.shape[a]
    return n


def plan_arch(cfg, mesh: Mesh) -> dict:
    """Divisibility-driven sharding decisions for one architecture.

    Returns {kv_repeat, heads_sharded, vocab_pad} — the knobs the
    launcher must apply consistently to the Model and the AxisRules.
    """
    m = mesh.shape["model"]
    heads_ok = cfg.n_heads > 0 and cfg.n_heads % m == 0
    kv_repeat = 1
    if heads_ok and cfg.n_kv_heads > 0:
        if cfg.n_kv_heads % m == 0:
            kv_repeat = 1
        elif m % cfg.n_kv_heads == 0:
            kv_repeat = m // cfg.n_kv_heads
            # GQA grouping must stay integral after repetition
            if cfg.n_heads % (cfg.n_kv_heads * kv_repeat) != 0:
                kv_repeat = 1
    kv_eff = max(cfg.n_kv_heads, 1) * kv_repeat
    kv_sharded = heads_ok and kv_eff % m == 0
    vocab_pad = 0
    if cfg.vocab_size % m != 0 and cfg.vocab_size > 10_000:
        vocab_pad = (-cfg.vocab_size) % m
    return {
        "heads_sharded": heads_ok,
        "kv_repeat": kv_repeat,
        "kv_sharded": kv_sharded,
        "vocab_pad": vocab_pad,
    }


def arch_rules(cfg, mesh: Mesh, *, stage: str = "train",
               fsdp: bool = True, exclude_pod: bool = False,
               shard_residual: Optional[bool] = None,
               batch_size: Optional[int] = None) -> AxisRules:
    """AxisRules specialized to one architecture + execution stage.

    stage: "train" | "prefill" | "decode" | "decode_long".
    Every mapping degrades to None when the dimension does not divide
    the target axis, so lowering always succeeds; the roofline then
    shows the replication cost (e.g. gemma3's 8 heads on a 16-way model
    axis fall back to sequence-parallel attention via 'qblocks').
    """
    plan = plan_arch(cfg, mesh)
    m = mesh.shape["model"]
    rules = baseline_rules(mesh, fsdp=fsdp, exclude_pod=exclude_pod)
    mp = rules.mapping
    data_axes = mp["batch"]

    heads = "model" if plan["heads_sharded"] else None
    mp["heads"] = heads
    mp["heads_fused"] = heads
    mp["kv_heads"] = "model" if plan["kv_sharded"] else None
    mp["kv_fused"] = ("model" if (plan["kv_sharded"]
                                  and plan["kv_repeat"] == 1) else None)
    mp["qblocks"] = None if plan["heads_sharded"] else "model"
    mp["vocab"] = ("model"
                   if (cfg.vocab_size + plan["vocab_pad"]) % m == 0
                   else None)
    mp["ff"] = "model" if (cfg.d_ff == 0 or cfg.d_ff % m == 0) else None
    if cfg.moe is not None:
        mp["experts"] = ("model" if cfg.moe.num_experts % m == 0 else None)
        mp["ff"] = ("model" if cfg.moe.expert_d_ff % m == 0 else mp["ff"])
        if cfg.moe.dispatch_groups:
            # grouped dispatch: groups shard over data AND model; expert
            # compute is fully shard-local, expert weights are gathered
            # (ZeRO-style, fsdp axis) instead of tokens being scattered
            base = mp["batch"] if isinstance(mp["batch"], tuple) else (
                (mp["batch"],) if mp["batch"] else ())
            mp["moe_group"] = tuple(base) + ("model",)
            mp["experts"] = None
            mp["ff"] = None
    if cfg.ssm is not None:
        di = cfg.ssm.d_inner(cfg.d_model)
        h = cfg.ssm.n_heads(cfg.d_model)
        mp["ssm_inner"] = "model" if di % m == 0 else None
        mp["ssm_heads"] = "model" if h % m == 0 else None
    # fsdp viability: every fsdp'd dim here is d_model or expert d_model
    if cfg.d_model % _axis_size(mesh, mp["fsdp"]) != 0:
        mp["fsdp"] = None

    if batch_size is not None and data_axes:
        if batch_size % _axis_size(mesh, data_axes) != 0:
            # drop pod first, then give up on batch sharding
            if (len(data_axes) > 1
                    and batch_size % mesh.shape[data_axes[-1]] == 0):
                mp["batch"] = (data_axes[-1],)
            else:
                mp["batch"] = None

    if shard_residual is None:
        shard_residual = stage == "train"
    mp["residual"] = ("model" if (shard_residual and cfg.d_model % m == 0)
                      else None)

    if stage == "decode_long":
        # batch=1: shard the KV/state sequence dim instead
        mp["batch"] = None
        mp["cache_seq"] = tuple(
            a for a in (("data",) if exclude_pod else ("pod", "data"))
            if a in mesh.axis_names
        ) + ("model",)
    elif stage == "decode":
        mp["cache_seq"] = "model"  # dropped per-tensor when kv uses it
        mp["residual"] = None
    return rules

