"""Sharded-aware checkpointing with atomic writes and auto-resume.

Checkpoints are topology-independent: arrays are gathered to host and
saved whole, so a restart may restore onto a different mesh / worker
count (elastic scaling across restarts).  Writes go to a temp directory
renamed atomically; `latest_step` + `load_latest` give crash-safe
resume.  A lightweight manifest (pytree paths + shapes + dtypes) guards
against silently loading a mismatched tree.
"""

from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree: Any,
                    extra: Optional[dict] = None,
                    keep_last: int = 3) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    manifest = {
        "step": step,
        "extra": extra or {},
        "arrays": {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        },
    }
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = os.path.join(ckpt_dir, f"step_{step:08d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep_last)
    return final


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(all_steps(ckpt_dir))
    for s in steps[:-keep_last]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def all_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.startswith(".tmp"):
            try:
                out.append(int(name[5:]))
            except ValueError:
                pass
    return out


def checkpoint_nbytes(ckpt_dir: str, step: int) -> float:
    """Total array bytes a restore of ``step`` would read, computed
    from the manifest alone (no arrays touched) — what a disk-path
    weight-provisioning cost model should charge."""
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    total = 0.0
    for meta in manifest["arrays"].values():
        n = 1
        for d in meta["shape"]:
            n *= d
        total += n * np.dtype(meta["dtype"]).itemsize
    return total


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = all_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, step: int, tree_like: Any,
                    shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of `tree_like` (values replaced).

    `shardings` (same-structure pytree of NamedSharding or None) places
    restored arrays directly onto the current mesh — elastic restore.
    """
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    with np.load(os.path.join(path, "arrays.npz")) as zf:
        flat = {k: zf[k] for k in zf.files}

    leaves_with_path = jax.tree_util.tree_flatten_with_path(tree_like)[0]
    treedef = jax.tree_util.tree_structure(tree_like)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(
                        leaves_with_path))
    new_leaves = []
    for (path_k, leaf), shard in zip(leaves_with_path, shard_leaves):
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = flat[key]
        want = manifest["arrays"][key]
        if list(arr.shape) != want["shape"]:
            raise ValueError(f"manifest/array mismatch for {key!r}")
        if hasattr(leaf, "shape") and tuple(leaf.shape) != arr.shape:
            raise ValueError(
                f"{key!r}: checkpoint shape {arr.shape} != "
                f"expected {tuple(leaf.shape)}"
            )
        if shard is not None:
            new_leaves.append(jax.device_put(arr, shard))
        else:
            new_leaves.append(jax.numpy.asarray(arr))
    return treedef.unflatten(new_leaves), manifest["extra"]


def load_latest(ckpt_dir: str, tree_like: Any, shardings: Any = None):
    # sweep half-written staging dirs left by a writer that died before
    # its atomic rename — they can only accumulate, never resurrect
    if os.path.isdir(ckpt_dir):
        for name in os.listdir(ckpt_dir):
            if name.startswith(".tmp_"):
                shutil.rmtree(os.path.join(ckpt_dir, name),
                              ignore_errors=True)
    step = latest_step(ckpt_dir)
    if step is None:
        return None
    tree, extra = load_checkpoint(ckpt_dir, step, tree_like, shardings)
    return step, tree, extra
