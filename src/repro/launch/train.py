"""Training driver.

Runs real steps for reduced configs on local devices (CPU-runnable
end-to-end example: ~100M-param model, a few hundred steps), and is the
same code path the dry-run lowers for the full configs on the
production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
        --smoke --steps 50 --batch 8 --seq 64
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config, get_smoke_config
from repro.distributed.fault_tolerance import RunnerConfig, TrainRunner
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig
from repro.models.build import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--micro-batches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    model = build_model(cfg, remat=True)
    n = cfg.param_count()
    print(f"arch={cfg.name} params={n/1e6:.1f}M "
          f"pattern={cfg.layer_pattern()[:4]}")

    runner = TrainRunner(
        model,
        DataConfig(batch=args.batch, seq_len=args.seq, seed=args.seed),
        TrainConfig(adamw=AdamWConfig(lr=args.lr),
                    micro_batches=args.micro_batches),
        RunnerConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                     ckpt_dir=args.ckpt_dir, log_every=10),
    )
    t0 = time.time()
    out = runner.run(jax.random.key(args.seed))
    dt = time.time() - t0
    for h in out["history"]:
        print(f"step {h['step']:5d}  loss {h['loss']:.4f}  "
              f"|g| {h['grad_norm']:.3f}")
    steps_run = args.steps - out["resumed_from"]
    print(f"done: {steps_run} steps in {dt:.1f}s "
          f"({dt / max(steps_run, 1):.3f} s/step), "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
