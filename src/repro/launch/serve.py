"""Serving driver: run the HyperFlexis cluster on a workload.

    # simulator plane (paper benchmarks)
    PYTHONPATH=src python -m repro.launch.serve --model qwen7b \
        --policy hyperflexis --qps 64 --tasks 4task --workers 2 --scaling

    # ONLINE mode: JSONL requests on stdin -> JSONL stream events on
    # stdout (admitted/rejected/first_token/token/finished + a final
    # summary row).  Request lines:
    #   {"task": "gsm8k", "prompt": [5,3,9], "l_out": 4,
    #    "ttft_slo": 5.0, "tpot_slo": 1.0, "arrival": 0.1}
    # (prompt may be replaced by "l_in" on the sim plane; omitted
    # SLOs default to the task's Table-1 class; omitted arrival means
    # "now")
    printf '%s\n' '{"task":"gsm8k","prompt":[5,3,9,2,7],"l_out":4}' | \
        PYTHONPATH=src python -m repro.launch.serve --online \
        --backend engine --model qwen7b --smoke --workers 1 \
        --engine-max-len 48 --page-size 8 --chunk-size 16

    # real-engine plane: the SAME control plane over jitted compute
    # (reduced smoke config; size --engine-max-len to your workload or
    # clip Table-1 prompt/output lengths to CPU scale)
    PYTHONPATH=src python -m repro.launch.serve --model qwen7b --smoke \
        --backend engine --qps 16 --n-per-task 4 --workers 1 \
        --engine-max-len 96 --clip-prompt 40 --clip-output 8 --json

    # engine-plane P/D disaggregation: prefill engines park completed
    # prompts, the Migrator moves REAL paged-KV payloads to decode
    # engines over TLManager-costed (measured-bytes) transfers
    PYTHONPATH=src python -m repro.launch.serve --model qwen7b --smoke \
        --backend engine --mode pd --n-prefill 1 --n-decode 1 \
        --qps 16 --n-per-task 4 --clip-prompt 24 --clip-output 6 \
        --engine-max-len 48 --page-size 8 --chunk-size 16 --json
"""

from __future__ import annotations

import argparse
import json
import sys

from repro.configs import get_config, get_smoke_config
from repro.core.faults import FaultInjector
from repro.core.request import FOUR_TASK_SET, TASKS, TWO_TASK_SET
from repro.core.scaler import ScalerConfig
from repro.core.slo_mapper import PrioritySLOMapper, bands_from_tasks
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import poisson_workload, shared_prefix_workload


def run_online(args, cfg: ClusterConfig) -> None:
    """stdin JSONL requests -> stdout JSONL stream events."""
    from repro.serving.session import ServingSession

    session = ServingSession(
        Cluster(cfg), admission=args.admission,
        clock="wall" if args.wall_clock else "virtual",
        on_event=lambda ev: print(json.dumps(ev.to_json()), flush=True),
    )

    def submit_line(line: str) -> None:
        # a malformed line must not kill the session (every other
        # client's stream dies with it): report a structured error
        # event and keep serving
        try:
            req = json.loads(line)
            if not isinstance(req, dict):
                raise ValueError("request line must be a JSON object")
            spec = TASKS.get(req.get("task", ""))
            ttft = req.get("ttft_slo", spec.ttft_slo if spec else 10.0)
            tpot = req.get("tpot_slo", spec.tpot_slo if spec else 1.0)
            arrival = req.get("arrival")
            if arrival is not None and not args.wall_clock:
                # replay: advance the virtual clock to the stamped
                # arrival so the admission verdict sees the state *at*
                # arrival
                session.run_until(float(arrival))
            session.submit(
                prompt=req.get("prompt"),
                l_in=req.get("l_in"),
                l_out=int(req.get("l_out", 1)),
                task=req.get("task", "default"),
                ttft_slo=float(ttft), tpot_slo=float(tpot),
                arrival=arrival, rid=req.get("rid"),
                priority=req.get("priority"),
            )
        except Exception as e:  # noqa: BLE001 — structured, not fatal
            print(json.dumps({
                "event": "error",
                "reason": f"{type(e).__name__}: {e}",
                "line": line[:200],
            }), flush=True)

    if args.wall_clock:
        # live mode: a client may hold the pipe open while it consumes
        # events, so never block on readline without serving — multiplex
        # stdin readiness with event processing
        import select

        eof = False
        while not eof:
            ready, _, _ = select.select([sys.stdin], [], [], 0.02)
            if ready:
                line = sys.stdin.readline()
                if not line:
                    eof = True
                elif line.strip():
                    submit_line(line.strip())
            else:
                session.poll()
    else:
        for line in sys.stdin:
            if line.strip():
                submit_line(line.strip())
    session.drain()
    res = session.close()
    print(json.dumps({
        "event": "summary",
        **res.metrics.row(),
        **session.streaming.row(),
        "backend": args.backend,
        "n_faults": res.n_faults,
        "n_recovered": res.n_recovered,
        "n_lost": res.n_lost,
        "n_transfer_retries": res.n_transfer_retries,
    }), flush=True)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", default="qwen7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced CPU-runnable model variant")
    ap.add_argument("--backend", default="sim",
                    choices=["sim", "engine"],
                    help="execution plane: event simulator or the real "
                         "JAX engine (same scheduler either way)")
    ap.add_argument("--policy", default="hyperflexis",
                    choices=["hyperflexis", "rr", "scorpio", "aladdin",
                             "sa"])
    ap.add_argument("--tasks", default="4task",
                    choices=["2task", "4task"])
    ap.add_argument("--qps", type=float, default=64.0)
    ap.add_argument("--n-per-task", type=int, default=300)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--mode", default="collocated",
                    choices=["collocated", "pd"])
    ap.add_argument("--n-prefill", type=int, default=2)
    ap.add_argument("--n-decode", type=int, default=2)
    ap.add_argument("--one-shot-pd", action="store_true")
    ap.add_argument("--scaling", action="store_true")
    ap.add_argument("--max-workers", type=int, default=4)
    ap.add_argument("--weight-strategy", default="d2d",
                    choices=["d2d", "cpu", "disk", "auto"],
                    help="scale-out weight transport (Table 2); d2d "
                         "falls back to disk with no live donor, auto "
                         "picks the cheapest by measured cost")
    ap.add_argument("--live-migration", action="store_true",
                    help="decode-to-decode live migration: rescue "
                         "predicted-TPOT-miss requests onto less-loaded "
                         "instances and evacuate scale-in / role-flip "
                         "targets instead of draining them")
    ap.add_argument("--priority-mapping", action="store_true")
    ap.add_argument("--monitor-interval", type=float, default=0.05)
    ap.add_argument("--scale-interval", type=float, default=1.0)
    # chunked prefill (sim plane): prompt tokens per prefill step;
    # the engine plane chunks natively via --chunk-size
    ap.add_argument("--chunk-tokens", type=int, default=None,
                    help="sim plane: bound prompt tokens per prefill "
                         "step (None = monolithic prefill)")
    # prefix cache (both planes): page-level KV reuse across requests
    ap.add_argument("--prefix-cache", default=False,
                    action=argparse.BooleanOptionalAction,
                    help="reuse cached KV pages across requests with "
                         "shared prefixes (engine: per-replica page "
                         "cache; sim: cluster-shared prefix index)")
    ap.add_argument("--prefix-cache-pages", type=int, default=None,
                    help="cap the prefix cache footprint in pages "
                         "(None = bounded by the page pool)")
    # shared-prefix workload (the prefix-cache stressor)
    ap.add_argument("--workload", default="poisson",
                    choices=["poisson", "shared-prefix"],
                    help="batch workload generator; shared-prefix "
                         "draws Zipfian prefix groups (chat shape)")
    ap.add_argument("--prefix-groups", type=int, default=8,
                    help="shared-prefix: number of Zipfian groups")
    ap.add_argument("--prefix-len", type=int, default=64,
                    help="shared-prefix: shared tokens per group")
    # engine-plane knobs (only read with --backend engine)
    ap.add_argument("--engine-slots", type=int, default=8)
    ap.add_argument("--engine-max-len", type=int, default=128)
    ap.add_argument("--page-size", type=int, default=16,
                    help="engine plane: KV page size (tokens)")
    ap.add_argument("--chunk-size", type=int, default=32,
                    help="engine plane: static prefill-chunk ceiling")
    ap.add_argument("--decode-block", type=int, default=8,
                    help="engine plane: max fused decode iterations "
                         "per dispatch (1 = per-token stepping)")
    # speculative decoding (both planes)
    ap.add_argument("--spec-decode", default=False,
                    action=argparse.BooleanOptionalAction,
                    help="SLO-customized speculative decoding: n-gram "
                         "drafter + one-dispatch verify on the engine "
                         "plane, acceptance-rate-scaled decode ticks "
                         "on the sim plane; per-lane depth from each "
                         "request's TPOT slack")
    ap.add_argument("--max-spec-len", type=int, default=8,
                    help="speculation depth ceiling per lane")
    ap.add_argument("--spec-accept-rate", type=float, default=0.7,
                    help="sim plane: modeled per-token acceptance "
                         "probability for speculative proposals")
    ap.add_argument("--clip-prompt", type=int, default=None,
                    help="clip workload prompt lengths (engine smoke "
                         "runs: Table-1 prompts exceed reduced caches)")
    ap.add_argument("--clip-output", type=int, default=None,
                    help="clip workload output lengths")
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", action="store_true")
    # online session mode (JSONL in/out; see module docstring)
    ap.add_argument("--online", action="store_true",
                    help="read JSONL requests from stdin, stream JSONL "
                         "events to stdout (ServingSession front door)")
    ap.add_argument("--admission", default="reject",
                    choices=["none", "reject", "degrade"],
                    help="online mode: submit-time Eq. 5 admission "
                         "policy (reject doomed requests, renegotiate "
                         "their SLO, or queue everything)")
    ap.add_argument("--wall-clock", action="store_true",
                    help="online mode: pace event processing against "
                         "real time instead of the virtual clock")
    # fault tolerance (see repro.core.faults for the spec grammar)
    ap.add_argument("--fault-schedule", default=None,
                    help="deterministic fault spec, e.g. "
                         "'crash:wid=1,t=2.0;kv_drop:p=0.5,max=3;"
                         "weight_fail:strategy=d2d,p=1.0'; seeded by "
                         "--seed so runs replay bit-for-bit")
    ap.add_argument("--recovery", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="replica-failure recovery and transfer retry "
                         "(--no-recovery is the ablation: crashes shed "
                         "their residents instead of re-queueing)")
    args = ap.parse_args()

    task_set = FOUR_TASK_SET if args.tasks == "4task" else TWO_TASK_SET
    model = (get_smoke_config(args.model) if args.smoke
             else get_config(args.model))
    mapper = None
    if args.priority_mapping:
        mapper = PrioritySLOMapper(
            bands_from_tasks([TASKS[t] for t in task_set])
        )
    engine_cfg = None
    if args.backend == "engine":
        from repro.serving.engine import EngineConfig

        engine_cfg = EngineConfig(
            n_slots=args.engine_slots, max_len=args.engine_max_len,
            page_size=args.page_size, chunk_size=args.chunk_size,
            decode_block=args.decode_block,
        )  # spec_decode is applied via the ClusterConfig override
    cfg = ClusterConfig(
        model=model,
        n_workers=args.workers,
        policy=args.policy,
        backend=args.backend,
        engine=engine_cfg,
        mode=args.mode,
        n_prefill=args.n_prefill,
        n_decode=args.n_decode,
        one_shot_pd=args.one_shot_pd,
        scaling=args.scaling,
        scaler=ScalerConfig(tau=args.scale_interval,
                            max_workers=args.max_workers,
                            weight_strategy=args.weight_strategy),
        monitor_interval=args.monitor_interval,
        chunk_tokens=args.chunk_tokens,
        prefix_cache=args.prefix_cache,
        prefix_cache_pages=args.prefix_cache_pages,
        spec_decode=args.spec_decode,
        max_spec_len=args.max_spec_len,
        spec_accept_rate=args.spec_accept_rate,
        live_migration=args.live_migration,
        tp=args.tp,
        seed=args.seed,
        slo_mapper=mapper,
        faults=(FaultInjector.from_spec(args.fault_schedule,
                                        seed=args.seed)
                if args.fault_schedule else None),
        recovery=args.recovery,
    )
    if args.online:
        run_online(args, cfg)
        return
    if args.workload == "shared-prefix":
        reqs = shared_prefix_workload(
            task=task_set[0], n=args.n_per_task * len(task_set),
            qps=args.qps, seed=args.seed, n_groups=args.prefix_groups,
            prefix_len=args.prefix_len,
            suffix_len=max(1, args.prefix_len // 2),
        )
    else:
        reqs = poisson_workload(
            task_set, qps=args.qps, n_per_task=args.n_per_task,
            seed=args.seed, use_priority=args.priority_mapping,
        )
    for r in reqs:
        if args.clip_prompt:
            r.l_in = min(r.l_in, args.clip_prompt)
        if args.clip_output:
            r.l_out = min(r.l_out, args.clip_output)
    res = Cluster(cfg).run(reqs)
    m = res.metrics
    if args.json:
        # RunMetrics.row() is the canonical schema (identical for sim
        # and engine runs, incl. the per-task SLO breakdown)
        print(json.dumps({
            **m.row(),
            "backend": args.backend,
            "scale_out": res.n_scale_out,
            "scale_in": res.n_scale_in,
            "role_flips": res.n_role_flips,
            "live_migrations": res.n_live_migrations,
            "n_faults": res.n_faults,
            "n_recovered": res.n_recovered,
            "n_lost": res.n_lost,
            "n_transfer_retries": res.n_transfer_retries,
            "recovery_latency_s": res.recovery_latency_s,
            "spec_dispatches": res.spec_dispatches,
            "spec_proposed": res.spec_proposed,
            "spec_accepted": res.spec_accepted,
        }))
        return
    print(f"policy={args.policy} backend={args.backend} mode={args.mode} "
          f"qps={args.qps} workers={args.workers} scaling={args.scaling}")
    print(f"  attainment      {m.attainment:.3f} "
          f"(ttft {m.ttft_attainment:.3f}, tpot {m.tpot_attainment:.3f})")
    print(f"  mean E2E        {m.mean_e2e:.2f}s   p99 {m.p99_e2e:.2f}s")
    print(f"  cost            {m.cost_units:.0f} units "
          f"(makespan {m.makespan:.1f}s)")
    if args.prefix_cache:
        print(f"  prefix cache    hit_rate {m.prefix_hit_rate:.3f} "
              f"({m.prefix_hit_tokens} tokens reused)")
    if args.spec_decode:
        tpd = (1.0 + res.spec_accepted / res.spec_dispatches
               if res.spec_dispatches else 1.0)
        print(f"  spec decode     dispatches={res.spec_dispatches} "
              f"proposed={res.spec_proposed} "
              f"accepted={res.spec_accepted} "
              f"tokens/dispatch={tpd:.2f}")
    for t, v in m.per_task.items():
        print(f"    {t:20s} att={v['attainment']:.3f} "
              f"(ttft {v['ttft_attainment']:.3f} / "
              f"tpot {v['tpot_attainment']:.3f}) "
              f"e2e={v['mean_e2e']:.2f}s ttft={v['mean_ttft']:.3f}s")
    if args.scaling:
        print(f"  scaling: out={res.n_scale_out} in={res.n_scale_in} "
              f"role_flips={res.n_role_flips}")
    if args.live_migration:
        print(f"  live migration: landed={res.n_live_migrations} "
              f"(rescue={res.n_rescues} evac={res.n_evacuations}) "
              f"migrated_reqs={m.n_migrated}")
    if args.fault_schedule:
        print(f"  faults: injected={res.n_faults} "
              f"recovered={res.n_recovered} lost={res.n_lost} "
              f"transfer_retries={res.n_transfer_retries} "
              f"(recovery={'on' if args.recovery else 'off'})")
    for t, wid, ev in res.timeline[:20]:
        print(f"    t={t:7.2f}s worker{wid} {ev}")


if __name__ == "__main__":
    main()
