"""Roofline report generator: dryrun.jsonl -> markdown table.

    PYTHONPATH=src python -m repro.launch.roofline --in runs/dryrun.jsonl
"""

from __future__ import annotations

import argparse
import json
from collections import OrderedDict


_DEFAULT_OPTIONS = {
    "compress": False, "fsdp": True, "remat": True,
    "shard_residual": None, "q_chunk": 512, "unroll": True,
    "pad_heads": 0, "moe_groups": 0, "train_kv_repeat": False,
}


def nondefault_options(options: dict) -> dict:
    return {
        k: v for k, v in (options or {}).items()
        if _DEFAULT_OPTIONS.get(k, object()) != v
    }


def is_baseline(rec: dict) -> bool:
    return not nondefault_options(rec.get("options", {}))


def load(path: str) -> list[dict]:
    recs = OrderedDict()
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            try:
                r = json.loads(line)
            except json.JSONDecodeError:
                continue
            key = (r.get("arch"), r.get("shape"), r.get("mesh"),
                   json.dumps(nondefault_options(r.get("options", {})),
                              sort_keys=True))
            recs[key] = r  # later lines win (re-runs)
    return list(recs.values())


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def table(recs: list[dict], mesh: str = "single",
          baseline_only: bool = True) -> str:
    rows = [r for r in recs if r.get("mesh") == mesh
            and r.get("status") == "ok"
            and (is_baseline(r) or not baseline_only)]
    out = [
        "| arch | shape | compute | memory | collective | bottleneck |"
        " useful | roofline-frac | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mem = r.get("memory", {})
        dev_bytes = (mem.get("argument_bytes", 0)
                     + mem.get("temp_bytes", 0)
                     + mem.get("output_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['shape']} "
            f"| {fmt_s(r['compute_term_s'])} "
            f"| {fmt_s(r['memory_term_s'])} "
            f"| {fmt_s(r['collective_term_s'])} "
            f"| {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} "
            f"| {dev_bytes/1e9:.2f}GB |"
        )
    return "\n".join(out)


def failures(recs: list[dict]) -> str:
    rows = [r for r in recs if r.get("status") != "ok"]
    if not rows:
        return "(none)"
    return "\n".join(
        f"- {r['arch']} x {r['shape']} x {r['mesh']}: "
        f"{r.get('error', '?')[:160]}"
        for r in rows
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--in", dest="inp", default="runs/dryrun.jsonl")
    ap.add_argument("--mesh", default="single")
    args = ap.parse_args()
    recs = load(args.inp)
    print(f"## Roofline ({args.mesh}-pod)\n")
    print(table(recs, args.mesh))
    print("\n### Failures\n")
    print(failures(recs))


if __name__ == "__main__":
    main()
