"""Automated §Perf hillclimb driver.

Runs a cell's baseline plus a set of candidate option-variants, compares
the three roofline terms, and prints the hypothesis log table — the
exact loop EXPERIMENTS.md §Perf records, automated:

    PYTHONPATH=src python -m repro.launch.hillclimb \
        --arch command-r-plus-104b --shape decode_32k \
        --variants no-fsdp,q-chunk=2048

Known variant knobs: no-fsdp, no-remat, no-residual-shard, compress,
train-kv-repeat, q-chunk=<n>, pad-heads=<n>, moe-groups=<n>.
"""

# Must precede any other import (jax locks device count on first init).
import os  # noqa: E402

os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

import argparse  # noqa: E402
import json  # noqa: E402


def parse_variant(spec: str) -> dict:
    kw: dict = {}
    for part in spec.split("+"):
        part = part.strip()
        if not part:
            continue
        if part == "no-fsdp":
            kw["fsdp"] = False
        elif part == "no-remat":
            kw["remat"] = False
        elif part == "no-residual-shard":
            kw["shard_residual"] = False
        elif part == "compress":
            kw["compress"] = True
        elif part == "train-kv-repeat":
            kw["train_kv_repeat"] = True
        elif part.startswith("q-chunk="):
            kw["q_chunk"] = int(part.split("=")[1])
        elif part.startswith("pad-heads="):
            kw["pad_heads"] = int(part.split("=")[1])
        elif part.startswith("moe-groups="):
            kw["moe_groups"] = int(part.split("=")[1])
        else:
            raise ValueError(f"unknown variant knob {part!r}")
    return kw


def fmt(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def main() -> None:
    from repro.launch.dryrun import run_cell

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--variants", default="",
                    help="comma-separated variant specs; '+' combines "
                         "knobs within one variant")
    ap.add_argument("--out", default="runs/hillclimb.jsonl")
    args = ap.parse_args()

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    rows = []
    specs = ["baseline"] + [v for v in args.variants.split(",") if v]
    with open(args.out, "a") as f:
        for spec in specs:
            kw = {} if spec == "baseline" else parse_variant(spec)
            rec = run_cell(args.arch, args.shape, args.mesh, **kw)
            f.write(json.dumps(rec) + "\n")
            f.flush()
            rows.append((spec, rec))
            if rec["status"] != "ok":
                print(f"[FAIL] {spec}: {rec.get('error', '')[:160]}")

    base = rows[0][1]
    print(f"\n## {args.arch} x {args.shape} x {args.mesh}\n")
    print("| variant | compute | memory | collective | bottleneck |"
          " useful | vs-baseline dominant |")
    print("|---|---|---|---|---|---|---|")
    for spec, r in rows:
        if r["status"] != "ok":
            continue
        if base["status"] == "ok" and base["bottleneck"] in (
            "compute", "memory", "collective"
        ):
            dom_key = f"{base['bottleneck']}_term_s"
            ratio = (base[dom_key] / r[dom_key]
                     if r.get(dom_key) else float("nan"))
            delta = f"{ratio:.2f}x"
        else:
            delta = "-"
        print(f"| {spec} | {fmt(r['compute_term_s'])} "
              f"| {fmt(r['memory_term_s'])} "
              f"| {fmt(r['collective_term_s'])} "
              f"| {r['bottleneck']} "
              f"| {r['useful_flops_ratio']:.2f} | {delta} |")


if __name__ == "__main__":
    main()
