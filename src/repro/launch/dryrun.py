"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 host placeholder devices, lowers the jitted
train/prefill/decode step with full-size ShapeDtypeStruct inputs and
explicit NamedShardings, compiles, and extracts

- memory_analysis()        -> bytes/device (fits or not),
- cost_analysis()          -> per-device HLO FLOPs / bytes,
- the compiled HLO's collective ops -> bytes over the interconnect,

which EXPERIMENTS.md §Dry-run / §Roofline consume.
"""

# The VERY FIRST lines — before any other import — because jax locks the
# device count at first init.
import os  # noqa: E402

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, mfu_flops  # noqa: E402
from repro.configs.base import ModelConfig, ShapeSpec  # noqa: E402
from repro.distributed.sharding import arch_rules, plan_arch, use_rules  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models.build import build_model  # noqa: E402
from repro.training.data import DataConfig, batch_spec  # noqa: E402
from repro.training.optimizer import adamw_init  # noqa: E402
from repro.training.train_loop import TrainConfig, build_train_step  # noqa: E402

# ---------------------------------------------------------------------------
# Hardware constants (TPU v5e) for the roofline terms
# ---------------------------------------------------------------------------
PEAK_FLOPS = 197e12     # bf16 / chip
HBM_BW = 819e9          # bytes/s / chip
LINK_BW = 50e9          # bytes/s / ICI link

_DTYPE_BYTES = {
    "f64": 8, "u64": 8, "s64": 8, "f32": 4, "u32": 4, "s32": 4,
    "bf16": 2, "f16": 2, "u16": 2, "s16": 2,
    "u8": 1, "s8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_COLL_KINDS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_RE = re.compile(
    r"=\s*(.*?)\s*(all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start|-done)?\("
)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum collective payload bytes (per-device module) by op kind."""
    out = {k: {"bytes": 0, "count": 0} for k in _COLL_KINDS}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        if "-done(" in line:
            continue  # async pair: count the -start only
        result_type, kind = m.group(1), m.group(2)
        out[kind]["bytes"] += _shape_bytes(result_type)
        out[kind]["count"] += 1
    # effective on-link bytes: ring all-reduce moves ~2x payload
    link_bytes = sum(
        v["bytes"] * (2.0 if k == "all-reduce" else 1.0)
        for k, v in out.items()
    )
    out["link_bytes"] = link_bytes
    return out


# ---------------------------------------------------------------------------
# Cell construction
# ---------------------------------------------------------------------------


def _shardings_of(rules, axes_tree):
    return jax.tree.map(
        lambda axes: rules.sharding(tuple(axes)),
        axes_tree,
        is_leaf=lambda x: isinstance(x, tuple),
    )


def build_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
               compress: bool = False, fsdp: bool = True,
               shard_residual=None, remat: bool = True,
               q_chunk: int = 512, unroll: bool = True,
               train_kv_repeat: bool = False):
    """Returns (lower_fn) which produces the jax lowered object."""
    plan = plan_arch(cfg, mesh)
    stage = ("train" if shape.kind == "train" else
             "prefill" if shape.kind == "prefill" else
             ("decode_long" if shape.seq_len > 100_000 else "decode"))
    rules = arch_rules(
        cfg, mesh, stage=stage, fsdp=fsdp,
        exclude_pod=compress and shape.kind == "train",
        shard_residual=shard_residual,
        batch_size=shape.global_batch,
    )
    p = jax.sharding.PartitionSpec

    def repl():
        return jax.sharding.NamedSharding(mesh, p())

    if shape.kind == "train":
        model = build_model(
            cfg, param_dtype=jnp.float32, compute_dtype=jnp.bfloat16,
            kv_repeat=plan["kv_repeat"] if train_kv_repeat else 1,
            remat=remat, q_chunk=q_chunk,
            vocab_pad=plan["vocab_pad"], unroll=unroll,
        )
        params_abs = model.abstract_params()
        opt_abs = jax.eval_shape(adamw_init, params_abs)
        p_sh = _shardings_of(rules, model.param_axes())
        opt_sh = type(opt_abs)(
            step=repl(),
            mu=p_sh, nu=p_sh,
            norm_ema=repl(),
        )
        data = DataConfig(batch=shape.global_batch, seq_len=shape.seq_len)
        batch_abs = batch_spec(cfg, data)
        b_axes = {
            k: (("batch", "seq", "embed") if k == "frames"
                else ("batch", "seq"))
            for k in batch_abs
        }
        b_sh = {k: rules.sharding(v) for k, v in b_axes.items()}
        step_fn = build_train_step(
            model, TrainConfig(grad_compression=compress), mesh
        )
        jitted = jax.jit(step_fn, in_shardings=(p_sh, opt_sh, b_sh),
                         donate_argnums=(0, 1))

        def lower():
            with use_rules(rules):
                return jitted.lower(params_abs, opt_abs, batch_abs)

        return lower

    # serving stages: bf16 params
    model = build_model(
        cfg, param_dtype=jnp.bfloat16, compute_dtype=jnp.bfloat16,
        kv_repeat=plan["kv_repeat"], remat=False, q_chunk=q_chunk,
        vocab_pad=plan["vocab_pad"], unroll=unroll,
    )
    params_abs = model.abstract_params()
    p_sh = _shardings_of(rules, model.param_axes())

    if shape.kind == "prefill":
        b, s = shape.global_batch, shape.seq_len
        if cfg.frontend == "frames":
            tok_abs = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                           jnp.bfloat16)
            tok_sh = rules.sharding(("batch", "seq", "embed"))
        else:
            tok_abs = jax.ShapeDtypeStruct((b, s), jnp.int32)
            tok_sh = rules.sharding(("batch", "seq"))
        lens_abs = jax.ShapeDtypeStruct((b,), jnp.int32)

        def prefill_fn(params, tokens, lens):
            return model.prefill(params, tokens, lens)

        jitted = jax.jit(
            prefill_fn,
            in_shardings=(p_sh, tok_sh, rules.sharding(("batch",))),
        )

        def lower():
            with use_rules(rules):
                return jitted.lower(params_abs, tok_abs, lens_abs)

        return lower

    # decode
    b, s = shape.global_batch, shape.seq_len
    cache_abs = jax.eval_shape(lambda: model.init_cache(b, s))
    c_sh = _shardings_of(rules, model.cache_logical_axes())
    tok_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((b,), jnp.int32)
    b_sh = rules.sharding(("batch",))

    jitted = jax.jit(
        model.decode_step,
        in_shardings=(p_sh, c_sh, b_sh, b_sh),
        donate_argnums=(1,),
    )

    def lower():
        with use_rules(rules):
            return jitted.lower(params_abs, cache_abs, tok_abs, pos_abs)

    return lower


# ---------------------------------------------------------------------------
# Analysis
# ---------------------------------------------------------------------------


def _cost_of(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = parse_collectives(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["link_bytes"]),
        "coll_detail": {k: v for k, v in coll.items()
                        if k != "link_bytes"},
    }


def measure_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, **kw) -> dict:
    """Two-phase measurement.

    Phase 1 (memory): compile the full-depth scan-over-layers program —
    compact HLO, exact buffer accounting -> memory_analysis().

    Phase 2 (cost): XLA's cost model counts loop bodies once, so
    per-device FLOPs/bytes/collective-bytes come from *block
    extrapolation*: compile a 0-layer variant (embed+head+loss+opt) and
    a 1-layer variant per block kind with all loops unrolled; the exact
    per-layer increment is the difference, and the cell total is
    f0 + sum_k count_k * delta_k.  (The weight-shared zamba2 block
    over-counts its optimizer update 12x — negligible.)
    """
    import dataclasses as dc

    rec: dict = {}
    # ---- phase 1: memory (scan, full depth) ----
    t0 = time.time()
    lowered = build_cell(cfg, shape, mesh, unroll=False, **kw)()
    compiled = lowered.compile()
    rec["compile_scan_s"] = round(time.time() - t0, 1)
    try:
        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_bytes": int(mem.argument_size_in_bytes),
            "output_bytes": int(mem.output_size_in_bytes),
            "temp_bytes": int(mem.temp_size_in_bytes),
            "code_bytes": int(mem.generated_code_size_in_bytes),
        }
    except Exception as e:  # pragma: no cover
        rec["memory"] = {"error": repr(e)}
    rec["scan_flops_per_device"] = float(
        compiled.cost_analysis().get("flops", 0.0)
    )

    # ---- phase 2: block-extrapolated cost ----
    counts: dict[str, int] = {}
    for kind, cnt in cfg.layer_pattern():
        counts[kind] = counts.get(kind, 0) + cnt

    def variant_cost(pattern):
        vcfg = dc.replace(cfg, pattern_override=tuple(pattern))
        lw = build_cell(vcfg, shape, mesh, unroll=True, **kw)()
        return _cost_of(lw.compile())

    t1 = time.time()
    f0 = variant_cost(())
    deltas = {}
    for kind in counts:
        f1 = variant_cost(((kind, 1),))
        deltas[kind] = {
            m: max(0.0, f1[m] - f0[m])
            for m in ("flops", "bytes", "coll_bytes")
        }
    rec["cost_passes_s"] = round(time.time() - t1, 1)

    totals = {
        m: f0[m] + sum(counts[k] * deltas[k][m] for k in counts)
        for m in ("flops", "bytes", "coll_bytes")
    }
    rec["cost_method"] = "block-extrapolated"
    rec["base_cost"] = {m: f0[m] for m in ("flops", "bytes",
                                           "coll_bytes")}
    rec["per_layer"] = deltas
    rec["layer_counts"] = counts
    rec["flops_per_device"] = totals["flops"]
    rec["bytes_per_device"] = totals["bytes"]
    rec["collective_bytes_per_device"] = totals["coll_bytes"]
    rec["collectives"] = f0["coll_detail"]
    return rec


def analyze_terms(rec: dict, cfg: ModelConfig, shape: ShapeSpec,
                  mesh) -> None:
    chips = mesh.size
    rec["chips"] = chips
    rec["compute_term_s"] = rec["flops_per_device"] / PEAK_FLOPS
    rec["memory_term_s"] = rec["bytes_per_device"] / HBM_BW
    rec["collective_term_s"] = (
        rec["collective_bytes_per_device"] / LINK_BW
    )
    terms = {
        "compute": rec["compute_term_s"],
        "memory": rec["memory_term_s"],
        "collective": rec["collective_term_s"],
    }
    rec["bottleneck"] = max(terms, key=terms.get)
    model_flops = mfu_flops(cfg, shape)
    rec["model_flops"] = model_flops
    total_hlo = rec["flops_per_device"] * chips
    rec["useful_flops_ratio"] = (
        model_flops / total_hlo if total_hlo > 0 else 0.0
    )
    # roofline fraction: ideal time of the dominant resource over the
    # sum of all three (a serial, no-overlap pessimistic bound)
    tsum = sum(terms.values())
    rec["roofline_fraction"] = (
        max(terms.values()) / tsum if tsum > 0 else 0.0
    )


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             pad_heads: int = 0, moe_groups: int = 0, **kw) -> dict:
    import dataclasses as dc

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    kw.pop("unroll", None)  # phase-controlled inside measure_cell

    # hillclimb knobs: pad attention heads to the TP degree / grouped
    # MoE dispatch (groups aligned with the data shards)
    run_cfg = cfg
    if pad_heads:
        run_cfg = dc.replace(run_cfg, n_heads=cfg.n_heads + pad_heads)
    if moe_groups and cfg.moe is not None:
        run_cfg = dc.replace(
            run_cfg, moe=dc.replace(cfg.moe, dispatch_groups=moe_groups)
        )
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": dict(zip(mesh.axis_names,
                               [mesh.shape[a] for a in mesh.axis_names])),
        "options": {k: v for k, v in kw.items()},
    }
    if pad_heads:
        rec["options"]["pad_heads"] = pad_heads
    if moe_groups:
        rec["options"]["moe_groups"] = moe_groups
    t0 = time.time()
    try:
        rec.update(measure_cell(run_cfg, shape, mesh, **kw))
        # model_flops / useful ratio always judged against the
        # *published* config — padding counts as overhead
        analyze_terms(rec, cfg, shape, mesh)
        rec["status"] = "ok"
    except Exception as e:
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    rec["total_s"] = round(time.time() - t0, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="runs/dryrun.jsonl")
    ap.add_argument("--compress", action="store_true",
                    help="int8 cross-pod gradient sync (train cells)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-residual-shard", action="store_true")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--pad-heads", type=int, default=0)
    ap.add_argument("--moe-groups", type=int, default=0)
    ap.add_argument("--train-kv-repeat", action="store_true",
                    help="repeat KV heads to the TP degree in train "
                         "cells (fixes uneven GQA head sharding)")
    ap.add_argument("--scan-layers", action="store_true",
                    help="keep lax.scan over layers (compact HLO, but "
                         "cost analysis undercounts loop bodies)")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = (list(ASSIGNED_ARCHS) if args.arch == "all"
             else args.arch.split(","))
    meshes = (["single", "multi"] if args.mesh == "both"
              else [args.mesh])

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    seen = set()
    if args.skip_existing and os.path.exists(args.out):
        with open(args.out) as f:
            for line in f:
                try:
                    r = json.loads(line)
                    if r.get("status") == "ok":
                        seen.add((r["arch"], r["shape"], r["mesh"]))
                except json.JSONDecodeError:
                    pass

    kw = dict(
        compress=args.compress,
        fsdp=not args.no_fsdp,
        remat=not args.no_remat,
        shard_residual=(False if args.no_residual_shard else None),
        q_chunk=args.q_chunk,
        unroll=not args.scan_layers,
        pad_heads=args.pad_heads,
        moe_groups=args.moe_groups,
        train_kv_repeat=args.train_kv_repeat,
    )
    n_ok = n_fail = 0
    with open(args.out, "a") as f:
        for arch in archs:
            cfg = get_config(arch)
            shapes = ([s.name for s in cfg.shapes()]
                      if args.shape == "all" else args.shape.split(","))
            for shape_name in shapes:
                for mesh_kind in meshes:
                    key = (arch, shape_name, mesh_kind)
                    if key in seen:
                        continue
                    rec = run_cell(arch, shape_name, mesh_kind, **kw)
                    f.write(json.dumps(rec) + "\n")
                    f.flush()
                    ok = rec["status"] == "ok"
                    n_ok += ok
                    n_fail += not ok
                    msg = (
                        f"[{'OK' if ok else 'FAIL'}] {arch} x {shape_name}"
                        f" x {mesh_kind} ({rec['total_s']}s)"
                    )
                    if ok:
                        msg += (
                            f" bottleneck={rec['bottleneck']}"
                            f" c={rec['compute_term_s']:.3e}"
                            f" m={rec['memory_term_s']:.3e}"
                            f" x={rec['collective_term_s']:.3e}"
                            f" useful={rec['useful_flops_ratio']:.2f}"
                        )
                    else:
                        msg += " " + rec.get("error", "")[:200]
                    print(msg, flush=True)
            # documented skips
            for sname, why in cfg.skipped_shapes():
                if args.shape == "all":
                    print(f"[SKIP] {arch} x {sname}: {why}", flush=True)
    print(f"dry-run complete: {n_ok} ok, {n_fail} fail")


if __name__ == "__main__":
    main()
