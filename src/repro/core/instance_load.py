"""Unified per-instance load signal (Llumnix-style global scheduling).

One scalar per worker, computed from the same observable surface every
control-plane component already reads — KV occupancy, queue depth,
predicted next-step time vs. the batch's tightest TPOT, and SLO-miss
risk under the fitted latency model — so the Dispatcher (placement
tie-break), the MigrationCoordinator (victim/destination pairing), and
the Scaler (scale-in / role-flip target choice) all rank instances by
the SAME definition of "loaded".  Divergent per-component heuristics
are how dispatch fills the worker migration is trying to empty.

The :class:`ReservationLedger` closes the in-flight-migration blind
spot: a request whose KV transfer has been *scheduled* but has not yet
landed via ``accept_migrated`` is invisible in the destination's
``running``/``waiting`` views, so anything reading only those views
overcommits the destination between ``kv_ready`` events.  Every
migration (P/D hand-off or live decode-to-decode) reserves its tokens
and TPOT on the destination at planning time; the Cluster releases the
reservation when the transfer resolves — landed, aborted, or
destination vanished.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.latency_model import LatencyModel
from repro.core.request import Request


class ReservationLedger:
    """Per-destination accounting of migrations in flight."""

    def __init__(self):
        # dst wid -> {rid: (reserved tokens, tpot_slo)}
        self._by_dst: dict[int, dict[int, tuple[int, float]]] = {}
        self._dst_of: dict[int, int] = {}

    def reserve(self, dst: int, r: Request) -> None:
        """Charge ``r`` against ``dst`` until its transfer resolves.
        Re-reserving an rid moves the charge (a re-planned migration
        never double-counts)."""
        self.release(r.rid)
        self._by_dst.setdefault(dst, {})[r.rid] = (r.cur_len, r.tpot_slo)
        self._dst_of[r.rid] = dst

    def release(self, rid: int) -> Optional[int]:
        """Drop ``rid``'s reservation; returns the destination wid it
        was charged to (None if it held none) — idempotent, so every
        ``kv_ready`` path may call it unconditionally."""
        dst = self._dst_of.pop(rid, None)
        if dst is not None:
            slots = self._by_dst.get(dst)
            if slots is not None:
                slots.pop(rid, None)
        return dst

    def dst_of(self, rid: int) -> Optional[int]:
        return self._dst_of.get(rid)

    def drop_dst(self, dst: int) -> list[int]:
        """Clear every charge against a vanished destination (replica
        crash): those transfers can never land, and a dead worker must
        stop reserving capacity in the load signal.  Returns the rids
        released — their eventual ``kv_ready`` events then no-op."""
        rids = list(self._by_dst.pop(dst, {}))
        for rid in rids:
            self._dst_of.pop(rid, None)
        return rids

    def lens(self, dst: int) -> list[int]:
        return [tok for tok, _ in self._by_dst.get(dst, {}).values()]

    def tpots(self, dst: int) -> list[float]:
        return [tp for _, tp in self._by_dst.get(dst, {}).values()]

    def tokens(self, dst: int) -> int:
        return sum(tok for tok, _ in self._by_dst.get(dst, {}).values())

    def n_inflight(self, dst: int) -> int:
        return len(self._by_dst.get(dst, {}))


@dataclasses.dataclass
class InstanceLoadConfig:
    headroom: float = 0.95    # fraction of the tightest TPOT E_d may use
    w_kv: float = 1.0         # KV occupancy weight
    w_queue: float = 0.4      # waiting-queue depth weight
    w_pressure: float = 1.0   # predicted decode pressure weight
    w_risk: float = 0.5       # SLO-miss-risk weight
    pressure_cap: float = 2.0 # saturate so one hot replica can't hide
                              # ordering among the others


class InstanceLoadCalculator:
    """One load scalar per Backend worker, reservation-aware."""

    def __init__(self, latency_model: LatencyModel,
                 cfg: Optional[InstanceLoadConfig] = None,
                 ledger: Optional[ReservationLedger] = None):
        self.model = latency_model
        self.cfg = InstanceLoadConfig() if cfg is None else cfg
        self.ledger = ledger if ledger is not None else ReservationLedger()

    # -- components --------------------------------------------------------------
    def decode_lens(self, w) -> list[int]:
        """Context lengths the next decode step would batch, including
        reserved in-flight arrivals."""
        return ([r.cur_len for r in w.running]
                + self.ledger.lens(w.wid))

    def decode_tpots(self, w) -> list[float]:
        return ([r.tpot_slo for r in w.running]
                + self.ledger.tpots(w.wid))

    def kv_occupancy(self, w) -> float:
        used = w.kv_tokens() + self.ledger.tokens(w.wid)
        return used / max(w.kv_capacity, 1)

    def pressure(self, w) -> float:
        """Predicted next decode-step time over the tightest TPOT
        budget of the (running + reserved) batch; > 1 means the fitted
        model already predicts a TPOT miss on this worker."""
        lens = self.decode_lens(w)
        if not lens:
            return 0.0
        tpots = self.decode_tpots(w)
        budget = min(tpots) * self.cfg.headroom
        e_d = self.model.decode_step_time(lens)
        return e_d / max(budget, 1e-9)

    def slo_risk(self, w) -> float:
        """Fraction of the decode batch whose own TPOT budget the
        predicted next step already exceeds — pressure localizes the
        tightest request, risk says how widespread the miss is."""
        lens = self.decode_lens(w)
        if not lens:
            return 0.0
        e_d = self.model.decode_step_time(lens)
        tpots = self.decode_tpots(w)
        miss = sum(1 for tp in tpots
                   if e_d > tp * self.cfg.headroom)
        return miss / len(tpots)

    # -- the scalar --------------------------------------------------------------
    def load(self, w) -> float:
        """Weighted load in ~[0, w_kv + w_queue + w_pressure·cap + w_risk];
        monotone in every component, 0 for an idle worker."""
        c = self.cfg
        queue = len(w.waiting)
        q_term = 1.0 - 1.0 / (1.0 + queue)   # [0, 1), saturating
        p_term = min(self.pressure(w), c.pressure_cap)
        return (c.w_kv * self.kv_occupancy(w)
                + c.w_queue * q_term
                + c.w_pressure * p_term
                + c.w_risk * self.slo_risk(w))

    def rank(self, workers) -> list:
        """Active workers, least loaded first (wid tie-break)."""
        return sorted((w for w in workers if w.active),
                      key=lambda w: (self.load(w), w.wid))
