"""TransferLink Manager (paper §4, §6 "Fast Scaling").

Responsibilities, mapped from Ascend/HCCL onto TPU/ICI semantics:

- **P/D links**: KV caches move prefill→decode over device-to-device
  links.  Links are *proactively* established when workers join (the
  Mooncake comparison in §6) — a lazily created link pays a setup cost
  on first transfer.
- **Fast Scaling**: a new instance pulls weights from a live instance's
  WeightManager over D2D instead of disk, with fall back to disk on
  failure.  In JAX the transport is `jax.device_put`/resharding over
  ICI; here the manager computes transfer times from link bandwidth and
  also performs *real* small-scale transfers in the engine examples.

All times are deterministic functions of bytes and per-pair bandwidth so
the event simulator and the scaler agree.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ModelConfig
from repro.core.latency_model import Hardware, TPU_V5E


@dataclasses.dataclass(frozen=True)
class TransferCosts:
    link_setup: float = 0.150      # s, communication-domain establishment
    d2d_eff: float = 0.80          # achievable fraction of ICI bw
    runtime_warmup: float = 0.35   # s, CPU runtime init when not warm


def kv_bytes(cfg: ModelConfig, tokens: int, dtype_bytes: int = 2) -> float:
    """KV-cache footprint of `tokens` cached tokens (SSM: fixed state)."""
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind, cnt in cfg.layer_pattern():
        if kind == "mamba":
            if cfg.ssm is not None:
                s = cfg.ssm
                di = s.d_inner(cfg.d_model)
                h = s.n_heads(cfg.d_model)
                # fixed-size state: conv tail + SSM state (f32)
                total += cnt * (
                    (s.conv_width - 1) * (di + 2 * s.n_groups * s.d_state)
                    * dtype_bytes
                    + h * s.head_dim * s.d_state * 4
                )
        else:
            total += cnt * 2 * cfg.n_kv_heads * hd * tokens * dtype_bytes
    return total


class TLManager:
    def __init__(self, hw: Hardware = TPU_V5E,
                 costs: TransferCosts = TransferCosts(),
                 proactive_links: bool = True):
        self.hw = hw
        self.costs = costs
        self.proactive_links = proactive_links
        self._links: set[tuple[int, int]] = set()
        self.kv_bytes_moved = 0.0
        # weight-provisioning accounting: total plus a host-vs-ICI
        # split ("d2d" rides the device interconnect; "cpu" and "disk"
        # both cross the host link — the bench reports both)
        self.weight_bytes_moved = 0.0
        self.weight_bytes_ici = 0.0
        self.weight_bytes_host = 0.0
        self.n_kv_transfers = 0
        self.n_weight_loads = 0
        # measured transfer model: EWMA bytes/s per strategy, fed by
        # real provisions (WeightManager) — once observed, it replaces
        # the analytic bandwidth in weight_load_time, so the Scaler
        # costs scale-outs from what this host actually sustains
        self._weight_bw: dict[str, float] = {}

    # -- links ---------------------------------------------------------------
    def establish_link(self, a: int, b: int) -> float:
        """Returns the setup latency paid *now* (0 if already linked)."""
        key = (min(a, b), max(a, b))
        if key in self._links:
            return 0.0
        self._links.add(key)
        return self.costs.link_setup

    def ensure_links(self, new_worker: int, peers) -> None:
        """Proactive link establishment at scale-out (§6)."""
        for p in peers:
            self._links.add((min(new_worker, p), max(new_worker, p)))

    def has_link(self, a: int, b: int) -> bool:
        return (min(a, b), max(a, b)) in self._links

    # -- KV migration ----------------------------------------------------------
    def kv_transfer_time(self, cfg: ModelConfig, tokens: int,
                         src: int, dst: int, tp: int = 1,
                         nbytes: Optional[float] = None) -> float:
        """Transfer latency for a KV hand-off.  ``nbytes`` overrides
        the analytic per-token estimate with the *measured* payload
        size (engine plane: what export_kv actually materializes)."""
        if nbytes is None:
            nbytes = kv_bytes(cfg, tokens)
        bw = self.hw.ici_bw * self.costs.d2d_eff * tp
        t = nbytes / bw
        if not self.proactive_links and not self.has_link(src, dst):
            t += self.establish_link(src, dst)
        self.kv_bytes_moved += nbytes
        self.n_kv_transfers += 1
        return t

    # -- weight provisioning (Fast Scaling, Table 2) ----------------------------
    def observe_weight_load(self, strategy: str, nbytes: float,
                            seconds: float) -> None:
        """Feed one *measured* provision (WeightManager) into the
        transfer model.  The EWMA bandwidth replaces the analytic
        figure in subsequent ``weight_load_time`` predictions."""
        if seconds <= 0 or nbytes <= 0:
            return
        bw = nbytes / seconds
        prev = self._weight_bw.get(strategy)
        self._weight_bw[strategy] = (bw if prev is None
                                     else 0.5 * prev + 0.5 * bw)
        self.n_weight_loads += 1

    def measured_weight_bw(self, strategy: str) -> Optional[float]:
        return self._weight_bw.get(strategy)

    def weight_load_time(self, cfg: ModelConfig, strategy: str,
                         tp: int = 1, dtype_bytes: int = 2,
                         warm: bool = True, record: bool = True,
                         nbytes: Optional[float] = None) -> float:
        """Cold-start weight provisioning latency.

        strategy: "d2d" (Fast Scaling — pull from a live instance's
        WeightManager over ICI), "cpu" (host-offloaded copy), "disk".
        TP shards load in parallel across the tp device group.  Once a
        strategy has measured samples (``observe_weight_load``) its
        observed bandwidth wins over the analytic figure.  ``record``
        books the moved bytes (every strategy moves the full tree —
        set False for cost *probes* that commit no transfer).
        """
        if nbytes is None:
            nbytes = cfg.param_count() * dtype_bytes
        per_dev = nbytes / tp
        if strategy not in ("d2d", "cpu", "disk"):
            raise ValueError(strategy)
        measured = self._weight_bw.get(strategy)
        if measured is not None:
            # measured wall time already amortizes link setup / file
            # open overheads into the observed bandwidth
            t = (nbytes if strategy == "disk" else per_dev) / measured
        elif strategy == "d2d":
            t = self.costs.link_setup + per_dev / (
                self.hw.ici_bw * self.costs.d2d_eff
            )
        elif strategy == "cpu":
            t = per_dev / self.hw.host_bw
        else:  # disk — shared disk: parallel readers contend
            t = nbytes / self.hw.disk_bw
        if record:
            self.weight_bytes_moved += nbytes
            if strategy == "d2d":
                self.weight_bytes_ici += nbytes
            else:
                self.weight_bytes_host += nbytes
        if not warm:
            t += self.costs.runtime_warmup
        return t
