"""Multi-SLO-aware Dispatcher (paper §5.1, Algorithm 1).

Centralized scheduler.  Requests wait in Q_R ordered by (TPOT, arrival);
workers sit in Q_W ordered by *maturity time* — the earliest moment a
worker can take new load without endangering deadlines.  A dispatch pass
pops the maturest worker, computes its token budget (Eq. 5), scans Q_R
admitting requests whose TTFT-attainment probability `calculate_p`
clears the threshold theta, dispatches, and re-inserts the worker with

    maturity <- now + E_p + (E_p / relax) * E_d,
    relax = min TPOT(waiting + new + running) - E_d

so the prefill stall is amortized against the decode slack.

State observation goes through the Monitor's snapshots plus a local
*shadow* (requests this dispatcher just placed) — the paper's
"synchronize in background, update local state after dispatch".

Workers are :class:`~repro.serving.backend.Backend` instances; the
dispatcher only reads the protocol surface (``waiting`` / ``running``
views, ``kv_capacity``, ``kv_tokens()``), so the same instance
schedules simulated and real-engine planes unmodified.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, Iterable, Optional

from repro.core.latency_model import LatencyModel
from repro.core.monitor import Monitor
from repro.core.queues import RequestPriorityQueue, WorkerPriorityQueue
from repro.core.request import Request
from repro.core.token_budget import maturity_interval, ntoken_limit

INF = float("inf")


def _suffix(l_in: int, hit: int) -> int:
    """Prompt tokens that actually need prefill compute after a
    prefix-cache hit of ``hit`` tokens (>= 1: the engine always
    re-prefills at least one token for the first-token logits)."""
    return max(1, l_in - hit)


@dataclasses.dataclass
class DispatcherConfig:
    theta: float = 0.55          # admission probability threshold
    admit_overdue: bool = True   # never starve already-late requests
    scan_limit: int = 512        # max Q_R entries examined per pass
    default_ttft: float = 10.0
    default_tpot: float = 1.0


@dataclasses.dataclass
class AdmissionVerdict:
    """Submit-time admit/reject decision (proactive admission control).

    Produced by :meth:`Dispatcher.admission_verdict` from the same
    Eq. 5 / ``calculate_p`` machinery a dispatch pass uses — but
    evaluated when the request is *submitted*, so an online client
    learns immediately that a request is doomed instead of watching it
    queue past its deadline.
    """

    admit: bool
    p: float                     # best TTFT-attainment prob. over workers
    wid: Optional[int] = None    # worker achieving it
    est_ttft: float = 0.0        # estimated TTFT on that worker (s)
    reason: str = ""             # human-readable refusal cause


class WorkerShadow:
    """Monitor snapshot + local deltas for one worker."""

    def __init__(self, worker):
        self.worker = worker
        self.snap_time = -INF
        self.cur_lens: list[int] = []
        self.waiting_lens: list[int] = []
        self.waiting_slos: list[tuple[float, float]] = []
        self.running_tpots: list[float] = []
        self.kv_tokens = 0
        self.utilization = 0.0

    def refresh(self, snap) -> None:
        if snap is None or snap.time <= self.snap_time:
            return
        self.snap_time = snap.time
        self.cur_lens = list(snap.cur_lens)
        self.kv_tokens = snap.kv_tokens
        self.utilization = snap.utilization
        self.waiting_lens = []
        self.waiting_slos = []
        # waiting set is re-derived from live worker (the dispatcher owns
        # placement, so its own view of the waiting set is authoritative).
        # Lengths are the *uncached suffix* — prefix-cache hits skip
        # prefill compute, so only the suffix loads the Eq. 5 budget
        # (kv_tokens still charges the full l_in: shared pages are
        # resident either way)
        for r in self.worker.waiting:
            self.waiting_lens.append(_suffix(r.l_in, r.prefix_hit_tokens))
            self.waiting_slos.append((r.ttft_slo, r.tpot_slo))
        self.running_tpots = [r.tpot_slo for r in self.worker.running]

    def after_dispatch(self, reqs: Iterable[Request]) -> None:
        for r in reqs:
            self.waiting_lens.append(_suffix(r.l_in, r.prefix_hit_tokens))
            self.waiting_slos.append((r.ttft_slo, r.tpot_slo))
            self.kv_tokens += r.l_in


class Dispatcher:
    """Prefill-stage / collocated scheduler (Algorithm 1)."""

    def __init__(self, latency_model: LatencyModel, monitor: Monitor,
                 cfg: Optional[DispatcherConfig] = None,
                 on_dispatch: Optional[Callable] = None,
                 load_calc=None):
        self.model = latency_model
        self.monitor = monitor
        # None sentinel: a dataclass default evaluated in the signature
        # would be ONE shared object across every Dispatcher instance
        self.cfg = DispatcherConfig() if cfg is None else cfg
        self.on_dispatch = on_dispatch
        # optional InstanceLoadCalculator: breaks admission ties (equal
        # TTFT-attainment probability) toward the less-loaded worker,
        # so placement agrees with migration/scaling about "loaded"
        self.load_calc = load_calc
        self.qr = RequestPriorityQueue()
        self.qw = WorkerPriorityQueue()
        self.shadows: dict[int, WorkerShadow] = {}
        self._maturity: dict[int, float] = {}

    # -- workers ---------------------------------------------------------------
    def add_worker(self, worker, now: float) -> None:
        self.shadows[worker.wid] = WorkerShadow(worker)
        self._maturity[worker.wid] = now
        self.qw.push(worker, now)

    def remove_worker(self, wid: int) -> None:
        self.shadows.pop(wid, None)
        self._maturity.pop(wid, None)
        # lazily dropped from Q_W on pop

    def notify_worker_free(self, wid: int, now: float) -> None:
        """Maturity correction (paper §5.1: 'periodic telemetry ...
        used to correct delayed observations').  Called when a worker
        finishes a step earlier than the estimate — pull its maturity in
        so the next pass can feed it immediately, and fold the
        completion event into the shadow (event-driven state update, so
        a slow Monitor interval degrades gracefully — Fig. 8)."""
        if wid not in self.shadows:
            return
        shadow = self.shadows[wid]
        w = shadow.worker
        shadow.cur_lens = [r.cur_len for r in w.running]
        shadow.running_tpots = [r.tpot_slo for r in w.running]
        shadow.kv_tokens = w.kv_tokens()
        shadow.waiting_lens = [_suffix(r.l_in, r.prefix_hit_tokens)
                               for r in w.waiting]
        shadow.waiting_slos = [(r.ttft_slo, r.tpot_slo)
                               for r in w.waiting]
        if now < self._maturity.get(wid, 0.0):
            self._maturity[wid] = now
            self.qw.push(w, now)

    # -- request intake ----------------------------------------------------------
    def on_request_arrive(self, r: Request) -> None:
        self.qr.add(r)

    def pending(self) -> int:
        return len(self.qr)

    # -- Algorithm 1 helpers -----------------------------------------------------
    def _free_tokens(self, shadow: WorkerShadow) -> int:
        cap = shadow.worker.kv_capacity
        return max(0, cap - shadow.kv_tokens)

    def _tightest_slos(self, shadow: WorkerShadow) -> tuple[float, float]:
        ttfts = [s[0] for s in shadow.waiting_slos]
        tpots = [s[1] for s in shadow.waiting_slos] + shadow.running_tpots
        head = self.qr.peek()
        if head is not None:
            ttfts.append(head.ttft_slo)
            tpots.append(head.tpot_slo)
        ttft = min(ttfts) if ttfts else self.cfg.default_ttft
        tpot = min(tpots) if tpots else self.cfg.default_tpot
        return ttft, tpot

    def get_ntoken(self, shadow: WorkerShadow) -> int:
        ttft, tpot = self._tightest_slos(shadow)
        e_d = self.model.decode_step_time(shadow.cur_lens)
        return ntoken_limit(ttft, tpot, e_d, self.model)

    def _request_cost(self, r: Request, shadow: WorkerShadow) -> int:
        """Prompt tokens ``r`` would actually prefill on this worker:
        the uncached suffix after the worker's prefix-cache hit (full
        l_in when the plane has no cache)."""
        return _suffix(r.l_in, shadow.worker.prefix_peek(r))

    def calculate_p(self, r: Request, shadow: WorkerShadow,
                    now: float) -> float:
        """TTFT-attainment probability in [0, 1] (Algorithm 1)."""
        e_p = self.model.prefill_time(
            shadow.waiting_lens + [self._request_cost(r, shadow)]
        )
        t_remaining = (r.arrival + r.ttft_slo) - (now + e_p)
        slack = t_remaining / max(r.ttft_slo, 1e-6)
        util = shadow.utilization
        return max(0.0, min(1.0, 0.5 + slack * (1.0 - 0.5 * util)))

    # -- submit-time admission (online serving front door) ------------------------
    def admission_verdict(self, r: Request, now: float) -> AdmissionVerdict:
        """Evaluate the Eq. 5 budget estimate for ``r`` at submit time.

        Read-only: scans the worker shadows (snapshot + local deltas —
        the same possibly-slightly-stale view a dispatch pass budgets
        with) for the best TTFT-attainment probability and rejects when
        no worker clears theta.  The caller decides what a rejection
        means (refuse outright, or degrade the SLO and admit anyway).
        """
        best: Optional[AdmissionVerdict] = None
        best_load: Optional[float] = None
        for wid, shadow in self.shadows.items():
            w = shadow.worker
            if not w.active:
                continue
            if r.l_in > w.kv_capacity:
                continue  # this worker could never hold the prompt
            p = self.calculate_p(r, shadow, now)
            e_p = self.model.prefill_time(
                shadow.waiting_lens + [self._request_cost(r, shadow)]
            )
            arrival = r.arrival if r.arrival is not None else now
            est = max(0.0, (now + e_p) - arrival)
            load = (self.load_calc.load(w)
                    if self.load_calc is not None else None)
            better = best is None or p > best.p + 1e-9
            if (not better and best is not None and load is not None
                    and abs(p - best.p) <= 1e-9 and best_load is not None
                    and load < best_load):
                # idle/near-idle workers all saturate p: the unified
                # load signal breaks the tie instead of dict order
                better = True
            if better:
                best = AdmissionVerdict(False, p, wid, est)
                best_load = load
        if best is None:
            return AdmissionVerdict(
                False, 0.0, None, INF,
                reason="no active worker can hold the prompt",
            )
        best.admit = best.p >= self.cfg.theta
        if not best.admit:
            best.reason = (f"TTFT-attainment probability {best.p:.2f} "
                           f"below theta={self.cfg.theta}")
        return best

    # -- the dispatch pass ---------------------------------------------------------
    def dispatch_pass(self, now: float) -> list[tuple]:
        """Run Algorithm 1 until no mature worker or empty queue.

        Returns [(worker, [requests]), ...] of performed dispatches.
        """
        done = []
        while self.qr:
            w, maturity = self.qw.peek()
            if w is None or maturity > now:
                break
            self.qw.pop()
            if w.wid not in self.shadows or not w.active:
                continue  # scaled-in
            if abs(maturity - self._maturity.get(w.wid, maturity)) > 1e-12:
                continue  # stale duplicate entry (maturity was corrected)
            shadow = self.shadows[w.wid]
            shadow.refresh(self.monitor.snapshot(w.wid))

            # Eq. 5 bounds the worker's total uncommitted prompt tokens:
            # tokens already waiting for prefill count against the budget.
            committed = sum(shadow.waiting_lens)
            token_limit = min(self._free_tokens(shadow),
                              self.get_ntoken(shadow) - committed)
            selected: list[Request] = []
            overdue_pool: list[Request] = []
            costs: dict[int, int] = {}
            used = 0
            # Eq. 5 charges the *uncached suffix*: a prefix-cache hit
            # shrinks the prefill work this worker would actually run,
            # so more (or longer) requests fit the same token budget
            for i, r in enumerate(self.qr.scan()):
                if i >= self.cfg.scan_limit:
                    break
                cost = self._request_cost(r, shadow)
                if used + cost > token_limit:
                    continue
                if self.calculate_p(r, shadow, now) >= self.cfg.theta:
                    selected.append(r)
                    costs[r.rid] = cost
                    used += cost
                elif self.cfg.admit_overdue and r.deadline() <= now:
                    overdue_pool.append(r)
            # already-late requests only fill the leftover budget, so
            # they never push still-savable requests past their TTFT
            for r in overdue_pool:
                cost = self._request_cost(r, shadow)
                if used + cost > token_limit:
                    continue
                selected.append(r)
                costs[r.rid] = cost
                used += cost
            for r in selected:
                self.qr.remove(r)
                r.dispatch_time = now
                # provisional hit estimate so the shadow's waiting_lens
                # budget the suffix; the executing plane re-stamps the
                # actual hit at prefill time
                r.prefix_hit_tokens = max(0, r.l_in - costs[r.rid])
            if selected:
                shadow.after_dispatch(selected)
                if self.on_dispatch is not None:
                    self.on_dispatch(w, selected, now)
                done.append((w, selected))

            # next maturity (Algorithm 1 tail)
            e_p = self.model.prefill_time(shadow.waiting_lens)
            all_lens = shadow.cur_lens + shadow.waiting_lens
            e_d = self.model.decode_step_time(all_lens)
            tpots = ([s[1] for s in shadow.waiting_slos]
                     + shadow.running_tpots)
            min_tpot = min(tpots) if tpots else self.cfg.default_tpot
            interval = maturity_interval(e_p, e_d, min_tpot)
            if not selected and not e_p:
                # idle worker with nothing admitted: poll again shortly
                interval = max(interval, 0.01)
            self._maturity[w.wid] = now + interval
            self.qw.push(w, now + interval)
        return done

    def next_wakeup(self) -> Optional[float]:
        _, maturity = self.qw.peek()
        return maturity
