"""Request model and task classes (paper §3, Table 1).

One request type serves BOTH execution planes (PR 2's unified control
plane): the discrete-event simulator and the real JAX engine.  The
lifecycle is

    arrival -> admitted -> prefilling(chunks) -> decoding
            -> finished | preempted(-> admitted)
    arrival -> rejected            (submit-time admission control)

tracked by :class:`RequestState`.  Scheduler-facing fields (SLOs,
priority, lengths, timing) and engine-facing fields (token ids,
generated output, slot/page bookkeeping) live side by side, so
Algorithms 1-3 operate on the same objects whether the tokens are
simulated or jitted.
"""

from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional

import numpy as np


class RequestState(str, enum.Enum):
    """Unified lifecycle (both planes)."""

    ARRIVED = "arrived"        # known to the control plane, not placed
    ADMITTED = "admitted"      # dispatched to a worker / engine queue
    PREFILLING = "prefilling"  # prompt tokens being consumed (chunked)
    DECODING = "decoding"      # emitting output tokens
    FINISHED = "finished"
    PREEMPTED = "preempted"    # evicted under KV pressure; re-queued
    REJECTED = "rejected"      # refused at submit time (admission control)
    FAILED = "failed"          # lost to a fault; recovery shed it


@dataclasses.dataclass
class Request:
    rid: int
    task: str = "default"
    # None = not yet released to a plane; the engine stamps submit time
    arrival: Optional[float] = None
    l_in: int = 0               # prompt length (tokens)
    l_out: int = 1              # output cap — the scheduler can't see it
    ttft_slo: float = 10.0      # seconds
    tpot_slo: float = 1.0       # seconds per output token
    priority: Optional[int] = None  # for priority-based SLO mapping

    # ---- lifecycle (filled in by the runtime) ----
    state: RequestState = RequestState.ARRIVED
    dispatch_time: Optional[float] = None
    prefill_start: Optional[float] = None
    prefill_progress: int = 0     # prompt tokens prefilled (chunked plane)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens_done: int = 0
    prefill_worker: Optional[int] = None
    decode_worker: Optional[int] = None
    migrate_ready: Optional[float] = None  # KV transfer completion time
    # ---- migration (P/D hand-off and live decode-to-decode) ----
    migrating: bool = False            # a live-migration transfer in flight
    last_migrated: Optional[float] = None  # landing time (move cooldown)
    n_migrations: int = 0              # landed KV moves (hand-off + live)

    # ---- prefix cache (both planes) ----
    # workload-declared shared-prefix identity: requests with the same
    # prefix_group share their first prefix_len prompt tokens (the sim
    # plane has no token ids, so this IS the content key; the engine
    # plane materializes matching tokens from it)
    prefix_group: Optional[int] = None
    prefix_len: int = 0
    # page-aligned tokens served from the cache instead of prefilled;
    # stamped by the plane that ran (or simulated) the prefill
    prefix_hit_tokens: int = 0

    # ---- engine plane (real token ids; None on the simulator plane) ----
    # compare=False: ndarray equality is elementwise — it would make
    # the generated __eq__ raise whenever two requests tie on the
    # scalar fields (e.g. list membership tests in worker pools)
    prompt: Optional["np.ndarray"] = dataclasses.field(
        default=None, compare=False)       # (l_in,) int32 token ids
    generated: Optional[list] = dataclasses.field(
        default=None, compare=False)       # output token ids
    slot: Optional[int] = None             # engine batch row
    admit_seq: int = -1                    # submit order; preemption keeps it
    # in-flight migration payload (engine plane): set by the source's
    # export_kv when the transfer lands, consumed by accept_migrated
    kv_payload: Optional[object] = dataclasses.field(
        default=None, compare=False, repr=False)

    @classmethod
    def from_prompt(cls, rid: int, prompt, max_new: int, *,
                    task: str = "engine", ttft_slo: float = 10.0,
                    tpot_slo: float = 1.0, arrival: Optional[float] = None,
                    priority: Optional[int] = None) -> "Request":
        """Build an engine-plane request from real token ids.

        ``max_new`` becomes ``l_out`` (the generation cap); ``l_in`` is
        derived from the prompt.  ``arrival=None`` lets the engine stamp
        submit time — pass an explicit arrival when a workload generator
        owns the clock.
        """
        prompt = np.asarray(prompt, np.int32)
        return cls(rid=rid, task=task, arrival=arrival,
                   l_in=int(prompt.shape[0]), l_out=int(max_new),
                   ttft_slo=ttft_slo, tpot_slo=tpot_slo, priority=priority,
                   prompt=prompt)

    @property
    def max_new(self) -> int:
        """Engine-plane alias: the generation cap is ``l_out``."""
        return self.l_out

    # -- derived metrics ----------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None or self.arrival is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        # engine runs may stop early (EOS/cache-full): use actual output
        n = self.tokens_done if self.tokens_done > 0 else self.l_out
        if n <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (n - 1)

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None or self.arrival is None:
            return None
        return self.finish_time - self.arrival

    def ttft_ok(self) -> bool:
        t = self.ttft
        return t is not None and t <= self.ttft_slo + 1e-9

    def tpot_ok(self) -> bool:
        t = self.tpot
        return t is not None and t <= self.tpot_slo + 1e-9

    def attained(self) -> bool:
        return self.ttft_ok() and self.tpot_ok()

    @property
    def cur_len(self) -> int:
        """Prefill + decoded tokens so far (l_cur in Eq. 2)."""
        return self.l_in + self.tokens_done

    def deadline(self) -> float:
        return (self.arrival or 0.0) + self.ttft_slo


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One benchmark task class (Table 1)."""

    name: str
    ttft_slo: float
    tpot_slo: float
    in_mean: float
    in_std: float
    out_mean: float
    out_std: float
    priority: int = 0

    def sample_lengths(self, rng) -> tuple[int, int]:
        l_in = max(1, int(rng.normal(self.in_mean, self.in_std)))
        l_out = max(1, int(rng.normal(self.out_mean, self.out_std)))
        return l_in, l_out


# Table 1 of the paper (SLOs in seconds; lengths mean +- std over 300 reqs)
TASKS: dict[str, TaskSpec] = {
    "medical_qa": TaskSpec("medical_qa", 0.7, 0.5, 32.57, 10.32, 38.92,
                           16.83, priority=0),
    "tldr_content_gen": TaskSpec("tldr_content_gen", 1.0, 0.7, 44.38, 6.58,
                                 96.04, 35.03, priority=1),
    "tldr_headline_gen": TaskSpec("tldr_headline_gen", 2.0, 0.9, 121.82,
                                  35.04, 13.59, 6.55, priority=2),
    "wikisql": TaskSpec("wikisql", 20.0, 1.0, 643.22, 337.01, 27.82, 4.84,
                        priority=3),
    "gsm8k": TaskSpec("gsm8k", 0.7, 0.2, 51.44, 15.78, 90.13, 26.73,
                      priority=0),
    "sharegpt": TaskSpec("sharegpt", 2.0, 0.5, 259.19, 324.88, 207.79,
                         234.99, priority=1),
}

FOUR_TASK_SET = ["medical_qa", "tldr_content_gen", "tldr_headline_gen",
                 "wikisql"]
TWO_TASK_SET = ["gsm8k", "sharegpt"]
