"""Request model and task classes (paper §3, Table 1)."""

from __future__ import annotations

import dataclasses
import math
from typing import Optional


@dataclasses.dataclass
class Request:
    rid: int
    task: str
    arrival: float
    l_in: int           # prompt length (tokens)
    l_out: int          # true output length — unknown to the scheduler
    ttft_slo: float     # seconds
    tpot_slo: float     # seconds per output token
    priority: Optional[int] = None  # for priority-based SLO mapping

    # ---- lifecycle (filled in by the runtime) ----
    dispatch_time: Optional[float] = None
    prefill_start: Optional[float] = None
    prefill_progress: int = 0     # prompt tokens prefilled (chunked plane)
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    tokens_done: int = 0
    prefill_worker: Optional[int] = None
    decode_worker: Optional[int] = None
    migrate_ready: Optional[float] = None  # KV transfer completion time

    # -- derived metrics ----------------------------------------------------
    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def tpot(self) -> Optional[float]:
        if self.finish_time is None or self.first_token_time is None:
            return None
        if self.l_out <= 1:
            return 0.0
        return (self.finish_time - self.first_token_time) / (self.l_out - 1)

    @property
    def e2e(self) -> Optional[float]:
        if self.finish_time is None:
            return None
        return self.finish_time - self.arrival

    def ttft_ok(self) -> bool:
        t = self.ttft
        return t is not None and t <= self.ttft_slo + 1e-9

    def tpot_ok(self) -> bool:
        t = self.tpot
        return t is not None and t <= self.tpot_slo + 1e-9

    def attained(self) -> bool:
        return self.ttft_ok() and self.tpot_ok()

    @property
    def cur_len(self) -> int:
        """Prefill + decoded tokens so far (l_cur in Eq. 2)."""
        return self.l_in + self.tokens_done

    def deadline(self) -> float:
        return self.arrival + self.ttft_slo


@dataclasses.dataclass(frozen=True)
class TaskSpec:
    """One benchmark task class (Table 1)."""

    name: str
    ttft_slo: float
    tpot_slo: float
    in_mean: float
    in_std: float
    out_mean: float
    out_std: float
    priority: int = 0

    def sample_lengths(self, rng) -> tuple[int, int]:
        l_in = max(1, int(rng.normal(self.in_mean, self.in_std)))
        l_out = max(1, int(rng.normal(self.out_mean, self.out_std)))
        return l_in, l_out


# Table 1 of the paper (SLOs in seconds; lengths mean +- std over 300 reqs)
TASKS: dict[str, TaskSpec] = {
    "medical_qa": TaskSpec("medical_qa", 0.7, 0.5, 32.57, 10.32, 38.92,
                           16.83, priority=0),
    "tldr_content_gen": TaskSpec("tldr_content_gen", 1.0, 0.7, 44.38, 6.58,
                                 96.04, 35.03, priority=1),
    "tldr_headline_gen": TaskSpec("tldr_headline_gen", 2.0, 0.9, 121.82,
                                  35.04, 13.59, 6.55, priority=2),
    "wikisql": TaskSpec("wikisql", 20.0, 1.0, 643.22, 337.01, 27.82, 4.84,
                        priority=3),
    "gsm8k": TaskSpec("gsm8k", 0.7, 0.2, 51.44, 15.78, 90.13, 26.73,
                      priority=0),
    "sharegpt": TaskSpec("sharegpt", 2.0, 0.5, 259.19, 324.88, 207.79,
                         234.99, priority=1),
}

FOUR_TASK_SET = ["medical_qa", "tldr_content_gen", "tldr_headline_gen",
                 "wikisql"]
TWO_TASK_SET = ["gsm8k", "sharegpt"]
