"""SLO-aware scaling controller (paper §6, Algorithm 3).

Every tau seconds the scaler computes a load metric

    LoadMetric = f(Utils, T_wait, R_in, R_process)

and scales out above epsilon_o / in below epsilon_i (sustained).  For
P/D-disaggregated deployments each pool is scaled independently and,
when demand diverges, an idle worker *switches roles* instead of
churning instances (engines are role-agnostic; links are bidirectional).

Cold starts use the Fast Scaling path: a warm pool of runtime-initialized
instances pulls weights D2D from a live WeightManager via the TLManager,
falling back to host-offload or disk (Table 2 strategies).

Workers are :class:`~repro.serving.backend.Backend` instances — the
scaler reads only Monitor snapshots and the protocol's ``waiting`` /
``running`` views, so the same instance scales simulated and
real-engine planes; the Cluster's worker factory decides which plane a
scaled-out replica lands on.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.monitor import Monitor
from repro.core.tlmanager import TLManager


@dataclasses.dataclass
class ScalerConfig:
    tau: float = 1.0              # scaling interval (Fig. 8 knob)
    eps_out: float = 0.85         # upper threshold
    eps_in: float = 0.25          # lower threshold
    sustain_in: int = 3           # consecutive low-load ticks before scale-in
    max_workers: int = 4
    min_workers: int = 1
    # "d2d" | "cpu" | "disk" (Table 2) | "auto" (pick the cheapest by
    # the TLManager's measured-or-analytic cost each scale-out)
    weight_strategy: str = "d2d"
    warm_pool: bool = True        # pre-initialized CPU runtimes
    warm_pool_size: int = 1       # concurrent warm runtimes held ready
    role_transition_time: float = 0.08  # P<->D flip (link/role flip only)


@dataclasses.dataclass
class ScaleAction:
    kind: str          # "out" | "in" | "role"
    role: str          # target role for the new/flipped worker
    delay: float       # provisioning latency before the worker serves
    worker_id: Optional[int] = None  # for "in"/"role"
    strategy: Optional[str] = None   # "out": weight transport chosen
    warm: bool = True                # "out": consumed a warm runtime


class Scaler:
    def __init__(self, cfg: ScalerConfig, monitor: Monitor, tl: TLManager,
                 model_cfg: ModelConfig, tp: int = 1, *,
                 load_calc=None, evacuate: bool = False):
        self.cfg = cfg
        self.monitor = monitor
        self.tl = tl
        self.model_cfg = model_cfg
        self.tp = tp
        # optional shared InstanceLoadCalculator: scale-in / role-flip
        # targets become the LEAST-loaded candidate instead of list
        # order.  evacuate=True (cluster live-migration mode) lets a
        # non-drained worker be targeted — the cluster migrates its
        # residents off and commits when it empties (migrate-then-flip
        # instead of drain-and-flip)
        self.load_calc = load_calc
        self.evacuate = evacuate
        self.last_decision = -1e18
        self._low_ticks: dict[str, int] = {}
        self.n_scale_out = 0
        self.n_scale_in = 0
        self.n_role_flips = 0
        # warm-pool occupancy: consumed at scale-out, replenished
        # runtime_warmup seconds later (a replacement runtime starts
        # initializing the moment one is taken) — concurrent
        # scale-outs beyond the pool pay the cold runtime init
        self._warm_free = cfg.warm_pool_size if cfg.warm_pool else 0
        self._warm_refill: list[float] = []

    # -- load metric ------------------------------------------------------------
    def load_metric(self, now: float, workers, queued) -> float:
        """f(Utils, T_wait, R_in, R_process) — normalized ~[0, 1.5]."""
        active = [w for w in workers if w.active]
        if not active:
            return 2.0
        utils = [
            (self.monitor.snapshot(w.wid).utilization
             if self.monitor.snapshot(w.wid) else 0.0)
            for w in active
        ]
        util_avg = sum(utils) / len(utils)
        # worst queued wait relative to its TTFT SLO
        wait_frac = 0.0
        for r in queued:
            frac = (now - r.arrival) / max(r.ttft_slo, 1e-6)
            wait_frac = max(wait_frac, frac)
        rate_ratio = self.monitor.rate_in / max(self.monitor.rate_done, 0.25)
        return max(util_avg,
                   min(wait_frac, 2.0) / 2.0,
                   min(rate_ratio, 2.0) / 2.0)

    # -- warm pool (Fast Scaling runtime pre-init) -------------------------------
    def warm_available(self, now: float) -> int:
        """Warm runtimes ready at ``now`` (matured refills folded in)."""
        ready = [t for t in self._warm_refill if t <= now]
        if ready:
            self._warm_refill = [t for t in self._warm_refill if t > now]
            self._warm_free = min(self._warm_free + len(ready),
                                  self.cfg.warm_pool_size)
        return self._warm_free

    def _take_warm(self, now: float) -> bool:
        """Consume one warm runtime; schedules its replacement's init.
        False when the pool is dry — that scale-out pays
        ``runtime_warmup`` on top of the weight transfer."""
        if not self.cfg.warm_pool or self.warm_available(now) <= 0:
            return False
        self._warm_free -= 1
        self._warm_refill.append(now + self.tl.costs.runtime_warmup)
        return True

    # -- provisioning path (Table 2) ---------------------------------------------
    def choose_strategy(self, has_donor: bool) -> str:
        """Pick the weight transport for this scale-out.  ``d2d``
        needs a live donor replica holding the weights — without one
        (scale-from-zero) it degrades to ``disk``.  ``auto`` takes the
        cheapest available path by the TLManager's measured-or-analytic
        cost model (probe only: no bytes booked)."""
        s = self.cfg.weight_strategy
        if s == "auto":
            cands = ["cpu", "disk"] + (["d2d"] if has_donor else [])
            return min(cands, key=lambda c: self.tl.weight_load_time(
                self.model_cfg, c, tp=self.tp, record=False))
        if s == "d2d" and not has_donor:
            return "disk"
        return s

    def provision_delay(self, now: float,
                        strategy: Optional[str] = None) -> tuple[float, bool]:
        """Provisioning latency for one scale-out at ``now``; consumes
        a warm runtime when one is ready.  Returns ``(delay, warm)``."""
        if strategy is None:
            strategy = self.cfg.weight_strategy
        warm = self._take_warm(now)
        t = self.tl.weight_load_time(
            self.model_cfg, strategy, tp=self.tp, warm=warm,
        )
        return t, warm


    # -- target selection ---------------------------------------------------------
    @staticmethod
    def _committed(ws) -> list:
        """Workers that will still serve this pool after in-flight
        actions settle: active and not being evacuated.  Pool-size
        guards count these — counting an evacuating worker would let a
        second flip empty the pool the first one is already leaving."""
        return [w for w in ws
                if w.active and not getattr(w, "evacuating", False)]

    def _scale_target(self, ws):
        """Scale-in / role-flip target.  Drained workers are free to
        take; with ``evacuate`` (live migration) a loaded worker may be
        targeted too — the cluster moves its residents off and commits
        when it drains.  Least-loaded first when a load calculator is
        wired, so the cheapest evacuation is always picked."""
        act = self._committed(ws)
        cands = [w for w in act if w.is_drained()]
        if not cands and self.evacuate:
            cands = act
        if not cands:
            return None
        if self.load_calc is not None:
            return min(cands, key=lambda w: (self.load_calc.load(w),
                                             w.wid))
        return cands[0]

    # -- Algorithm 3 --------------------------------------------------------------
    def tick(self, now: float, workers, queued, *,
             pool: str = "any") -> list[ScaleAction]:
        if now - self.last_decision < self.cfg.tau:
            return []
        self.last_decision = now
        actions: list[ScaleAction] = []
        pool_workers = [w for w in workers
                        if pool == "any" or w.role == pool]
        load = self.load_metric(now, pool_workers, queued)
        n_active = len(self._committed(pool_workers))
        n_total_active = sum(1 for w in workers if w.active)

        key = pool
        if load > self.cfg.eps_out:
            self._low_ticks[key] = 0
            if n_total_active < self.cfg.max_workers:
                strategy = self.choose_strategy(
                    has_donor=n_total_active > 0
                )
                delay, warm = self.provision_delay(now, strategy)
                actions.append(ScaleAction("out", pool, delay,
                                           strategy=strategy, warm=warm))
                self.n_scale_out += 1
        elif load < self.cfg.eps_in:
            self._low_ticks[key] = self._low_ticks.get(key, 0) + 1
            if (self._low_ticks[key] >= self.cfg.sustain_in
                    and n_active > self.cfg.min_workers):
                # active only: a deactivated-but-drained worker must
                # never be "scaled in" again (double-counts the action
                # and leaves the actually-loaded worker running)
                target = self._scale_target(pool_workers)
                if target is not None:
                    actions.append(
                        ScaleAction("in", pool, 0.0,
                                    worker_id=target.wid)
                    )
                    self.n_scale_in += 1
                    self._low_ticks[key] = 0
        else:
            self._low_ticks[key] = 0
        return actions

    # -- P/D coordinated tick -------------------------------------------------------
    def tick_pd(self, now: float, workers, prefill_queued,
                decode_queued) -> list[ScaleAction]:
        """Independent pool scaling + role transitions (paper §6)."""
        if now - self.last_decision < self.cfg.tau:
            return []
        self.last_decision = now
        p_pool = [w for w in workers if w.role == "prefill"]
        d_pool = [w for w in workers if w.role == "decode"]
        p_load = self.load_metric(now, p_pool, prefill_queued)
        d_load = self.load_metric(now, d_pool, decode_queued)
        actions: list[ScaleAction] = []
        n_active = sum(1 for w in workers if w.active)

        # role transitions first: avoid churn when demand diverges.
        # Without live migration only drained ACTIVE workers flip
        # (drain-and-flip: Backend.is_drained includes parked KV
        # awaiting migration); with evacuate the least-loaded worker is
        # targeted and the cluster migrates it empty (migrate-then-
        # flip).  Pool-size guards count committed active workers only —
        # deactivated replicas keep their role and would otherwise
        # inflate the pool, letting the last active worker flip away,
        # and an already-evacuating worker is leaving its pool.
        def n_act(ws):
            return len(self._committed(ws))

        if (p_load > self.cfg.eps_out and d_load < self.cfg.eps_in
                and n_act(d_pool) > self.cfg.min_workers):
            w = self._scale_target(d_pool)
            if w is not None:
                actions.append(ScaleAction(
                    "role", "prefill", self.cfg.role_transition_time,
                    worker_id=w.wid,
                ))
                self.n_role_flips += 1
                return actions
        if (d_load > self.cfg.eps_out and p_load < self.cfg.eps_in
                and n_act(p_pool) > self.cfg.min_workers):
            w = self._scale_target(p_pool)
            if w is not None:
                actions.append(ScaleAction(
                    "role", "decode", self.cfg.role_transition_time,
                    worker_id=w.wid,
                ))
                self.n_role_flips += 1
                return actions

        for role, load, pool, queued in (
            ("prefill", p_load, p_pool, prefill_queued),
            ("decode", d_load, d_pool, decode_queued),
        ):
            if load > self.cfg.eps_out and n_active < self.cfg.max_workers:
                strategy = self.choose_strategy(has_donor=n_active > 0)
                delay, warm = self.provision_delay(now, strategy)
                actions.append(ScaleAction("out", role, delay,
                                           strategy=strategy, warm=warm))
                self.n_scale_out += 1
                n_active += 1
            elif load < self.cfg.eps_in:
                k = role
                self._low_ticks[k] = self._low_ticks.get(k, 0) + 1
                if (self._low_ticks[k] >= self.cfg.sustain_in
                        and n_act(pool) > self.cfg.min_workers):
                    target = self._scale_target(pool)
                    if target is not None:
                        actions.append(ScaleAction(
                            "in", role, 0.0, worker_id=target.wid
                        ))
                        self.n_scale_in += 1
                        self._low_ticks[k] = 0
            else:
                self._low_ticks[role] = 0
        return actions
