"""Priority-based SLO mapping (paper §5.2, Algorithm 2, Eq. 6).

Derives absolute (TTFT, TPOT) targets for a request that only carries a
relative priority p in [0, N-1] (0 = highest):

1. sliding windows of the W most recent measured TTFT/TPOT values,
   kept value-sorted (so indexing = quantile selection);
2. index i_s = base + offset with base = sum_{i<p} C_i and
   offset = floor((p+1)/(N+1) * C_p) — higher priorities land on lower
   latency quantiles (Eq. 6);
3. queue-time-spike correction: subtract the extra queuing delay between
   the reference request and the last same-priority request;
4. clamp into the per-priority [min, max] band;
5. contention rule: while higher-priority requests are pending, lower
   priorities are pushed to their relaxed bound so strict ordering is
   preserved (this is what makes Fig. 6 work).
"""

from __future__ import annotations

import bisect
import collections
import dataclasses
from typing import Optional, Sequence


@dataclasses.dataclass(frozen=True)
class PriorityBand:
    min_ttft: float
    max_ttft: float
    min_tpot: float
    max_tpot: float


@dataclasses.dataclass
class _Record:
    value: float
    queue_time: float
    priority: int
    seq: int


class PrioritySLOMapper:
    def __init__(self, bands: Sequence[PriorityBand], window: int = 200):
        self.n = len(bands)
        self.bands = list(bands)
        self.window = window
        # value-sorted windows + FIFO for eviction
        self._ttft_sorted: list[tuple[float, float]] = []  # (ttft, q_time)
        self._tpot_sorted: list[float] = []
        self._fifo: collections.deque = collections.deque()
        self._counts = [0] * self.n
        self._last_queue_time = [0.0] * self.n
        self._seq = 0

    # -- observation of completed requests -----------------------------------
    def observe(self, priority: int, ttft: float, tpot: float,
                queue_time: float) -> None:
        self._seq += 1
        rec = _Record(ttft, queue_time, priority, self._seq)
        self._fifo.append((rec, tpot))
        bisect.insort(self._ttft_sorted, (ttft, queue_time))
        bisect.insort(self._tpot_sorted, tpot)
        self._counts[priority] += 1
        if len(self._fifo) > self.window:
            old, old_tpot = self._fifo.popleft()
            i = bisect.bisect_left(
                self._ttft_sorted, (old.value, old.queue_time)
            )
            if i < len(self._ttft_sorted):
                self._ttft_sorted.pop(i)
            j = bisect.bisect_left(self._tpot_sorted, old_tpot)
            if j < len(self._tpot_sorted):
                self._tpot_sorted.pop(j)
            self._counts[old.priority] -= 1

    # -- Eq. 6 indexing -------------------------------------------------------
    def _index(self, p: int) -> int:
        base = sum(self._counts[:p])
        offset = int((p + 1) / (self.n + 1) * self._counts[p])
        return base + offset

    # -- Algorithm 2 ----------------------------------------------------------
    def assign(self, priority: int, *,
               higher_priority_pending: bool = False) -> tuple[float, float]:
        band = self.bands[priority]
        if higher_priority_pending:
            # contention: strict prioritization — relax lower priorities
            # to their loosest bound to preserve capacity upstream.
            return band.max_ttft, band.max_tpot
        if not self._ttft_sorted:
            mid = lambda lo, hi: 0.5 * (lo + hi)  # noqa: E731
            return (mid(band.min_ttft, band.max_ttft),
                    mid(band.min_tpot, band.max_tpot))
        idx = min(self._index(priority), len(self._ttft_sorted) - 1)
        ttft, q_time = self._ttft_sorted[idx]
        tpot = self._tpot_sorted[min(idx, len(self._tpot_sorted) - 1)]
        # queue-time-spike correction
        dq = q_time - self._last_queue_time[priority]
        ttft = ttft - dq
        self._last_queue_time[priority] = q_time
        ttft = min(max(ttft, band.min_ttft), band.max_ttft)
        tpot = min(max(tpot, band.min_tpot), band.max_tpot)
        return ttft, tpot


def bands_from_tasks(specs, spread: float = 0.25) -> list[PriorityBand]:
    """Paper §7.3: median SLO targets +-25% per priority level."""
    out = []
    for s in sorted(specs, key=lambda t: t.priority):
        out.append(PriorityBand(
            min_ttft=s.ttft_slo * (1 - spread),
            max_ttft=s.ttft_slo * (1 + spread),
            min_tpot=s.tpot_slo * (1 - spread),
            max_tpot=s.tpot_slo * (1 + spread),
        ))
    return out
