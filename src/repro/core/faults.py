"""Deterministic fault injection for the serving plane.

Production serving lives or dies on how it handles replicas dying,
transfers dropping, and provisioning paths failing — none of which can
be *tested* unless every failure scenario is deterministic and
replayable.  :class:`FaultInjector` is a seeded, scriptable schedule of
faults the Cluster event loop consults at well-defined points:

- ``replica_crash(wid, t)`` — the replica's process dies at virtual
  time ``t``: it stops stepping immediately; the health watchdog
  (monitor cadence) detects the corpse and runs recovery.
- ``kv_transfer_drop(p)`` — each landing KV transfer (P/D hand-off or
  live decode-to-decode migration) is dropped with probability ``p``
  from a seeded stream, bounded by an optional injection ``max``.
- ``weight_load_fail(strategy, p)`` — a weight-provisioning attempt
  through ``strategy`` fails with probability ``p``; the cluster falls
  back along d2d -> cpu -> disk.
- ``straggler(wid, slowdown)`` — every step on ``wid`` takes
  ``slowdown``x its measured/modelled duration (optionally windowed
  ``[t, until)``), the grey-failure mode that never trips a crash
  detector.

The compact spec format (``serve --fault-schedule``) is
semicolon-separated entries of ``kind:key=value,...``::

    crash:wid=1,t=2.0;kv_drop:p=0.5,max=3;weight_fail:strategy=d2d,p=1.0
    straggler:wid=0,slowdown=4.0,t=1.0,until=6.0

Same seed + same event order -> identical fault decisions, so any
failure run replays bit-for-bit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class CrashEntry:
    wid: int
    t: float


@dataclasses.dataclass(frozen=True)
class StragglerEntry:
    wid: int
    slowdown: float
    t: float = 0.0
    until: float = math.inf


@dataclasses.dataclass
class FaultRecord:
    """One injected fault (the replayable audit log)."""

    t: float
    kind: str       # "crash" | "kv_drop" | "weight_fail" | "straggler"
    detail: str

    def __str__(self) -> str:  # timeline-friendly
        return f"{self.kind}:{self.detail}@{self.t:.3f}"


class FaultInjector:
    """Scriptable, seeded fault schedule consulted by the Cluster."""

    def __init__(self, *, crashes=(), kv_drop_p: float = 0.0,
                 kv_drop_max: Optional[int] = None,
                 weight_fail_p: Optional[dict] = None,
                 stragglers=(), seed: int = 0):
        self.crashes: list[CrashEntry] = [
            c if isinstance(c, CrashEntry) else CrashEntry(*c)
            for c in crashes
        ]
        if not 0.0 <= kv_drop_p <= 1.0:
            raise ValueError(f"kv_drop_p={kv_drop_p} not in [0, 1]")
        self.kv_drop_p = kv_drop_p
        self.kv_drop_max = kv_drop_max
        # strategy -> failure probability ("*" applies to any strategy)
        self.weight_fail_p: dict[str, float] = dict(weight_fail_p or {})
        for s, p in self.weight_fail_p.items():
            if not 0.0 <= p <= 1.0:
                raise ValueError(f"weight_fail[{s}]={p} not in [0, 1]")
        self.stragglers: list[StragglerEntry] = [
            s if isinstance(s, StragglerEntry) else StragglerEntry(*s)
            for s in stragglers
        ]
        # independent seeded streams per fault class: injecting one
        # class never perturbs another class's decisions, so adding a
        # crash to a schedule does not reshuffle which transfers drop
        self._rng_kv = np.random.default_rng(seed)
        self._rng_weight = np.random.default_rng(seed + 1)
        self.log: list[FaultRecord] = []
        self._n_kv_dropped = 0
        self._noted_stragglers: set[int] = set()

    # -- spec parsing ---------------------------------------------------------
    @classmethod
    def from_spec(cls, spec: str, seed: int = 0) -> "FaultInjector":
        """Parse the ``--fault-schedule`` string format (see module
        docstring).  Unknown entry kinds or malformed fields raise —
        a typo'd fault schedule must fail loudly, not silently run a
        fault-free benchmark."""
        crashes: list[CrashEntry] = []
        stragglers: list[StragglerEntry] = []
        kv_drop_p, kv_drop_max = 0.0, None
        weight_fail: dict[str, float] = {}
        for raw in spec.split(";"):
            raw = raw.strip()
            if not raw:
                continue
            kind, _, body = raw.partition(":")
            kind = kind.strip()
            kv: dict[str, str] = {}
            for pair in body.split(","):
                pair = pair.strip()
                if not pair:
                    continue
                k, sep, v = pair.partition("=")
                if not sep:
                    raise ValueError(
                        f"fault entry {raw!r}: expected key=value, "
                        f"got {pair!r}"
                    )
                kv[k.strip()] = v.strip()
            try:
                if kind == "crash":
                    crashes.append(CrashEntry(wid=int(kv["wid"]),
                                              t=float(kv["t"])))
                elif kind == "kv_drop":
                    kv_drop_p = float(kv["p"])
                    if "max" in kv:
                        kv_drop_max = int(kv["max"])
                elif kind == "weight_fail":
                    weight_fail[kv.get("strategy", "*")] = float(kv["p"])
                elif kind == "straggler":
                    stragglers.append(StragglerEntry(
                        wid=int(kv["wid"]),
                        slowdown=float(kv["slowdown"]),
                        t=float(kv.get("t", 0.0)),
                        until=float(kv.get("until", math.inf)),
                    ))
                else:
                    raise ValueError(
                        f"unknown fault kind {kind!r} (have: crash, "
                        f"kv_drop, weight_fail, straggler)"
                    )
            except KeyError as e:
                raise ValueError(
                    f"fault entry {raw!r} is missing field {e}"
                ) from None
        return cls(crashes=crashes, kv_drop_p=kv_drop_p,
                   kv_drop_max=kv_drop_max, weight_fail_p=weight_fail,
                   stragglers=stragglers, seed=seed)

    # -- queries (the Cluster's consultation points) ---------------------------
    def note(self, t: float, kind: str, detail: str) -> None:
        self.log.append(FaultRecord(t=t, kind=kind, detail=detail))

    @property
    def n_injected(self) -> int:
        return len(self.log)

    def drop_kv_transfer(self, now: float, rid: int,
                         src: int, dst: int) -> bool:
        """One seeded Bernoulli draw per landing transfer; records the
        injection when it fires."""
        if self.kv_drop_p <= 0.0:
            return False
        if (self.kv_drop_max is not None
                and self._n_kv_dropped >= self.kv_drop_max):
            return False
        if float(self._rng_kv.random()) >= self.kv_drop_p:
            return False
        self._n_kv_dropped += 1
        self.note(now, "kv_drop", f"rid={rid}:{src}->{dst}")
        return True

    def fail_weight_load(self, now: float, strategy: str) -> bool:
        p = self.weight_fail_p.get(strategy,
                                   self.weight_fail_p.get("*", 0.0))
        if p <= 0.0 or float(self._rng_weight.random()) >= p:
            return False
        self.note(now, "weight_fail", strategy)
        return True

    def slowdown(self, wid: int, now: float) -> float:
        """Step-duration multiplier for ``wid`` at ``now`` (>= 1.0;
        overlapping straggler windows compound)."""
        f = 1.0
        for i, s in enumerate(self.stragglers):
            if s.wid == wid and s.t <= now < s.until:
                f *= max(s.slowdown, 1.0)
                if i not in self._noted_stragglers:
                    # logged once per entry, at first application
                    self._noted_stragglers.add(i)
                    self.note(now, "straggler",
                              f"wid={s.wid}:x{s.slowdown:g}")
        return f

    def has_stragglers(self) -> bool:
        return bool(self.stragglers)
