"""Stage latency models (paper Eq. 1, Eq. 2, Appendix A).

    E_p = a + b * sum(l_in) + c * sum(l_in^2)        (prefill batch)
    E_d = a' + b' * sum(l_cur) + c' * B              (one decode step)

Two sources of coefficients:

- :class:`AnalyticLatencyModel` — roofline-derived ground truth for a
  model config on given hardware (used by the event simulator as the
  "real machine").  Prefill is compute-bound (b = 2*N_active / peak),
  decode is memory-bound (a' = weight bytes / HBM bw,
  b' = KV bytes/token / HBM bw).
- :class:`FittedLatencyModel` — least-squares fit from profiled
  (batch, lengths, t_p, t_d) samples, exactly the paper's profiler.
  Schedulers only ever see a *fitted* model, preserving the
  predictor-error structure of the real system.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    """Per-chip roofline constants (defaults: TPU v5e)."""

    peak_flops: float = 197e12       # bf16 FLOP/s
    hbm_bw: float = 819e9            # bytes/s
    ici_bw: float = 50e9             # bytes/s per link (D2D)
    disk_bw: float = 3.5e9           # bytes/s (weight loading)
    host_bw: float = 12e9            # bytes/s host->device
    flops_eff: float = 0.55          # achievable fraction of peak (prefill)
    bw_eff: float = 0.75             # achievable fraction of HBM bw
    # per-instance accelerator memory for KV-capacity accounting in the
    # serving simulator (the paper's Ascend NPUs carry 64 GB each)
    hbm_capacity: float = 64e9


TPU_V5E = Hardware()

# Ascend-NPU-calibrated profile for the paper's Table-2 hardware: the
# three published D2D times all imply ~16 GB/s effective per-device-pair
# bandwidth (15.4GB/0.89s, 32.5GB/2.05s, 17.6GB/1.16s).
ASCEND_910 = Hardware(ici_bw=20e9, host_bw=2.5e9, disk_bw=3.7e9)


@dataclasses.dataclass
class LatencyCoeffs:
    a: float   # prefill fixed overhead (s)
    b: float   # prefill per-token (s)
    c: float   # prefill per-token^2 (s)
    a_d: float  # decode fixed per step (s)
    b_d: float  # decode per cached token (s)
    c_d: float  # decode per sequence in batch (s)


class LatencyModel:
    """Eq. 1 / Eq. 2 evaluation given coefficients."""

    def __init__(self, coeffs: LatencyCoeffs):
        self.coeffs = coeffs

    def prefill_time(self, lens: Sequence[int]) -> float:
        if not len(lens):
            return 0.0
        k = self.coeffs
        s1 = float(sum(lens))
        s2 = float(sum(x * x for x in lens))
        return k.a + k.b * s1 + k.c * s2

    def decode_step_time(self, cur_lens: Sequence[int]) -> float:
        if not len(cur_lens):
            return 0.0
        k = self.coeffs
        return k.a_d + k.b_d * float(sum(cur_lens)) + k.c_d * len(cur_lens)

    def spec_step_time(self, cur_lens: Sequence[int],
                       n_spec_tokens: int) -> float:
        """Cost of one propose-verify speculative dispatch: a decode
        step widened by ``n_spec_tokens`` extra verify lanes, each
        priced at the prefill per-token rate (the verify pass is a
        short chunked prefill over the same weights)."""
        return (self.decode_step_time(cur_lens)
                + self.coeffs.b * max(0, int(n_spec_tokens)))

    # Convenience for Eq. 5 (token budget) — a, b of the prefill model.
    @property
    def a(self) -> float:
        return self.coeffs.a

    @property
    def b(self) -> float:
        return self.coeffs.b


class AnalyticLatencyModel(LatencyModel):
    """Ground-truth coefficients from the model/hardware roofline."""

    def __init__(self, cfg: ModelConfig, hw: Hardware = TPU_V5E,
                 tp: int = 1, dtype_bytes: int = 2):
        n_active = cfg.active_param_count()
        flops_rate = hw.peak_flops * hw.flops_eff * tp
        bw = hw.hbm_bw * hw.bw_eff * tp

        b = 2.0 * n_active / flops_rate
        # quadratic attention term per token^2 (4*L*H*hd flops / token^2)
        hd = cfg.resolved_head_dim
        n_attn_layers = sum(
            cnt for kind, cnt in cfg.layer_pattern()
            if kind not in ("mamba",)
        )
        c = 4.0 * n_attn_layers * cfg.n_heads * hd / flops_rate

        weight_bytes = cfg.active_param_count() * dtype_bytes
        a_d = weight_bytes / bw
        kv_bytes_per_tok = self._kv_bytes_per_token(cfg, dtype_bytes)
        b_d = kv_bytes_per_tok / bw
        # c' (per-sequence step overhead: sampling, batch bookkeeping,
        # kernel launches) ~1 ms/seq — this is what makes E_d grow with
        # batch size on the paper's NPUs and TPOT bind under load.
        super().__init__(LatencyCoeffs(
            a=0.003, b=b, c=c, a_d=a_d, b_d=b_d, c_d=1e-3,
        ))
        self.cfg = cfg
        self.hw = hw
        self.tp = tp

    @staticmethod
    def _kv_bytes_per_token(cfg: ModelConfig, dtype_bytes: int) -> float:
        hd = cfg.resolved_head_dim
        total = 0.0
        for kind, cnt in cfg.layer_pattern():
            if kind == "mamba":
                continue  # O(1) state: no per-token KV growth
            total += cnt * 2 * cfg.n_kv_heads * hd * dtype_bytes
        return total


class FittedLatencyModel(LatencyModel):
    """Least-squares fit from profiled samples (Appendix A)."""

    def __init__(self):
        super().__init__(LatencyCoeffs(0.0, 1e-4, 0.0, 0.0, 1e-6, 0.0))
        self._p_samples: list[tuple[float, float, float]] = []
        self._d_samples: list[tuple[float, float, float]] = []
        self.fitted = False

    def n_samples(self) -> int:
        """Total profiled observations (prefill + decode) — lets
        callers skip refitting when nothing new landed without reaching
        into the sample storage."""
        return len(self._p_samples) + len(self._d_samples)

    def observe_prefill(self, lens: Sequence[int], t: float) -> None:
        s1 = float(sum(lens))
        s2 = float(sum(x * x for x in lens))
        self._p_samples.append((s1, s2, t))

    def observe_decode(self, cur_lens: Sequence[int], t: float) -> None:
        self._d_samples.append(
            (float(sum(cur_lens)), float(len(cur_lens)), t)
        )

    def observe_decode_block(self, lens_per_iter: Sequence[Sequence[int]],
                             t: float) -> None:
        """Attribute one fused K-iteration decode block (wall time
        ``t``) as K per-iteration Eq. 2 samples of ``t / K`` each, so
        the fit stays comparable with per-token stepping.

        Wall time is attributed to *emitted* tokens only: trailing
        all-empty iterations (every row finished — or, under
        speculation, every lane past the accepted prefix was rejected)
        are trimmed before dividing ``t``, so rejected speculative
        lanes never dilute the per-iteration cost and bias the Eq. 5
        decode fit low (which would make admission over-promise).
        Interior empty iterations still carry no sample — their share
        of the wall time is engine overhead the intercept absorbs."""
        k = len(lens_per_iter)
        while k > 0 and not lens_per_iter[k - 1]:
            k -= 1
        if k == 0:
            return
        per = t / k
        for lens in lens_per_iter[:k]:
            if lens:
                self.observe_decode(lens, per)

    def fit(self, min_samples: int = 8) -> bool:
        ok = True
        if len(self._p_samples) >= min_samples:
            arr = np.asarray(self._p_samples)
            x = np.stack(
                [np.ones(len(arr)), arr[:, 0], arr[:, 1]], axis=1
            )
            # minimize squared *relative* error (paper App. A): weight rows
            w = 1.0 / np.maximum(arr[:, 2], 1e-6)
            sol, *_ = np.linalg.lstsq(
                x * w[:, None], arr[:, 2] * w, rcond=None
            )
            a, b, c = [max(0.0, float(v)) for v in sol]
            self.coeffs.a, self.coeffs.b, self.coeffs.c = a, b, c
        else:
            ok = False
        if len(self._d_samples) >= min_samples:
            arr = np.asarray(self._d_samples)
            x = np.stack(
                [np.ones(len(arr)), arr[:, 0], arr[:, 1]], axis=1
            )
            w = 1.0 / np.maximum(arr[:, 2], 1e-6)
            sol, *_ = np.linalg.lstsq(
                x * w[:, None], arr[:, 2] * w, rcond=None
            )
            a_d, b_d, c_d = [max(0.0, float(v)) for v in sol]
            self.coeffs.a_d, self.coeffs.b_d, self.coeffs.c_d = (
                a_d, b_d, c_d
            )
        else:
            ok = False
        self.fitted = ok
        return ok

    @classmethod
    def from_profile(cls, truth: LatencyModel, rng,
                     batch_sizes: Iterable[int] = (1, 2, 4, 8, 16, 32, 64,
                                                   96, 128, 160, 192),
                     input_lens: Iterable[int] = (4, 8, 16, 32, 48, 64, 96,
                                                  128, 192, 256, 284, 512,
                                                  768, 1024, 1536, 2020),
                     noise: float = 0.03) -> "FittedLatencyModel":
        """Paper App. A profiling sweep against a ground-truth model."""
        m = cls()
        for bs in batch_sizes:
            for li in input_lens:
                lens = [li] * bs
                tp = truth.prefill_time(lens) * rng.lognormal(0.0, noise)
                m.observe_prefill(lens, tp)
                td = truth.decode_step_time(lens) * rng.lognormal(0.0, noise)
                m.observe_decode(lens, td)
        m.fit()
        return m
