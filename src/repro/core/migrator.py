"""Migrator: decode-stage scheduler for P/D disaggregation (paper §5.1)
and the live decode-to-decode MigrationCoordinator built on the same
admission math.

Two-stage scheduling: the Dispatcher places the *prefill* stage only; a
request whose prefill completed enters the Migrator's queue, and the
decode instance is chosen **then**, against the decode pool's actual
load — fixing the two failure modes of one-shot dispatching (unknown
prefill completion time, unknown future decode load).

Decode workers are interruptible per iteration, so maturity is the end
of the current decode step.  Admission: a request joins worker w only if
the predicted next-step cost E_d(B ∪ {r}) stays within the tightest
TPOT of the merged batch and the KV cache fits.  The KV cache transfer
is costed by the TLManager and the request only joins the batch when the
transfer lands.

Both planners charge in-flight transfers to their destination through a
shared :class:`~repro.core.instance_load.ReservationLedger` — a request
whose ``kv_ready`` is scheduled but not yet ``accept_migrated`` is
invisible in the destination's ``running``/``waiting`` views, and
without the ledger successive passes overcommit one worker's KV and
TPOT budget (the engine plane then silently preempts-youngest).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.configs.base import ModelConfig
from repro.core.instance_load import (
    InstanceLoadCalculator,
    ReservationLedger,
)
from repro.core.latency_model import LatencyModel
from repro.core.monitor import Monitor
from repro.core.queues import RequestPriorityQueue
from repro.core.request import Request
from repro.core.tlmanager import TLManager


@dataclasses.dataclass
class MigratorConfig:
    headroom: float = 0.95   # fraction of TPOT the predicted E_d may use
    scan_limit: int = 256


class Migrator:
    def __init__(self, latency_model: LatencyModel, monitor: Monitor,
                 tl: TLManager, model_cfg: ModelConfig, tp: int = 1,
                 cfg: Optional[MigratorConfig] = None,
                 on_migrate: Optional[Callable] = None,
                 measure_bytes: Optional[Callable] = None,
                 ledger: Optional[ReservationLedger] = None):
        self.model = latency_model
        self.monitor = monitor
        self.tl = tl
        self.model_cfg = model_cfg
        self.tp = tp
        # None sentinel: a dataclass default evaluated in the signature
        # would be ONE shared object across every Migrator instance
        self.cfg = MigratorConfig() if cfg is None else cfg
        self.on_migrate = on_migrate
        # engine plane: returns the request's *measured* KV payload
        # bytes (None -> fall back to the analytic per-token estimate)
        self.measure_bytes = measure_bytes
        self.ledger = ledger if ledger is not None else ReservationLedger()
        self.queue = RequestPriorityQueue()  # prefilled, awaiting decode

    def on_prefill_complete(self, r: Request) -> None:
        self.queue.add(r)

    def pending(self) -> int:
        return len(self.queue)

    # -- the migration pass ------------------------------------------------------
    def migrate_pass(self, now: float, decode_workers) -> list[tuple]:
        """Assign prefilled requests to decode workers; returns
        [(request, worker, transfer_time), ...]."""
        out = []
        workers = [w for w in decode_workers if w.active]
        if not workers:
            return out
        led = self.ledger
        for i, r in enumerate(list(self.queue.scan())):
            if i >= self.cfg.scan_limit:
                break
            best = None
            best_slack = None
            for w in workers:
                # pending (in-flight) migrations count toward the load:
                # the ledger charges every scheduled-but-not-landed
                # transfer's tokens and TPOT to its destination
                lens = ([q.cur_len for q in w.running]
                        + [q.cur_len for q in w.waiting]
                        + led.lens(w.wid))
                if (w.kv_capacity - w.kv_tokens()
                        - led.tokens(w.wid)) < r.cur_len:
                    continue
                e_d = self.model.decode_step_time(lens + [r.cur_len])
                tpots = ([q.tpot_slo for q in w.running]
                         + [q.tpot_slo for q in w.waiting]
                         + led.tpots(w.wid)
                         + [r.tpot_slo])
                budget = min(tpots) * self.cfg.headroom
                slack = budget - e_d
                if slack >= 0 and (best_slack is None
                                   or slack > best_slack):
                    best, best_slack = w, slack
            if best is None:
                continue
            self.queue.remove(r)
            # worker id 0 is a valid prefill worker — never `or 0` here
            assert r.prefill_worker is not None, (
                f"request {r.rid} reached migrate_pass without a "
                f"prefill_worker; on_prefill_complete fired too early"
            )
            nbytes = (self.measure_bytes(r)
                      if self.measure_bytes is not None else None)
            t_x = self.tl.kv_transfer_time(
                self.model_cfg, r.l_in, src=r.prefill_worker,
                dst=best.wid, tp=self.tp, nbytes=nbytes,
            )
            led.reserve(best.wid, r)
            r.decode_worker = best.wid
            r.migrate_ready = now + t_x
            if self.on_migrate is not None:
                self.on_migrate(r, best, now, t_x)
            out.append((r, best, t_x))
        return out


@dataclasses.dataclass
class MigrationConfig:
    """Knobs for live decode-to-decode migration."""

    headroom: float = 0.95   # destination admission, same as Migrator
    trigger: float = 1.0     # pressure above which a replica sheds load
    max_moves: int = 4       # moves planned per pass
    cooldown: float = 0.25   # s a landed request is pinned before it
                             # may move again (anti-ping-pong)
    min_remaining: int = 4   # don't move nearly-finished requests: the
                             # transfer would outlive the stream


class MigrationCoordinator:
    """Victim/destination pairing for live decode-to-decode migration.

    Generalizes the Migrator's one-way prefill→decode hand-off: any
    *decoding* request can be checkpointed mid-stream (``export_kv``
    captures its newest tokens at transfer completion), moved with
    TLManager-costed bytes, and resumed token-identically.  Victims
    come from two places:

    - **evacuation** — every running request on a worker the Scaler
      targeted for scale-in or a role flip (migrate-then-flip instead
      of drain-and-flip);
    - **rescue** — workers whose :class:`InstanceLoadCalculator`
      pressure predicts a TPOT miss shed load until the predicted step
      fits the batch's tightest budget again (this is also what
      rebalances bursty ramps).

    Destinations are ranked by the shared load scalar among workers
    that pass the Migrator's admission math (reservations included),
    so migration never overcommits what dispatch is also filling.
    """

    def __init__(self, load_calc: InstanceLoadCalculator,
                 latency_model: LatencyModel, tl: TLManager,
                 model_cfg: ModelConfig, tp: int = 1,
                 cfg: Optional[MigrationConfig] = None,
                 measure_bytes: Optional[Callable] = None):
        self.load_calc = load_calc
        self.ledger = load_calc.ledger
        self.model = latency_model
        self.tl = tl
        self.model_cfg = model_cfg
        self.tp = tp
        self.cfg = MigrationConfig() if cfg is None else cfg
        # engine plane: (request, src_wid) -> measured payload bytes
        self.measure_bytes = measure_bytes
        self.n_rescues = 0
        self.n_evacuations = 0

    # -- admission (same math as Migrator.migrate_pass) ---------------------------
    def _dest_ok(self, r: Request, w) -> bool:
        led = self.ledger
        if (w.kv_capacity - w.kv_tokens()
                - led.tokens(w.wid)) < r.cur_len:
            return False
        lens = ([q.cur_len for q in w.running]
                + [q.cur_len for q in w.waiting]
                + led.lens(w.wid))
        e_d = self.model.decode_step_time(lens + [r.cur_len])
        tpots = ([q.tpot_slo for q in w.running]
                 + [q.tpot_slo for q in w.waiting]
                 + led.tpots(w.wid)
                 + [r.tpot_slo])
        return e_d <= min(tpots) * self.cfg.headroom

    def _movable(self, r: Request, now: float) -> bool:
        if r.migrating or r.kv_payload is not None:
            return False
        if r.l_out - r.tokens_done < self.cfg.min_remaining:
            return False
        if (r.last_migrated is not None
                and now - r.last_migrated < self.cfg.cooldown):
            return False
        return True

    def _rescue_victims(self, src, now: float) -> list[Request]:
        """Shed just enough of ``src``'s decode batch to bring the
        predicted step back under the tightest remaining TPOT budget.
        Loosest-TPOT, longest-context requests go first: they have the
        most slack to survive the transfer and removing them shrinks
        E_d the most."""
        remaining = list(src.running)
        out: list[Request] = []
        for r in sorted(src.running,
                        key=lambda q: (-q.tpot_slo, -q.cur_len)):
            lens = [q.cur_len for q in remaining]
            tpots = [q.tpot_slo for q in remaining]
            if not lens or (self.model.decode_step_time(lens)
                            <= min(tpots) * self.cfg.headroom):
                break
            if not self._movable(r, now):
                continue
            out.append(r)
            remaining.remove(r)
        return out

    # -- the planning pass --------------------------------------------------------
    def plan(self, now: float, workers,
             evacuating=()) -> list[tuple]:
        """One planning pass; returns
        [(request, src_worker, dst_worker, transfer_time, reason), ...].
        Reserves each move on its destination — the caller schedules
        the transfer and releases the reservation at ``kv_ready``."""
        evac = set(evacuating)

        def is_evac(w) -> bool:
            return w.wid in evac or getattr(w, "evacuating", False)

        dests = [w for w in workers
                 if w.active and not is_evac(w)
                 and w.role in ("decode", "collocated")]
        moves: list[tuple] = []
        for src in workers:
            if len(moves) >= self.cfg.max_moves:
                break
            if not src.active:
                continue
            if is_evac(src):
                victims = [r for r in src.running
                           if self._movable(r, now)]
                reason = "evac"
            elif (src.role in ("decode", "collocated")
                    and src.running
                    and self.load_calc.pressure(src) > self.cfg.trigger):
                victims = self._rescue_victims(src, now)
                reason = "rescue"
            else:
                continue
            pool = [w for w in dests if w.wid != src.wid]
            for r in victims:
                if len(moves) >= self.cfg.max_moves:
                    break
                cands = [w for w in pool if self._dest_ok(r, w)]
                if not cands:
                    continue
                best = min(cands, key=lambda w: (self.load_calc.load(w),
                                                 w.wid))
                nbytes = (self.measure_bytes(r, src.wid)
                          if self.measure_bytes is not None else None)
                t_x = self.tl.kv_transfer_time(
                    self.model_cfg, r.cur_len, src=src.wid,
                    dst=best.wid, tp=self.tp, nbytes=nbytes,
                )
                self.ledger.reserve(best.wid, r)
                r.migrating = True
                if reason == "evac":
                    self.n_evacuations += 1
                else:
                    self.n_rescues += 1
                moves.append((r, src, best, t_x, reason))
        return moves
