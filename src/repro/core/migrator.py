"""Migrator: decode-stage scheduler for P/D disaggregation (paper §5.1).

Two-stage scheduling: the Dispatcher places the *prefill* stage only; a
request whose prefill completed enters the Migrator's queue, and the
decode instance is chosen **then**, against the decode pool's actual
load — fixing the two failure modes of one-shot dispatching (unknown
prefill completion time, unknown future decode load).

Decode workers are interruptible per iteration, so maturity is the end
of the current decode step.  Admission: a request joins worker w only if
the predicted next-step cost E_d(B ∪ {r}) stays within the tightest
TPOT of the merged batch and the KV cache fits.  The KV cache transfer
is costed by the TLManager and the request only joins the batch when the
transfer lands.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Optional

from repro.configs.base import ModelConfig
from repro.core.latency_model import LatencyModel
from repro.core.monitor import Monitor
from repro.core.queues import RequestPriorityQueue
from repro.core.request import Request
from repro.core.tlmanager import TLManager


@dataclasses.dataclass
class MigratorConfig:
    headroom: float = 0.95   # fraction of TPOT the predicted E_d may use
    scan_limit: int = 256


class Migrator:
    def __init__(self, latency_model: LatencyModel, monitor: Monitor,
                 tl: TLManager, model_cfg: ModelConfig, tp: int = 1,
                 cfg: MigratorConfig = MigratorConfig(),
                 on_migrate: Optional[Callable] = None,
                 measure_bytes: Optional[Callable] = None):
        self.model = latency_model
        self.monitor = monitor
        self.tl = tl
        self.model_cfg = model_cfg
        self.tp = tp
        self.cfg = cfg
        self.on_migrate = on_migrate
        # engine plane: returns the request's *measured* KV payload
        # bytes (None -> fall back to the analytic per-token estimate)
        self.measure_bytes = measure_bytes
        self.queue = RequestPriorityQueue()  # prefilled, awaiting decode

    def on_prefill_complete(self, r: Request) -> None:
        self.queue.add(r)

    def pending(self) -> int:
        return len(self.queue)

    # -- the migration pass ------------------------------------------------------
    def migrate_pass(self, now: float, decode_workers) -> list[tuple]:
        """Assign prefilled requests to decode workers; returns
        [(request, worker, transfer_time), ...]."""
        out = []
        workers = [w for w in decode_workers if w.active]
        if not workers:
            return out
        for i, r in enumerate(list(self.queue.scan())):
            if i >= self.cfg.scan_limit:
                break
            best = None
            best_slack = None
            for w in workers:
                # pending (in-flight) migrations count toward the load
                lens = [q.cur_len for q in w.running] + [
                    q.cur_len for q in w.waiting
                ]
                if w.kv_capacity - w.kv_tokens() < r.cur_len:
                    continue
                e_d = self.model.decode_step_time(lens + [r.cur_len])
                tpots = [q.tpot_slo for q in w.running] + [
                    q.tpot_slo for q in w.waiting
                ] + [r.tpot_slo]
                budget = min(tpots) * self.cfg.headroom
                slack = budget - e_d
                if slack >= 0 and (best_slack is None
                                   or slack > best_slack):
                    best, best_slack = w, slack
            if best is None:
                continue
            self.queue.remove(r)
            # worker id 0 is a valid prefill worker — never `or 0` here
            assert r.prefill_worker is not None, (
                f"request {r.rid} reached migrate_pass without a "
                f"prefill_worker; on_prefill_complete fired too early"
            )
            nbytes = (self.measure_bytes(r)
                      if self.measure_bytes is not None else None)
            t_x = self.tl.kv_transfer_time(
                self.model_cfg, r.l_in, src=r.prefill_worker,
                dst=best.wid, tp=self.tp, nbytes=nbytes,
            )
            r.decode_worker = best.wid
            r.migrate_ready = now + t_x
            if self.on_migrate is not None:
                self.on_migrate(r, best, now, t_x)
            out.append((r, best, t_x))
        return out
