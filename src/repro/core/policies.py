"""Scheduling policies: HyperFlexis (Algorithm 1) + the paper's baselines.

Baselines (§7.4), re-implemented against the same worker/latency
abstractions so comparisons are apples-to-apples:

- ROUND ROBIN (Llumnix+RR in the paper): immediate cyclic assignment.
- SCORPIO-like: deadline(EDF)-ordered queue + admission control against
  the predicted prefill completion, with a per-dispatch token cap
  (credit-aware batching, simplified).
- ALADDIN-like: best-fit bin packing on the predicted token budget.
- SIMULATED ANNEALING (Huang et al.): periodic batch assignment via SA
  minimizing predicted SLO violations.

Every policy exposes on_request_arrive / dispatch_pass / next_wakeup /
add_worker / remove_worker, so the cluster loop is policy-agnostic.
"""

from __future__ import annotations

import math
from typing import Callable, Optional

import numpy as np

from repro.core.dispatcher import (
    AdmissionVerdict,
    Dispatcher,
    DispatcherConfig,
)
from repro.core.latency_model import LatencyModel
from repro.core.monitor import Monitor
from repro.core.request import Request


class BasePolicy:
    name = "base"

    def __init__(self, latency_model: LatencyModel, monitor: Monitor,
                 on_dispatch: Callable, load_calc=None):
        self.model = latency_model
        self.monitor = monitor
        self.on_dispatch = on_dispatch
        # optional shared InstanceLoadCalculator (HyperFlexis threads it
        # into the Dispatcher; baselines ignore it by design — they ARE
        # the no-load-signal comparison points)
        self.load_calc = load_calc
        self.workers: list = []
        self.queue: list[Request] = []

    def add_worker(self, worker, now: float) -> None:
        self.workers.append(worker)

    def remove_worker(self, wid: int) -> None:
        self.workers = [w for w in self.workers if w.wid != wid]

    def on_request_arrive(self, r: Request) -> None:
        self.queue.append(r)

    def pending(self) -> int:
        return len(self.queue)

    def queued_requests(self):
        return list(self.queue)

    def drop_request(self, r: Request) -> None:
        """Remove a still-queued request (fault recovery sheds work
        that lost its last possible placement).  No-op if absent."""
        if r in self.queue:
            self.queue.remove(r)

    def next_wakeup(self) -> Optional[float]:
        return None

    def notify_worker_free(self, wid: int, now: float) -> None:
        pass

    def admission_verdict(self, r: Request, now: float) -> AdmissionVerdict:
        """Submit-time admit/reject estimate.  Baselines carry no
        proactive budget estimator, so they admit everything — only
        HyperFlexis (Algorithm 1) can refuse a doomed request at the
        front door."""
        return AdmissionVerdict(True, 1.0,
                                reason="policy has no budget estimator")

    def dispatch_pass(self, now: float):  # pragma: no cover - interface
        raise NotImplementedError


class HyperFlexisPolicy(BasePolicy):
    """Algorithm 1 via the core Dispatcher."""

    name = "hyperflexis"

    def __init__(self, latency_model, monitor, on_dispatch,
                 cfg: Optional[DispatcherConfig] = None, load_calc=None):
        # cfg defaults to None, not DispatcherConfig(): a default built
        # in the signature is evaluated once at import and shared by
        # every policy instance (Dispatcher builds its own fresh one)
        super().__init__(latency_model, monitor, on_dispatch,
                         load_calc=load_calc)
        self.dispatcher = Dispatcher(
            latency_model, monitor, cfg, on_dispatch=on_dispatch,
            load_calc=load_calc,
        )

    def add_worker(self, worker, now: float) -> None:
        super().add_worker(worker, now)
        self.dispatcher.add_worker(worker, now)

    def remove_worker(self, wid: int) -> None:
        super().remove_worker(wid)
        self.dispatcher.remove_worker(wid)

    def on_request_arrive(self, r: Request) -> None:
        self.dispatcher.on_request_arrive(r)

    def pending(self) -> int:
        return self.dispatcher.pending()

    def queued_requests(self):
        return self.dispatcher.qr.items()

    def drop_request(self, r: Request) -> None:
        self.dispatcher.qr.remove(r)

    def next_wakeup(self):
        return self.dispatcher.next_wakeup()

    def notify_worker_free(self, wid: int, now: float) -> None:
        self.dispatcher.notify_worker_free(wid, now)

    def admission_verdict(self, r: Request, now: float) -> AdmissionVerdict:
        return self.dispatcher.admission_verdict(r, now)

    def dispatch_pass(self, now: float):
        return self.dispatcher.dispatch_pass(now)


class RoundRobinPolicy(BasePolicy):
    name = "rr"

    def __init__(self, latency_model, monitor, on_dispatch,
                 load_calc=None):
        super().__init__(latency_model, monitor, on_dispatch,
                         load_calc=load_calc)
        self._next = 0

    def dispatch_pass(self, now: float):
        done = []
        active = [w for w in self.workers if w.active]
        if not active:
            return done
        while self.queue:
            r = self.queue.pop(0)
            w = active[self._next % len(active)]
            self._next += 1
            r.dispatch_time = now
            self.on_dispatch(w, [r], now)
            done.append((w, [r]))
        return done


class ScorpioPolicy(BasePolicy):
    """EDF + admission control + token-capped batching (simplified)."""

    name = "scorpio"

    def __init__(self, latency_model, monitor, on_dispatch,
                 batch_token_cap: int = 8192, load_calc=None):
        super().__init__(latency_model, monitor, on_dispatch,
                         load_calc=load_calc)
        self.cap = batch_token_cap

    def dispatch_pass(self, now: float):
        done = []
        active = [w for w in self.workers if w.active]
        if not active:
            return done
        self.queue.sort(key=lambda r: r.deadline())
        remaining: list[Request] = []
        batches: dict[int, list[Request]] = {w.wid: [] for w in active}
        by_wid = {w.wid: w for w in active}
        for r in self.queue:
            best, best_t = None, None
            for w in active:
                lens = ([q.l_in for q in w.waiting]
                        + [q.l_in for q in batches[w.wid]] + [r.l_in])
                if sum(lens) > self.cap:
                    continue
                if w.kv_capacity - w.kv_tokens() < r.l_in:
                    continue
                t_done = max(now, w.busy_until) + self.model.prefill_time(
                    lens
                )
                if best_t is None or t_done < best_t:
                    best, best_t = w, t_done
            admit = (best is not None
                     and (best_t <= r.deadline() or r.deadline() <= now))
            if admit:
                batches[best.wid].append(r)
            else:
                remaining.append(r)
        self.queue = remaining
        for wid, batch in batches.items():
            if batch:
                for r in batch:
                    r.dispatch_time = now
                self.on_dispatch(by_wid[wid], batch, now)
                done.append((by_wid[wid], batch))
        return done


class AladdinPolicy(BasePolicy):
    """Joint placement: best-fit bin packing among workers whose
    predicted prefill completion still meets the request deadline."""

    name = "aladdin"

    def dispatch_pass(self, now: float):
        done = []
        active = [w for w in self.workers if w.active]
        if not active:
            return done
        pending = sorted(self.queue, key=lambda r: -r.l_in)  # FFD-style
        self.queue = []
        leftovers = []
        placed: dict[int, list[Request]] = {w.wid: [] for w in active}
        by_wid = {w.wid: w for w in active}
        head = {w.wid: w.kv_capacity - w.kv_tokens() for w in active}
        for r in pending:
            feasible = []
            fallback = []
            for w in active:
                if head[w.wid] < r.l_in:
                    continue
                lens = ([q.l_in for q in w.waiting]
                        + [q.l_in for q in placed[w.wid]] + [r.l_in])
                t_done = max(now, w.busy_until) + self.model.prefill_time(
                    lens
                )
                item = (head[w.wid] - r.l_in, t_done, w.wid)
                fallback.append((t_done, w.wid))
                if t_done <= r.deadline():
                    feasible.append(item)
            if feasible:
                _, _, wid = min(feasible)  # tightest feasible fit
            elif fallback:
                _, wid = min(fallback)     # earliest finish otherwise
            else:
                leftovers.append(r)
                continue
            placed[wid].append(r)
            head[wid] -= r.l_in
        self.queue = leftovers
        for wid, batch in placed.items():
            if batch:
                for r in batch:
                    r.dispatch_time = now
                self.on_dispatch(by_wid[wid], batch, now)
                done.append((by_wid[wid], batch))
        return done


class SAPolicy(BasePolicy):
    """Simulated-annealing batch scheduler (Huang et al., simplified)."""

    name = "sa"

    def __init__(self, latency_model, monitor, on_dispatch,
                 iters: int = 200, seed: int = 0, load_calc=None):
        super().__init__(latency_model, monitor, on_dispatch,
                         load_calc=load_calc)
        self.iters = iters
        self.rng = np.random.default_rng(seed)

    def _violations(self, assign, reqs, active, now) -> float:
        score = 0.0
        for wi, w in enumerate(active):
            batch = [r for r, a in zip(reqs, assign) if a == wi]
            if not batch:
                continue
            lens = [q.l_in for q in w.waiting] + [r.l_in for r in batch]
            t_done = max(now, w.busy_until) + self.model.prefill_time(lens)
            for r in batch:
                if t_done > r.deadline():
                    score += 1.0
            # decode pressure
            cur = [q.cur_len for q in w.running]
            e_d = self.model.decode_step_time(
                cur + [r.l_in for r in batch]
            )
            tpots = [q.tpot_slo for q in w.running] + [
                r.tpot_slo for r in batch
            ]
            if tpots and e_d > min(tpots):
                score += 0.5 * len(batch)
        return score

    def dispatch_pass(self, now: float):
        done = []
        active = [w for w in self.workers if w.active]
        if not active or not self.queue:
            return done
        reqs = self.queue[:64]
        self.queue = self.queue[64:]
        n, k = len(reqs), len(active)
        assign = self.rng.integers(0, k, size=n)
        best = assign.copy()
        best_score = self._violations(assign, reqs, active, now)
        temp = 1.0
        for _ in range(self.iters):
            cand = best.copy()
            cand[self.rng.integers(0, n)] = self.rng.integers(0, k)
            s = self._violations(cand, reqs, active, now)
            if (s < best_score
                    or self.rng.random() < math.exp(
                        -(s - best_score) / max(temp, 1e-3))):
                best, best_score = cand, s
            temp *= 0.98
        batches: dict[int, list[Request]] = {w.wid: [] for w in active}
        for r, a in zip(reqs, best):
            batches[active[a].wid].append(r)
        by_wid = {w.wid: w for w in active}
        for wid, batch in batches.items():
            if batch:
                for r in batch:
                    r.dispatch_time = now
                self.on_dispatch(by_wid[wid], batch, now)
                done.append((by_wid[wid], batch))
        return done


POLICIES = {
    "hyperflexis": HyperFlexisPolicy,
    "rr": RoundRobinPolicy,
    "scorpio": ScorpioPolicy,
    "aladdin": AladdinPolicy,
    "sa": SAPolicy,
}


def make_policy(name: str, latency_model, monitor, on_dispatch,
                **kw) -> BasePolicy:
    return POLICIES[name](latency_model, monitor, on_dispatch, **kw)
