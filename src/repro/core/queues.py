"""Priority queues of Algorithm 1.

- RequestPriorityQueue (Q_R): requests ordered by (TPOT SLO, arrival).
- WorkerPriorityQueue (Q_W): workers ordered by maturity time.
"""

from __future__ import annotations

import heapq
import itertools
from typing import Callable, Iterator, Optional

from repro.core.request import Request


class RequestPriorityQueue:
    """Sorted by (tpot_slo, arrival); supports scan + selective removal."""

    def __init__(self):
        self._heap: list[tuple[float, float, int, Request]] = []
        self._removed: set[int] = set()
        self._count = itertools.count()

    def add(self, r: Request) -> None:
        if r.rid in self._removed:
            # re-admission (e.g. a migration destination vanished and
            # the request was requeued): clear the tombstone, and purge
            # stale heap entries so the rid can't be yielded twice
            self._removed.discard(r.rid)
            self._heap = [e for e in self._heap if e[3].rid != r.rid]
            heapq.heapify(self._heap)
        heapq.heappush(
            self._heap, (r.tpot_slo, r.arrival, next(self._count), r)
        )

    def __len__(self) -> int:
        return sum(
            1 for *_k, r in self._heap if r.rid not in self._removed
        )

    def __bool__(self) -> bool:
        self._compact()
        return bool(self._heap)

    def _compact(self) -> None:
        while self._heap and self._heap[0][3].rid in self._removed:
            heapq.heappop(self._heap)

    def scan(self) -> Iterator[Request]:
        """Iterate in priority order without removing."""
        for item in sorted(self._heap):
            r = item[3]
            if r.rid not in self._removed:
                yield r

    def remove(self, r: Request) -> None:
        self._removed.add(r.rid)
        self._compact()

    def peek(self) -> Optional[Request]:
        self._compact()
        return self._heap[0][3] if self._heap else None

    def items(self) -> list[Request]:
        return list(self.scan())


class WorkerPriorityQueue:
    """Min-heap of workers keyed by maturity time."""

    def __init__(self):
        self._heap: list[tuple[float, int, object]] = []
        self._count = itertools.count()

    def push(self, worker, maturity: float) -> None:
        heapq.heappush(self._heap, (maturity, next(self._count), worker))

    def pop(self):
        if not self._heap:
            return None, None
        maturity, _, w = heapq.heappop(self._heap)
        return w, maturity

    def peek(self):
        if not self._heap:
            return None, None
        maturity, _, w = self._heap[0]
        return w, maturity

    def __len__(self) -> int:
        return len(self._heap)

    def clear(self) -> None:
        self._heap.clear()
