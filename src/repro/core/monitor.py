"""Monitor subsystem (paper §4): periodic telemetry snapshots.

The dispatcher/migrator/scaler never read live worker state directly —
they read the last snapshot, refreshed every `interval` seconds (the
knob ablated in Fig. 8).  Between snapshots the dispatcher layers its
own *shadow* updates (requests it just dispatched) on top.
"""

from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass
class WorkerSnapshot:
    wid: int
    role: str
    time: float
    busy: bool
    n_waiting: int            # requests waiting for prefill
    n_running: int            # requests in the decode batch
    kv_tokens: int            # tokens resident in KV cache
    cur_lens: tuple           # current lengths of the decode batch
    waiting_tokens: int       # prompt tokens awaiting prefill
    utilization: float        # busy fraction over the last interval


class Monitor:
    def __init__(self, interval: float = 0.05):
        self.interval = interval
        self.snapshots: dict[int, WorkerSnapshot] = {}
        self.history: list[tuple[float, float]] = []  # (time, mean util)
        self._last_busy: dict[int, float] = {}
        self._last_time: Optional[float] = None
        # arrival / completion rates over the last interval
        self.rate_in = 0.0
        self.rate_done = 0.0
        self._arrivals = 0
        self._completions = 0

    def note_arrival(self) -> None:
        self._arrivals += 1

    def note_completion(self) -> None:
        self._completions += 1

    def update(self, now: float, workers) -> None:
        """Refresh snapshots.  Workers are Backends (sim or engine);
        each one renders its own WorkerSnapshot, so the Monitor never
        reaches into plane-specific state."""
        dt = (now - self._last_time) if self._last_time is not None else None
        utils = []
        for w in workers:
            if dt and dt > 0:
                busy_delta = w.busy_time - self._last_busy.get(w.wid, 0.0)
                util = min(1.0, busy_delta / dt)
            else:
                util = 1.0 if w.is_busy(now) else 0.0
            self._last_busy[w.wid] = w.busy_time
            utils.append(util)
            self.snapshots[w.wid] = w.snapshot(now, util)
        if dt and dt > 0:
            self.rate_in = self._arrivals / dt
            self.rate_done = self._completions / dt
            self._arrivals = 0
            self._completions = 0
            if utils:
                self.history.append((now, sum(utils) / len(utils)))
        self._last_time = now

    def snapshot(self, wid: int) -> Optional[WorkerSnapshot]:
        return self.snapshots.get(wid)

    def mean_utilization(self) -> float:
        if not self.snapshots:
            return 0.0
        vals = [s.utilization for s in self.snapshots.values()]
        return sum(vals) / len(vals)
