"""Token budget n_token (paper Eq. 5 / Appendix B).

The budget bounds how many *prompt* tokens a worker can accept so that,
in the worst case (a request arriving right after a dispatch), the
prefill stall amortized over decode iterations still meets the tightest
TTFT/TPOT at the worker:

    n_token <= (TTFT*TPOT - TTFT*E_d - a*TPOT) / (b*TPOT)

where (a, b) are the prefill-model coefficients and E_d the estimated
per-iteration decode cost of ongoing requests.
"""

from __future__ import annotations

from typing import Optional

from repro.core.latency_model import LatencyModel


def ntoken_limit(ttft: float, tpot: float, e_d: float,
                 model: LatencyModel) -> int:
    """Eq. 5.  Returns 0 when the worker cannot take any prompt tokens."""
    if tpot <= e_d:
        # No decode slack: any prefill stall would violate TPOT.
        return 0
    a, b = model.a, model.b
    if b <= 0:
        return 1_000_000_000
    n = (ttft * tpot - ttft * e_d - a * tpot) / (b * tpot)
    return max(0, int(n))


def chunk_schedule(l_in: int, chunk_tokens: Optional[int]) -> list[int]:
    """Split a prompt into bounded prefill chunks (last may be short).

    The per-chunk bound is how both execution planes (engine and
    simulator) keep a long prompt's prefill stall inside the Eq. 5
    decode slack: each chunk interleaves with one decode iteration.
    ``chunk_tokens`` None (or >= l_in) degenerates to monolithic.
    """
    if l_in <= 0:
        return []
    if chunk_tokens is None or chunk_tokens >= l_in:
        return [l_in]
    assert chunk_tokens > 0
    full, rem = divmod(l_in, chunk_tokens)
    return [chunk_tokens] * full + ([rem] if rem else [])


def maturity_interval(e_p: float, e_d: float, min_tpot: float) -> float:
    """Worker 'next maturity' advance (Algorithm 1 last lines).

    relax = min TPOT among (waiting + new + running) minus E_d is the
    per-iteration slack; the prefill stall E_p is amortized over
    E_p / relax iterations, each costing E_d.
    """
    relax = min_tpot - e_d
    if relax <= 1e-9:
        # no slack: the worker must drain decode before new prefill
        return e_p + 100.0 * e_d
    return e_p + (e_p / relax) * e_d
