from repro.core.dispatcher import Dispatcher, DispatcherConfig  # noqa: F401
from repro.core.latency_model import (  # noqa: F401
    AnalyticLatencyModel,
    FittedLatencyModel,
    Hardware,
    LatencyModel,
    TPU_V5E,
)
from repro.core.migrator import Migrator, MigratorConfig  # noqa: F401
from repro.core.monitor import Monitor  # noqa: F401
from repro.core.request import Request, TaskSpec, TASKS  # noqa: F401
from repro.core.scaler import ScaleAction, Scaler, ScalerConfig  # noqa: F401
from repro.core.slo_mapper import (  # noqa: F401
    PriorityBand,
    PrioritySLOMapper,
    bands_from_tasks,
)
from repro.core.tlmanager import TLManager, TransferCosts  # noqa: F401
from repro.core.token_budget import maturity_interval, ntoken_limit  # noqa: F401
