"""Train-step builder: remat+scan models, microbatch gradient
accumulation, optional manual compressed cross-pod gradient sync."""

from __future__ import annotations

import dataclasses
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.distributed.compression import pod_manual_value_and_grad
from repro.models.build import Model
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_init, adamw_update


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    adamw: AdamWConfig = AdamWConfig()
    micro_batches: int = 1
    grad_compression: bool = False  # manual int8 pod-axis grad sync


def _accumulate_grads(loss_fn, params, batch, n_micro: int):
    """Gradient accumulation via scan over microbatches (memory ~1/n)."""
    def split(x):
        b = x.shape[0]
        assert b % n_micro == 0, (b, n_micro)
        return x.reshape((n_micro, b // n_micro) + x.shape[1:])

    micro = jax.tree.map(split, batch)
    zero = jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )

    def body(carry, mb):
        loss_acc, grads_acc = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grads_acc = jax.tree.map(
            lambda a, g: a + g.astype(jnp.float32), grads_acc, grads
        )
        return (loss_acc + loss, grads_acc), None

    (loss_sum, grads_sum), _ = jax.lax.scan(body, (0.0, zero), micro)
    inv = 1.0 / n_micro
    return loss_sum * inv, jax.tree.map(lambda g: g * inv, grads_sum)


def build_train_step(model: Model, cfg: TrainConfig = TrainConfig(),
                     mesh: Optional[Any] = None):
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    vg = None
    if cfg.grad_compression and mesh is not None and (
        "pod" in mesh.axis_names
    ):
        vg = pod_manual_value_and_grad(loss_fn, mesh, compress=True)

    def train_step(params, opt_state, batch):
        if vg is not None:
            loss, grads = vg(params, batch)
        elif cfg.micro_batches > 1:
            loss, grads = _accumulate_grads(
                loss_fn, params, batch, cfg.micro_batches
            )
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        params, opt_state, stats = adamw_update(
            cfg.adamw, grads, opt_state, params
        )
        metrics = {"loss": loss, **stats}
        return params, opt_state, metrics

    return train_step


def init_train_state(model: Model, key) -> tuple[dict, AdamWState]:
    params = model.init(key)
    return params, adamw_init(params)
