"""AdamW in pure JAX (pytree-native, sharding-transparent)."""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    # skip the update when the global grad norm spikes (fault tolerance:
    # a straggler-corrupted or loss-spike step should not poison Adam)
    skip_anomalous: bool = True
    anomaly_factor: float = 10.0


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any
    norm_ema: jax.Array  # running grad-norm scale for the anomaly guard


def adamw_init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros_like(p)  # noqa: E731
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        mu=jax.tree.map(zeros, params),
        nu=jax.tree.map(zeros, params),
        norm_ema=jnp.asarray(0.0, jnp.float32),
    )


def _schedule(cfg: AdamWConfig, step) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def global_norm(tree) -> jax.Array:
    sq = sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)
    )
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params):
    """Returns (new_params, new_state, stats)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    step = state.step + 1
    lr = _schedule(cfg, state.step)

    ema = jnp.where(
        state.norm_ema == 0.0, gnorm,
        0.99 * state.norm_ema + 0.01 * gnorm,
    )
    ok = jnp.logical_or(
        jnp.logical_not(cfg.skip_anomalous),
        gnorm <= cfg.anomaly_factor * jnp.maximum(ema, 1e-9),
    )
    scale = jnp.where(ok, clip, 0.0)  # anomalous step -> no-op update

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        p_new = p32 - lr * (delta + cfg.weight_decay * p32)
        p_new = jnp.where(ok, p_new, p32)
        return p_new.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr,
             "skipped": jnp.logical_not(ok).astype(jnp.float32)}
    return new_p, AdamWState(step, new_m, new_v, ema), stats
