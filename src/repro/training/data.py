"""Deterministic, stateless, resumable synthetic data pipeline.

Batches are pure functions of (seed, step): restart/resume needs no
iterator state, a checkpointed step counter is enough — the pipeline is
fault-tolerant and *elastic* by construction (re-sharding the same
global batch across a different worker count yields identical data).
"""

from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    batch: int
    seq_len: int
    seed: int = 0


def make_batch(cfg: ModelConfig, data: DataConfig, step: int) -> dict:
    """Global batch for `step`, matching the arch's frontend."""
    rng = np.random.default_rng(
        np.random.SeedSequence([data.seed, step, 0xD47A])
    )
    b, s = data.batch, data.seq_len
    if cfg.frontend == "frames":
        frames = rng.standard_normal((b, s, cfg.d_model), np.float32)
        labels = rng.integers(0, cfg.vocab_size, (b, s)).astype(np.int32)
        mask = (rng.random((b, s)) < 0.15).astype(np.float32)  # HuBERT-style
        return {"frames": frames, "labels": labels, "mask": mask}
    tokens = rng.integers(0, cfg.vocab_size, (b, s + 1)).astype(np.int32)
    return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


def batch_spec(cfg: ModelConfig, data: DataConfig) -> dict:
    """jax.ShapeDtypeStruct tree matching make_batch (for dry-run)."""
    import jax
    import jax.numpy as jnp

    b, s = data.batch, data.seq_len
    if cfg.frontend == "frames":
        return {
            "frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                           jnp.float32),
            "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
            "mask": jax.ShapeDtypeStruct((b, s), jnp.float32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((b, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((b, s), jnp.int32),
    }
