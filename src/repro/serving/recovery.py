"""Replica-failure recovery and transfer retry (the serving plane's
fault-tolerance half; `core/faults.py` is the injection half).

One :class:`RecoveryManager` drives BOTH execution planes through the
Backend protocol:

- **Health watchdog** — runs on every monitor tick.  Detects replicas
  marked crashed by the fault schedule (detection latency = the monitor
  interval, like a real heartbeat) and, optionally, requests whose
  token progress has stalled past ``stuck_timeout`` on a live worker.
- **Replica crash** — the dead worker's weight tree is released, its
  prefix-cache pins and ReservationLedger charges dropped, and every
  in-flight resident is re-queued for re-dispatch: generated tokens are
  folded into the prompt (the engine's recompute-preemption idiom, so
  greedy re-prefill is token-exact) and the ORIGINAL arrival stamp is
  kept — Eq. 5 budgets and attainment see the true degradation.
  Re-admission is SLO-aware: the policy's admission verdict may degrade
  (stretch the TTFT SLO to the achievable estimate) or shed (FAILED)
  when the lost capacity makes the request unservable.
- **Transfer retry** — a dropped KV transfer (P/D hand-off or live
  decode-to-decode migration) releases its ledger charge and retries
  with capped exponential backoff on an alternate destination chosen by
  the Migrator's admission math; when retries exhaust or no destination
  admits, a live move falls back to source-continues-decode and a P/D
  hand-off re-enters the Migrator queue (or re-prefills if the source
  died too).

The Scaler needs no coupling: a crash drops active capacity, queued
work raises the load signal, and the next tick replaces the replica
through the normal scale-out path (d2d -> cpu -> disk fallback included
when the donor died mid-pull).
"""

from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Optional

import numpy as np

from repro.core.request import Request, RequestState

if TYPE_CHECKING:
    from repro.serving.cluster import Cluster


@dataclasses.dataclass
class RecoveryConfig:
    max_transfer_retries: int = 3
    retry_backoff: float = 0.05      # s; doubles per attempt
    retry_backoff_cap: float = 0.5
    # TTFT-SLO stretch when re-admission degrades (same semantics as
    # ServingSession's admission="degrade")
    degrade_factor: float = 1.25
    # requests whose token progress stalls this long on a LIVE worker
    # are pulled off and re-dispatched; None disables the scan
    stuck_timeout: Optional[float] = None
    headroom: float = 0.95           # retry-destination admission math


class RecoveryManager:
    """Failure-recovery policy over one Cluster (either plane)."""

    def __init__(self, cluster: "Cluster",
                 cfg: Optional[RecoveryConfig] = None, *,
                 enabled: bool = True):
        self.cluster = cluster
        self.cfg = RecoveryConfig() if cfg is None else cfg
        # enabled=False is the ablation arm: faults still fire, but a
        # crash sheds its residents (FAILED) instead of re-queueing and
        # dropped transfers never retry
        self.enabled = enabled
        self._crash_t: dict[int, float] = {}     # wid -> crash time
        self._recovered_wids: set[int] = set()
        self._attempts: dict[int, int] = {}      # rid -> transfer retries
        # rid -> ((tokens_done, prefill_progress), last change time)
        self._progress: dict[int, tuple] = {}
        self.n_recovered = 0
        self.n_lost = 0
        self.n_transfer_retries = 0
        self.recovery_latency_s = 0.0   # sum of fault -> re-admission gaps

    # -- crash lifecycle -------------------------------------------------------
    def note_crash(self, wid: int, now: float) -> None:
        """Record the (virtual) death time; the watchdog detects it on
        the next monitor tick."""
        self._crash_t.setdefault(wid, now)

    def watchdog(self, now: float) -> None:
        """Monitor-tick health pass: recover crashed replicas, scan for
        stuck requests."""
        cl = self.cluster
        for w in list(cl.workers):
            if getattr(w, "crashed", False) and (
                    w.wid not in self._recovered_wids):
                self._recovered_wids.add(w.wid)
                self.crash_replica(w, now)
        if (not any(w.active and not getattr(w, "crashed", False)
                    for w in cl.workers)
                and cl.scaler is None):
            # total capacity loss with no replacement coming: queued
            # requests can never be served — shed them so no stream
            # consumer hangs forever (runs even with recovery disabled;
            # this is about termination, not re-admission)
            for r in list(cl.policy.queued_requests()):
                cl.policy.drop_request(r)
                self._shed(r, now, "no capacity")
        if self.cfg.stuck_timeout is not None:
            self._scan_stuck(now)

    def crash_replica(self, w, now: float) -> None:
        """Tear down a dead replica and re-home everything it held."""
        cl = self.cluster
        crash_t = self._crash_t.get(w.wid, now)
        if w.role in ("collocated", "prefill"):
            cl.policy.remove_worker(w.wid)
        # a pending evacuation of the corpse is moot — the deferred
        # scale-in/flip must not fire on it later
        cl._evac.pop(w.wid, None)
        w.evacuating = False
        residents = w.drop_all(now)
        # transfers in flight TOWARD the corpse can never land: clear
        # their destination charges so the load signal stops reserving
        # capacity on a dead replica (their kv_ready events no-op)
        cl._mig_ledger.drop_dst(w.wid)
        if cl.weights is not None and cl.weights.owns(w.wid):
            # the dead process's weight copy is gone with it; releasing
            # also removes it from the d2d donor pool
            cl.weights.release(w.wid)
            w.engine.release_weights()
        n_req = n_shed = 0
        # re-queue tightest-TPOT first: mass re-admission must preserve
        # the same (tpot_slo, arrival) order a fresh queue would have
        for r in sorted(residents,
                        key=lambda q: (q.tpot_slo, q.arrival or 0.0,
                                       q.rid)):
            if r.state == RequestState.FINISHED:
                continue
            if self._requeue_or_shed(r, now, reason="crash",
                                     fault_t=crash_t):
                n_req += 1
            else:
                n_shed += 1
        cl.timeline.append(
            (now, w.wid, f"recover:requeued={n_req},shed={n_shed}")
        )
        cl._schedule_dispatch(now)

    # -- re-admission ----------------------------------------------------------
    def _reset_for_requeue(self, r: Request) -> None:
        """Strip every trace of the dead placement.  Engine plane:
        fold generated tokens into the prompt (recompute-preemption
        idiom) so greedy re-prefill reproduces the stream token-exactly;
        the original arrival stamp is untouched."""
        cl = self.cluster
        cl._mig_ledger.release(r.rid)
        if cl.prefix_index is not None:
            cl.prefix_index.release(r.rid)
        if r.prompt is not None and r.generated:
            r.prompt = np.concatenate([
                np.asarray(r.prompt, np.int32),
                np.asarray(r.generated, np.int32),
            ])
        r.prefill_progress = 0
        r.slot = None
        r.prefill_worker = None
        r.decode_worker = None
        r.migrating = False
        r.migrate_ready = None
        r.kv_payload = None
        r.state = RequestState.PREEMPTED
        self._progress.pop(r.rid, None)
        self._attempts.pop(r.rid, None)

    def _requeue_or_shed(self, r: Request, now: float, *, reason: str,
                         fault_t: float) -> bool:
        """Re-admit ``r`` through the policy (True) or shed it as
        FAILED (False), SLO-aware either way."""
        cl = self.cluster
        self._reset_for_requeue(r)
        if not self.enabled:
            self._shed(r, now, f"{reason} (recovery disabled)")
            return False
        degraded = False
        if cl.cfg.backend == "engine":
            probe = next((w for w in cl.workers
                          if getattr(w, "engine", None) is not None),
                         None)
            if probe is not None:
                try:
                    probe.engine.validate(r)
                except ValueError:
                    # the folded prompt + remaining budget can never
                    # fit any replica of this config
                    self._shed(r, now, f"{reason}: re-prefill cannot fit")
                    return False
        verdict = cl.policy.admission_verdict(r, now)
        if not verdict.admit:
            if verdict.wid is None and cl.scaler is None:
                # no worker could ever hold it and no replacement
                # capacity is coming: lost to the fault
                self._shed(r, now, f"{reason}: {verdict.reason}")
                return False
            if verdict.wid is not None and np.isfinite(verdict.est_ttft):
                new_slo = max(r.ttft_slo,
                              verdict.est_ttft * self.cfg.degrade_factor)
                if np.isfinite(new_slo):
                    r.ttft_slo = new_slo
                    degraded = True
        cl.policy.on_request_arrive(r)
        self.n_recovered += 1
        self.recovery_latency_s += max(now - fault_t, 0.0)
        if cl.on_retried is not None:
            info = {"reason": reason}
            if degraded:
                info["degraded"] = True
                info["ttft_slo"] = round(r.ttft_slo, 4)
            cl.on_retried(r, now, info)
        return True

    def _shed(self, r: Request, now: float, reason: str) -> None:
        r.state = RequestState.FAILED
        self.n_lost += 1
        self.cluster.timeline.append(
            (now, -1, f"shed:{r.rid}:{reason}")
        )
        if self.cluster.on_failed is not None:
            self.cluster.on_failed(r, now, reason)

    # -- stuck-request scan ----------------------------------------------------
    def _scan_stuck(self, now: float) -> None:
        st = self.cfg.stuck_timeout
        cl = self.cluster
        for w in list(cl.workers):
            if not w.active or getattr(w, "crashed", False):
                continue
            for r in list(w.running) + list(w.waiting):
                prog = (r.tokens_done, r.prefill_progress)
                last = self._progress.get(r.rid)
                if last is None or last[0] != prog:
                    self._progress[r.rid] = (prog, now)
                    continue
                if now - last[1] > st:
                    w.free_kv(r)
                    if self._requeue_or_shed(r, now, reason="stuck",
                                             fault_t=last[1]):
                        cl._schedule_dispatch(now)

    # -- transfer retry --------------------------------------------------------
    def on_transfer_landed(self, r: Request) -> None:
        """A transfer landed (first try or retry): reset the retry
        budget so a later, unrelated move starts fresh."""
        self._attempts.pop(r.rid, None)

    def on_transfer_fail(self, r: Request, src_wid: int, dst_wid: int,
                         now: float, live: bool) -> None:
        """A KV transfer dropped mid-flight (its ledger charge is
        already released).  Schedule a backed-off retry, or fall back
        when retries are exhausted / recovery is off."""
        cl = self.cluster
        attempt = self._attempts.get(r.rid, 0) + 1
        self._attempts[r.rid] = attempt
        if not self.enabled or attempt > self.cfg.max_transfer_retries:
            self._transfer_fallback(r, src_wid, now, live)
            return
        if live:
            # pin against coordinator re-planning until the retry fires
            r.migrating = True
        back = min(self.cfg.retry_backoff * (2 ** (attempt - 1)),
                   self.cfg.retry_backoff_cap)
        cl._push(now + back, "kv_retry",
                 (r, src_wid, dst_wid, live, attempt))
        cl.timeline.append(
            (now, src_wid,
             f"kv_retry:{r.rid}:attempt={attempt}(+{back:.3f}s)")
        )

    def retry_transfer(self, payload, now: float) -> None:
        """Handle a ``kv_retry`` event: re-place the transfer on an
        alternate destination, or fall back."""
        cl = self.cluster
        r, src_wid, failed_dst, live, attempt = payload
        if r.state in (RequestState.FINISHED, RequestState.FAILED):
            return
        src = cl._by_wid.get(src_wid)
        if (src is None or getattr(src, "crashed", False)
                or not src.holds_kv(r)):
            # the source died or the KV moved on (crash recovery
            # already re-homed the request) — nothing left to retry
            if live:
                r.migrating = False
            return
        dst = self._pick_retry_dst(r, src_wid, failed_dst)
        if dst is None:
            self._transfer_fallback(r, src_wid, now, live)
            return
        nbytes = None
        if cl.cfg.backend == "engine":
            nbytes = cl._measured_kv_bytes(r, src_wid)
        t_x = cl.tl.kv_transfer_time(
            cl.cfg.model, r.cur_len if live else r.l_in,
            src=src_wid, dst=dst.wid, tp=cl.cfg.tp, nbytes=nbytes,
        )
        cl._mig_ledger.reserve(dst.wid, r)
        r.migrating = live
        r.decode_worker = dst.wid
        r.migrate_ready = now + t_x
        self.n_transfer_retries += 1
        cl._push(now + t_x, "kv_ready", (r, dst.wid, src_wid))
        cl.timeline.append(
            (now, src_wid, f"kv_retry_to:{r.rid}->{dst.wid}")
        )
        if cl.on_retried is not None:
            cl.on_retried(r, now, {"reason": "kv_drop",
                                   "attempt": attempt,
                                   "dst": dst.wid})

    def _pick_retry_dst(self, r: Request, src_wid: int,
                        failed_dst: int):
        """Least-loaded admissible destination, preferring anything
        other than the one that just failed (same admission math as the
        Migrator: predicted merged-batch step within the tightest TPOT,
        KV fits, reservations charged)."""
        cl = self.cluster
        cands = [w for w in cl.workers
                 if w.active and not w.evacuating
                 and not getattr(w, "crashed", False)
                 and w.wid != src_wid
                 and w.role in ("decode", "collocated")
                 and self._dest_ok(r, w)]
        if not cands:
            return None
        return min(cands, key=lambda w: (w.wid == failed_dst,
                                         cl.load_calc.load(w), w.wid))

    def _dest_ok(self, r: Request, w) -> bool:
        led = self.cluster._mig_ledger
        if (w.kv_capacity - w.kv_tokens()
                - led.tokens(w.wid)) < r.cur_len:
            return False
        lens = ([q.cur_len for q in w.running]
                + [q.cur_len for q in w.waiting]
                + led.lens(w.wid))
        e_d = self.cluster.fitted.decode_step_time(lens + [r.cur_len])
        tpots = ([q.tpot_slo for q in w.running]
                 + [q.tpot_slo for q in w.waiting]
                 + led.tpots(w.wid)
                 + [r.tpot_slo])
        return e_d <= min(tpots) * self.cfg.headroom

    def _transfer_fallback(self, r: Request, src_wid: int, now: float,
                           live: bool) -> None:
        """Retries exhausted (or no destination admits): live moves
        stay decoding on their source; a P/D hand-off re-enters the
        Migrator queue if the source survives, else re-prefills."""
        cl = self.cluster
        src = cl._by_wid.get(src_wid)
        src_alive = (src is not None and src.active
                     and not getattr(src, "crashed", False))
        r.migrating = False
        r.migrate_ready = None
        r.decode_worker = None
        self._attempts.pop(r.rid, None)
        if live and src_alive:
            # rescue abandoned: the victim never stopped decoding on
            # its source, so nothing to do beyond unpinning it
            cl.timeline.append((now, src_wid, f"kv_giveup:{r.rid}:stay"))
            return
        if not live and src_alive and src.holds_kv(r):
            if cl.migrator is not None:
                cl.migrator.on_prefill_complete(r)
                cl._schedule_migrate(now)
                cl.timeline.append(
                    (now, src_wid, f"kv_giveup:{r.rid}:requeue_pd")
                )
                return
        # source gone (or no migrator to re-place it): re-prefill
        if src is not None:
            src.free_kv(r)
        self._requeue_or_shed(r, now, reason="kv_drop", fault_t=now)
        cl._schedule_dispatch(now)
