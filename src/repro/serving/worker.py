"""Simulated worker instance (one model replica, possibly TP-sharded).

Implements the :class:`~repro.serving.backend.Backend` protocol over a
discrete-event model.  Execution semantics follow the paper's
vLLM-Ascend deployment:

- a *prefill step* runs the whole waiting batch and is non-interruptible;
- *decode iterations* are interruptible: new requests join between
  iterations, finished ones leave;
- collocated workers prioritize pending prefill over the next decode
  iteration (which is why prefill stalls eat decode slack — the quantity
  Eq. 5 budgets for).

Ground-truth step durations come from an AnalyticLatencyModel with
multiplicative log-normal noise; schedulers only ever see fitted
coefficients.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.request import Request, RequestState
from repro.serving.backend import StepEvents, StepOutcome, WorkerBase
from repro.serving.spec_decode import (
    SpecConfig,
    expected_emitted,
    slo_spec_len,
)


class SimWorker(WorkerBase):
    def __init__(self, wid: int, role: str, truth: LatencyModel,
                 kv_capacity: int, rng: np.random.Generator,
                 noise: float = 0.02, active: bool = True,
                 chunk_tokens: Optional[int] = None,
                 prefix_index=None, spec_decode: bool = False,
                 max_spec_len: int = 8, spec_accept_rate: float = 0.7):
        super().__init__(wid, role, kv_capacity, active=active)
        self.truth = truth
        self.rng = rng
        self.noise = noise
        # speculative-decoding mirror of the engine plane: each decode
        # step widens into a propose-verify dispatch whose depth per
        # request comes from the same SLO controller the engine uses,
        # and whose emitted-token count is scaled by the modeled
        # acceptance rate — so the Dispatcher/Scaler see the same
        # acceptance-rate-scaled throughput model on both planes.
        self.spec_decode = spec_decode
        self.spec_accept_rate = spec_accept_rate
        self._spec_cfg = SpecConfig(max_spec_len=max_spec_len)
        self._spec_plan: dict[int, int] = {}    # rid -> planned depth
        # deterministic fractional-token carry per rid: acceptance is
        # modeled in expectation, no RNG, so runs replay exactly
        self._spec_carry: dict[int, float] = {}
        self.spec_dispatches = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        # cluster-shared SimPrefixIndex (None = no prefix cache):
        # mirrors the engine plane's hit/miss accounting — cache-hit
        # tokens skip prefill, so step durations and Eq. 5 budgets see
        # only the uncached suffix
        self.prefix_index = prefix_index
        # chunked prefill (mirrors the engine's paged plane): each
        # prefill step consumes at most `chunk_tokens` prompt tokens and
        # alternates with a decode iteration, so long prompts don't
        # head-of-line-block in-flight decodes.  None = monolithic.
        if chunk_tokens is not None and chunk_tokens <= 0:
            raise ValueError(
                "chunk_tokens must be positive (None disables chunking); "
                "0 would spin the event loop at zero-duration steps"
            )
        self.chunk_tokens = chunk_tokens

        self.waiting: list[Request] = []   # dispatched, awaiting prefill
        self.running: list[Request] = []   # decode batch
        self.parked: list[Request] = []    # prefilled, awaiting migration
        self._turn = "prefill"     # chunked-plane round-robin fairness
        # monolithic prefill moves its batch out of `waiting` and into
        # the in-flight StepOutcome; track it so a crash teardown can
        # still re-home requests that were mid-prefill
        self._inflight_prefill: list[Request] = []

    # -- intake ---------------------------------------------------------------
    def submit(self, reqs: Sequence[Request], now: float) -> None:
        for r in reqs:
            r.state = RequestState.ADMITTED
        self.waiting.extend(reqs)

    def accept_migrated(self, r: Request, now: float) -> None:
        """A migrated request's KV landed: join the decode batch."""
        r.state = RequestState.DECODING
        self.running.append(r)

    def free_kv(self, r: Request) -> bool:
        for pool in (self.parked, self.waiting, self.running):
            if r in pool:
                pool.remove(r)
                return True
        return False

    def drop_all(self, now: float) -> list[Request]:
        """Crash teardown: every resident leaves at once (the process
        is gone); the RecoveryManager re-homes them.  Includes the
        batch inside an in-flight monolithic prefill step — its
        ``step_done`` will be discarded by the crashed guard."""
        residents = (self.waiting + self.running + self.parked
                     + self._inflight_prefill)
        self.waiting, self.running, self.parked = [], [], []
        self._inflight_prefill = []
        return residents

    def prefix_peek(self, r: Request) -> int:
        if self.prefix_index is None:
            return 0
        return self.prefix_index.peek(r)

    def _first_touch(self, r: Request, now: float) -> int:
        """Stamp prefill start and acquire the prefix-cache hit (pins
        the group until the request finishes)."""
        r.prefill_start = now
        hit = 0
        if self.prefix_index is not None:
            hit = self.prefix_index.acquire(r)
        r.prefix_hit_tokens = hit
        return hit

    # -- step selection --------------------------------------------------------
    def next_action(self) -> Optional[str]:
        """Pick the next step kind ("prefill" | "decode" | None).

        Monolithic plane: pending prefill always preempts the next
        decode iteration (the vLLM-collocated behavior Eq. 5 budgets
        for).  Chunked plane: alternate one bounded chunk with one
        decode iteration when both have work.
        """
        can_p = bool(self.waiting) and self.role in ("collocated", "prefill")
        can_d = bool(self.running) and self.role in ("collocated", "decode")
        if can_p and can_d and self.chunk_tokens is not None:
            return self._turn
        if can_p:
            return "prefill"
        return "decode" if can_d else None

    def run_step(self, now: float) -> Optional[StepOutcome]:
        kind = self.next_action()
        if kind == "prefill":
            batch, dur = self.start_prefill(now)
            return StepOutcome("prefill", dur, prefilled=batch)
        if kind == "decode":
            dur = self.start_decode(now)
            return StepOutcome("decode", dur)
        return None

    def finish_step(self, out: StepOutcome, now: float) -> StepEvents:
        # the sim plane has no real token ids: token stream events carry
        # token=None, stamped at step end by the latency model
        if out.kind == "prefill":
            self._inflight_prefill = []
            finished, parked, tokens = [], [], []
            for r in out.prefilled:
                if self.prefix_index is not None:
                    # prefill complete: the shared-prefix span is now
                    # (virtually) resident — later group-mates hit
                    self.prefix_index.publish(r)
                # a crash-recovered request re-prefills with its prior
                # progress intact: keep the original first-token stamp
                # and continue the token count instead of restarting it
                if r.first_token_time is None:
                    r.first_token_time = now
                r.tokens_done += 1
                tokens.append((r.rid, None, now))
                if r.tokens_done >= r.l_out:
                    r.finish_time = now
                    r.state = RequestState.FINISHED
                    finished.append(r)
                elif self.role == "prefill":
                    # P/D: decode placement is the Migrator's call
                    self.parked.append(r)
                    parked.append(r)
                else:
                    r.state = RequestState.DECODING
                    self.running.append(r)
            self._release_pins(finished)
            return StepEvents(finished, parked, tokens)
        still, finished, tokens = [], [], []
        for r in self.running:
            emit = 1
            k = self._spec_plan.get(r.rid, 0) if self.spec_decode else 0
            if k > 0:
                # expected accepted tokens accumulate in a fractional
                # carry; whole tokens emit as extra ticks this step
                self._spec_carry[r.rid] = (
                    self._spec_carry.get(r.rid, 0.0)
                    + expected_emitted(k, self.spec_accept_rate) - 1.0
                )
                extra = min(int(self._spec_carry[r.rid]),
                            max(0, r.l_out - r.tokens_done - 1))
                self._spec_carry[r.rid] -= extra
                self.spec_accepted += extra
                emit += extra
            for _ in range(emit):
                r.tokens_done += 1
                tokens.append((r.rid, None, now))
            if r.tokens_done >= r.l_out:
                r.finish_time = now
                r.state = RequestState.FINISHED
                finished.append(r)
                self._spec_carry.pop(r.rid, None)
            else:
                still.append(r)
        self.running = still
        self._release_pins(finished)
        return StepEvents(finished, [], tokens)

    def _release_pins(self, finished: Sequence[Request]) -> None:
        """Unpin finished requests' prefix groups.  The index is
        cluster-shared, so this works on whichever worker finishes the
        request — including after a P/D migration."""
        if self.prefix_index is None:
            return
        for r in finished:
            self.prefix_index.release(r.rid)

    # -- execution ------------------------------------------------------------
    def _noisy(self, t: float) -> float:
        if self.noise <= 0:
            return t
        return float(t * self.rng.lognormal(0.0, self.noise))

    def start_prefill(self, now: float) -> tuple[list[Request], float]:
        """Run one prefill step; returns (completed requests, duration).

        Monolithic: the whole waiting batch, non-interruptible.
        Chunked: consume at most `chunk_tokens` prompt tokens from the
        head of the queue; requests whose prompt is fully consumed
        complete (first token at step end), the rest stay waiting with
        their progress recorded.
        """
        self._turn = "decode"
        if self.chunk_tokens is None:
            batch = self.waiting
            self.waiting = []
            self._inflight_prefill = batch
            eff_lens: list[int] = []
            for r in batch:
                hit = self._first_touch(r, now)
                r.prefill_progress = r.l_in
                r.state = RequestState.PREFILLING
                # the cache-hit span skips prefill compute; >= 1 token
                # always prefills (first-token logits)
                eff_lens.append(max(1, r.l_in - hit))
            dur = self._noisy(self.truth.prefill_time(eff_lens))
            self.busy_until = now + dur
            self.busy_time += dur
            return batch, dur

        budget = self.chunk_tokens
        done: list[Request] = []
        chunk_lens: list[int] = []
        for r in list(self.waiting):
            if budget <= 0:
                break
            if r.state != RequestState.PREFILLING:
                # first touch: progress starts at the hit offset, the
                # chunk-continuation path the chunked plane already runs
                r.prefill_progress = min(self._first_touch(r, now),
                                         max(r.l_in - 1, 0))
            take = min(r.l_in - r.prefill_progress, budget)
            r.prefill_progress += take
            r.state = RequestState.PREFILLING
            budget -= take
            chunk_lens.append(take)
            if r.prefill_progress >= r.l_in:
                self.waiting.remove(r)
                done.append(r)
        # completed-this-chunk requests left `waiting` but only land in
        # running/parked at step end — crash teardown must see them
        self._inflight_prefill = done
        dur = self._noisy(self.truth.prefill_time(chunk_lens))
        self.busy_until = now + dur
        self.busy_time += dur
        return done, dur

    def start_decode(self, now: float) -> float:
        self._turn = "prefill"
        cur = [r.cur_len for r in self.running]
        n_spec = 0
        if self.spec_decode:
            # plan per-request depth with the same SLO controller the
            # engine runs; the verify lanes widen this step's duration
            self._spec_plan = {}
            for r in self.running:
                k = min(
                    slo_spec_len(r.tpot_slo, self.truth, cur,
                                 self._spec_cfg),
                    max(0, r.l_out - r.tokens_done - 1),
                )
                self._spec_plan[r.rid] = k
                n_spec += k
            if n_spec:
                self.spec_dispatches += 1
                self.spec_proposed += n_spec
        dur = self._noisy(self.truth.spec_step_time(cur, n_spec)
                          if n_spec else self.truth.decode_step_time(cur))
        self.busy_until = now + dur
        self.busy_time += dur
        return dur
