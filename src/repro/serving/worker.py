"""Simulated worker instance (one model replica, possibly TP-sharded).

Execution semantics follow the paper's vLLM-Ascend deployment:

- a *prefill step* runs the whole waiting batch and is non-interruptible;
- *decode iterations* are interruptible: new requests join between
  iterations, finished ones leave;
- collocated workers prioritize pending prefill over the next decode
  iteration (which is why prefill stalls eat decode slack — the quantity
  Eq. 5 budgets for).

Ground-truth step durations come from an AnalyticLatencyModel with
multiplicative log-normal noise; schedulers only ever see fitted
coefficients.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.request import Request


class SimWorker:
    def __init__(self, wid: int, role: str, truth: LatencyModel,
                 kv_capacity: int, rng: np.random.Generator,
                 noise: float = 0.02, active: bool = True,
                 chunk_tokens: Optional[int] = None):
        self.wid = wid
        self.role = role  # "collocated" | "prefill" | "decode" | "warm"
        self.truth = truth
        self.kv_capacity = kv_capacity
        self.rng = rng
        self.noise = noise
        self.active = active
        # chunked prefill (mirrors the engine's paged plane): each
        # prefill step consumes at most `chunk_tokens` prompt tokens and
        # alternates with a decode iteration, so long prompts don't
        # head-of-line-block in-flight decodes.  None = monolithic.
        if chunk_tokens is not None and chunk_tokens <= 0:
            raise ValueError(
                "chunk_tokens must be positive (None disables chunking); "
                "0 would spin the event loop at zero-duration steps"
            )
        self.chunk_tokens = chunk_tokens

        self.waiting: list[Request] = []   # dispatched, awaiting prefill
        self.running: list[Request] = []   # decode batch
        self.parked: list[Request] = []    # prefilled, awaiting migration

        self.busy_until = 0.0
        self.busy_time = 0.0
        self.up_since: Optional[float] = 0.0 if active else None
        self.up_time = 0.0
        self.step_pending = False  # a worker_step event is in flight
        self._turn = "prefill"     # chunked-plane round-robin fairness

    # -- state ---------------------------------------------------------------
    def kv_tokens(self) -> int:
        return (sum(r.cur_len for r in self.running)
                + sum(r.l_in for r in self.waiting)
                + sum(r.cur_len for r in self.parked))

    def is_busy(self, now: float) -> bool:
        return self.busy_until > now or bool(self.waiting or self.running)

    def has_work(self) -> bool:
        if self.role == "prefill":
            return bool(self.waiting)
        if self.role == "decode":
            return bool(self.running)
        return bool(self.waiting or self.running)

    def next_action(self) -> Optional[str]:
        """Pick the next step kind ("prefill" | "decode" | None).

        Monolithic plane: pending prefill always preempts the next
        decode iteration (the vLLM-collocated behavior Eq. 5 budgets
        for).  Chunked plane: alternate one bounded chunk with one
        decode iteration when both have work.
        """
        can_p = bool(self.waiting) and self.role in ("collocated", "prefill")
        can_d = bool(self.running) and self.role in ("collocated", "decode")
        if can_p and can_d and self.chunk_tokens is not None:
            return self._turn
        if can_p:
            return "prefill"
        return "decode" if can_d else None

    # -- execution ------------------------------------------------------------
    def _noisy(self, t: float) -> float:
        if self.noise <= 0:
            return t
        return float(t * self.rng.lognormal(0.0, self.noise))

    def start_prefill(self, now: float) -> tuple[list[Request], float]:
        """Run one prefill step; returns (completed requests, duration).

        Monolithic: the whole waiting batch, non-interruptible.
        Chunked: consume at most `chunk_tokens` prompt tokens from the
        head of the queue; requests whose prompt is fully consumed
        complete (first token at step end), the rest stay waiting with
        their progress recorded.
        """
        self._turn = "decode"
        if self.chunk_tokens is None:
            batch = self.waiting
            self.waiting = []
            for r in batch:
                r.prefill_start = now
                r.prefill_progress = r.l_in
            dur = self._noisy(
                self.truth.prefill_time([r.l_in for r in batch])
            )
            self.busy_until = now + dur
            self.busy_time += dur
            return batch, dur

        budget = self.chunk_tokens
        done: list[Request] = []
        chunk_lens: list[int] = []
        for r in list(self.waiting):
            if budget <= 0:
                break
            take = min(r.l_in - r.prefill_progress, budget)
            if r.prefill_progress == 0:
                r.prefill_start = now
            r.prefill_progress += take
            budget -= take
            chunk_lens.append(take)
            if r.prefill_progress >= r.l_in:
                self.waiting.remove(r)
                done.append(r)
        dur = self._noisy(self.truth.prefill_time(chunk_lens))
        self.busy_until = now + dur
        self.busy_time += dur
        return done, dur

    def start_decode(self, now: float) -> float:
        self._turn = "prefill"
        dur = self._noisy(
            self.truth.decode_step_time([r.cur_len for r in self.running])
        )
        self.busy_until = now + dur
        self.busy_time += dur
        return dur

    # -- lifecycle ------------------------------------------------------------
    def activate(self, now: float, role: Optional[str] = None) -> None:
        self.active = True
        if role:
            self.role = role
        if self.up_since is None:
            self.up_since = now

    def deactivate(self, now: float) -> None:
        self.active = False
        if self.up_since is not None:
            self.up_time += now - self.up_since
            self.up_since = None

    def total_up_time(self, end: float) -> float:
        t = self.up_time
        if self.up_since is not None:
            t += end - self.up_since
        return t
