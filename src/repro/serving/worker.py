"""Simulated worker instance (one model replica, possibly TP-sharded).

Execution semantics follow the paper's vLLM-Ascend deployment:

- a *prefill step* runs the whole waiting batch and is non-interruptible;
- *decode iterations* are interruptible: new requests join between
  iterations, finished ones leave;
- collocated workers prioritize pending prefill over the next decode
  iteration (which is why prefill stalls eat decode slack — the quantity
  Eq. 5 budgets for).

Ground-truth step durations come from an AnalyticLatencyModel with
multiplicative log-normal noise; schedulers only ever see fitted
coefficients.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.latency_model import LatencyModel
from repro.core.request import Request


class SimWorker:
    def __init__(self, wid: int, role: str, truth: LatencyModel,
                 kv_capacity: int, rng: np.random.Generator,
                 noise: float = 0.02, active: bool = True):
        self.wid = wid
        self.role = role  # "collocated" | "prefill" | "decode" | "warm"
        self.truth = truth
        self.kv_capacity = kv_capacity
        self.rng = rng
        self.noise = noise
        self.active = active

        self.waiting: list[Request] = []   # dispatched, awaiting prefill
        self.running: list[Request] = []   # decode batch
        self.parked: list[Request] = []    # prefilled, awaiting migration

        self.busy_until = 0.0
        self.busy_time = 0.0
        self.up_since: Optional[float] = 0.0 if active else None
        self.up_time = 0.0
        self.step_pending = False  # a worker_step event is in flight

    # -- state ---------------------------------------------------------------
    def kv_tokens(self) -> int:
        return (sum(r.cur_len for r in self.running)
                + sum(r.l_in for r in self.waiting)
                + sum(r.cur_len for r in self.parked))

    def is_busy(self, now: float) -> bool:
        return self.busy_until > now or bool(self.waiting or self.running)

    def has_work(self) -> bool:
        if self.role == "prefill":
            return bool(self.waiting)
        if self.role == "decode":
            return bool(self.running)
        return bool(self.waiting or self.running)

    # -- execution ------------------------------------------------------------
    def _noisy(self, t: float) -> float:
        if self.noise <= 0:
            return t
        return float(t * self.rng.lognormal(0.0, self.noise))

    def start_prefill(self, now: float) -> tuple[list[Request], float]:
        batch = self.waiting
        self.waiting = []
        for r in batch:
            r.prefill_start = now
        dur = self._noisy(self.truth.prefill_time([r.l_in for r in batch]))
        self.busy_until = now + dur
        self.busy_time += dur
        return batch, dur

    def start_decode(self, now: float) -> float:
        dur = self._noisy(
            self.truth.decode_step_time([r.cur_len for r in self.running])
        )
        self.busy_until = now + dur
        self.busy_time += dur
        return dur

    # -- lifecycle ------------------------------------------------------------
    def activate(self, now: float, role: Optional[str] = None) -> None:
        self.active = True
        if role:
            self.role = role
        if self.up_since is None:
            self.up_since = now

    def deactivate(self, now: float) -> None:
        self.active = False
        if self.up_since is not None:
            self.up_time += now - self.up_since
            self.up_since = None

    def total_up_time(self, end: float) -> float:
        t = self.up_time
        if self.up_since is not None:
            t += end - self.up_since
        return t
