"""KV cache management for the real inference engine.

Two layouts coexist:

- **Paged** (default execution plane): a pool of fixed-size pages
  shared by all sequences.  :class:`PageAllocator` hands out page ids
  from a free list; :class:`PagedKVManager` keeps per-slot page tables
  (logical position ``t`` of slot ``b`` lives at page
  ``table[b, t // page_size]``, offset ``t % page_size``) and grows /
  reclaims them as requests prefill, decode, and retire.  Attention
  K/V storage indexed this way never needs contiguous per-sequence
  rows, so long prompts can't fragment the cache.

- **Slot-based** (legacy / fallback): caches pre-allocated for
  ``n_slots`` sequences of ``max_len`` tokens; :class:`SlotManager`
  tracks occupancy and ``insert_rows``/``clear_rows`` do the tree
  surgery.  Still used for batch-row bookkeeping in both planes and for
  state that is O(1) per sequence (SSM/conv state, sliding-window
  rings), where paging has nothing to win.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np


class SlotManager:
    """Batch-row allocator.  The free list is a min-heap, so ``alloc``
    keeps the deterministic lowest-id-first order at O(log n) per
    alloc/free instead of the former O(n log n) re-sort per free."""

    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free = list(range(n_slots))  # already heap-ordered
        self.owner: dict[int, object] = {}

    def alloc(self, owner=None) -> Optional[int]:
        if not self._free:
            return None
        slot = heapq.heappop(self._free)
        self.owner[slot] = owner
        return slot

    def free(self, slot: int) -> None:
        # a double free would put the same id on the free list twice and
        # eventually hand one slot to two requests — fail loudly instead
        # (mirrors PageAllocator.free)
        assert slot in self.owner, f"double free of slot {slot}"
        del self.owner[slot]
        heapq.heappush(self._free, slot)

    @property
    def n_free(self) -> int:
        return len(self._free)

    def active_slots(self) -> list[int]:
        return sorted(self.owner.keys())


# ---------------------------------------------------------------------------
# Paged allocation
# ---------------------------------------------------------------------------


class PageAllocator:
    """Free-list allocator over a pool of `n_pages` fixed-size pages."""

    def __init__(self, n_pages: int, page_size: int):
        assert n_pages > 0 and page_size > 0
        self.n_pages = n_pages
        self.page_size = page_size
        self._free = list(range(n_pages))
        self._owner: dict[int, object] = {}

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_used(self) -> int:
        return self.n_pages - len(self._free)

    def alloc(self, n: int, owner=None) -> Optional[list[int]]:
        """Allocate `n` pages atomically; None if the pool can't."""
        if n < 0 or n > len(self._free):
            return None
        pages = [self._free.pop() for _ in range(n)]
        for p in pages:
            self._owner[p] = owner
        return pages

    def free(self, pages) -> None:
        for p in pages:
            assert p in self._owner, f"double free of page {p}"
            del self._owner[p]
            self._free.append(p)

    def owner_of(self, page: int):
        return self._owner.get(page)


class PagedKVManager:
    """Per-slot page tables over a shared :class:`PageAllocator`.

    The table is a dense ``(n_slots, max_pages)`` int32 array with -1
    for unallocated entries — the exact operand the paged attention
    paths (jnp gather and the Pallas kernel's scalar-prefetch index
    map) consume, so ``jnp.asarray(kv.table)`` is the whole handoff.
    """

    def __init__(self, n_slots: int, max_len: int, page_size: int,
                 n_pages: Optional[int] = None):
        self.page_size = page_size
        self.max_pages = -(-max_len // page_size)
        self.n_slots = n_slots
        if n_pages is None:
            n_pages = n_slots * self.max_pages
        self.alloc = PageAllocator(n_pages, page_size)
        self.table = np.full((n_slots, self.max_pages), -1, np.int32)
        self._n_pages_of = np.zeros(n_slots, np.int32)
        # device-mirror invalidation: ensure/release flip this so
        # device_table() re-uploads only when allocation actually
        # changed — steady-state decode blocks reuse the resident copy
        self.dirty = True
        self._table_dev = None
        # optional PrefixCache (attach_prefix_cache): shared prefix
        # pages referenced by slot tables, refcounted by the cache
        self.prefix = None

    @property
    def n_pages(self) -> int:
        return self.alloc.n_pages

    @property
    def n_free_pages(self) -> int:
        return self.alloc.n_free

    @property
    def n_available_pages(self) -> int:
        """Pages a new allocation could obtain: the free list plus
        cached-but-unreferenced prefix pages (evictable on demand)."""
        free = self.alloc.n_free
        if self.prefix is not None:
            free += self.prefix.n_reclaimable
        return free

    # -- prefix cache (page-level KV reuse across requests) ------------------
    def attach_prefix_cache(self, cache) -> None:
        """Wire a :class:`~repro.serving.prefix_cache.PrefixCache` over
        this manager's allocator.  From here on ``release`` arbitrates
        each page with the cache (shared pages deref instead of free)
        and ``ensure`` evicts unreferenced cached pages when the free
        list runs dry."""
        assert cache.alloc is self.alloc, (
            "prefix cache must share this manager's PageAllocator"
        )
        self.prefix = cache

    def lookup_prefix(self, slot: int, token_ids) -> int:
        """Point a *fresh* slot's table at the longest cached prefix of
        ``token_ids`` (pages pinned by the cache); returns the hit
        length in tokens.  The engine then prefills from that offset —
        all subsequent writes land in private pages past the shared
        span (the hit is full-page-aligned by construction)."""
        if self.prefix is None:
            return 0
        assert int(self._n_pages_of[slot]) == 0, (
            f"lookup_prefix needs a fresh slot (slot {slot} holds pages)"
        )
        pages, hit = self.prefix.lookup(token_ids)
        if pages:
            self.table[slot, : len(pages)] = pages
            self._n_pages_of[slot] = len(pages)
            self.dirty = True
        return hit

    def publish_prefix(self, slot: int, token_ids) -> int:
        """Register a prefill-complete slot's full-page prefix span in
        the cache; returns pages newly published."""
        if self.prefix is None:
            return 0
        return self.prefix.publish(self.pages_of(slot), token_ids)

    def peek_prefix(self, token_ids) -> int:
        """Hit length a lookup would return — read-only (the admission
        path budgets with this)."""
        if self.prefix is None or token_ids is None:
            return 0
        return self.prefix.peek(token_ids)

    def pages_of(self, slot: int) -> list[int]:
        return [int(p) for p in
                self.table[slot, : int(self._n_pages_of[slot])]]

    def n_pages_held(self, slot: int) -> int:
        return int(self._n_pages_of[slot])

    def device_table(self):
        """The page table as a device-resident jnp array, re-uploaded
        lazily: only allocation changes (``ensure`` growth /
        ``release``) invalidate the cached copy, so back-to-back
        decode steps hand the SAME buffer to the jitted step — no
        per-token host->device table upload."""
        if self._table_dev is None or self.dirty:
            self._table_dev = jnp.asarray(self.table)
            self.dirty = False
        return self._table_dev

    def ensure(self, slot: int, n_tokens: int) -> bool:
        """Grow slot's table to cover `n_tokens`; False if out of pages
        (the slot's existing pages are untouched on failure)."""
        need = -(-n_tokens // self.page_size)
        if need > self.max_pages:
            return False
        have = int(self._n_pages_of[slot])
        if need <= have:
            return True
        got = self.alloc.alloc(need - have, owner=slot)
        if got is None and self.prefix is not None:
            # free list dry but unreferenced cached pages exist: evict
            # LRU prefix pages back into the pool and retry once
            short = (need - have) - self.alloc.n_free
            if self.prefix.evict(short) >= short:
                got = self.alloc.alloc(need - have, owner=slot)
        if got is None:
            return False
        self.table[slot, have:need] = got
        self._n_pages_of[slot] = need
        self.dirty = True
        return True

    def release(self, slot: int) -> None:
        n = int(self._n_pages_of[slot])
        if n:
            for p in self.table[slot, :n]:
                p = int(p)
                # shared prefix pages deref (the cache decides when the
                # allocator gets them back); private pages free now
                if self.prefix is not None and self.prefix.release_page(p):
                    continue
                self.alloc.free([p])
            self.dirty = True
        self.table[slot, :] = -1
        self._n_pages_of[slot] = 0

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Shrink slot's table to cover exactly ``n_tokens`` — the
        speculative-decode rollback: pages wholly past the accepted
        length go back to the pool (prefix-shared pages deref, exactly
        like :meth:`release`).  Returns pages freed.  A prefix-cache
        hit span is full-page-aligned and the engine never truncates
        below the resident position, so pinned prefix pages are only
        ever touched via the same deref arbitration as release."""
        need = -(-n_tokens // self.page_size) if n_tokens > 0 else 0
        have = int(self._n_pages_of[slot])
        if need >= have:
            return 0
        for p in self.table[slot, need:have]:
            p = int(p)
            if self.prefix is not None and self.prefix.release_page(p):
                continue
            self.alloc.free([p])
        self.table[slot, need:have] = -1
        self._n_pages_of[slot] = need
        self.dirty = True
        return have - need


# ---------------------------------------------------------------------------
# P/D hand-off: materialize / install one sequence's KV state
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class KVPayload:
    """One request's cache contents + generation state, materialized
    for a device-to-device hand-off (paper §6).

    ``kv`` mirrors the engine's paged-cache pytree with attention
    leaves linearized to token-major ``(lead..., H, n_tokens, D)`` —
    page-layout-free, so the destination may use a different page size
    — and O(1)-per-sequence state (SSM/conv) as bare slot rows.
    """

    rid: int
    n_tokens: int        # cached tokens (absolute position of the next)
    last_token: int      # feeds the first decode step on the destination
    prefill_progress: int
    kv: list             # per-segment pytree (see above)

    @property
    def nbytes(self) -> int:
        """Actual payload size — what the TLManager should cost."""
        return int(sum(leaf.size * leaf.dtype.itemsize
                       for leaf in jax.tree.leaves(self.kv)))


def _gather_pages_leaf(leaf, page_ids, n_tokens):
    """(lead..., NP, H, ps, D) -> contiguous (lead..., H, n_tokens, D)."""
    from repro.kernels import ops

    lead = leaf.shape[:-4]
    flat = leaf.reshape((-1,) + leaf.shape[len(lead):])
    out = jax.vmap(lambda p: ops.page_gather(p, page_ids))(flat)
    out = out[:, :, :n_tokens, :]
    return out.reshape(lead + out.shape[1:])


def _scatter_pages_leaf(leaf, page_ids, seq):
    """Install contiguous ``seq`` (lead..., H, T, D) into the pool's
    ``page_ids`` (the destination allocator's choice); T is padded to
    the destination's page multiple, so source and destination page
    sizes may differ."""
    ps = leaf.shape[-2]
    m = page_ids.shape[0]
    t = seq.shape[-2]
    pad = m * ps - t
    assert pad >= 0, (m, ps, t)
    seq = jnp.pad(seq, [(0, 0)] * (seq.ndim - 2) + [(0, pad), (0, 0)])
    chunks = seq.reshape(seq.shape[:-2] + (m, ps, seq.shape[-1]))
    chunks = jnp.swapaxes(chunks, -4, -3)  # (lead..., M, H, ps, D)
    return leaf.at[..., page_ids, :, :, :].set(chunks.astype(leaf.dtype))


def gather_slot_kv(caches, axes, slot: int, page_ids, n_tokens: int):
    """Materialize slot's cache state: paged attention leaves gathered
    contiguous through ``page_ids``; per-slot leaves (axis != None)
    extracted as bare rows."""
    page_ids = jnp.asarray(page_ids, jnp.int32)

    def take(full, ax):
        if ax is None:
            return _gather_pages_leaf(full, page_ids, n_tokens)
        return jax.lax.index_in_dim(full, slot, axis=ax, keepdims=False)

    return jax.tree.map(take, caches, axes)


def scatter_slot_kv(caches, axes, slot: int, page_ids, payload_kv):
    """Inverse of :func:`gather_slot_kv` on the destination engine."""
    page_ids = jnp.asarray(page_ids, jnp.int32)

    def put(full, ax, part):
        if ax is None:
            return _scatter_pages_leaf(full, page_ids, part)
        return jax.lax.dynamic_update_index_in_dim(
            full, part.astype(full.dtype), slot, axis=ax
        )

    return jax.tree.map(put, caches, axes, payload_kv)


# ---------------------------------------------------------------------------
# Slot-layout tree surgery (legacy plane + non-paged leaves)
# ---------------------------------------------------------------------------


def insert_rows(cache, new, axes, slots, src_rows=None):
    """Copy per-sequence rows of `new` into `cache` at `slots`.

    cache/new: same-structure pytrees; axes: pytree of batch-axis ints;
    slots: list of destination slot indices; src_rows: matching source
    row indices in `new` (default 0..len-1).
    """
    if src_rows is None:
        src_rows = list(range(len(slots)))

    def put(full, part, ax):
        for dst, src in zip(slots, src_rows):
            row = jax.lax.index_in_dim(part, src, axis=ax, keepdims=False)
            full = jax.lax.dynamic_update_index_in_dim(
                full, row.astype(full.dtype), dst, axis=ax
            )
        return full

    return jax.tree.map(put, cache, new, axes)


def clear_rows(cache, axes, slots):
    """Zero the given slots (pos arrays get -1).

    Leaves whose axis is None (paged K/V pools: reclaimed by the
    PageAllocator, never by row) pass through untouched.
    """
    def wipe(full, ax):
        if ax is None:
            return full
        for s in slots:
            row = jax.lax.index_in_dim(full, s, axis=ax, keepdims=False)
            fill = (jnp.full_like(row, -1)
                    if full.dtype == jnp.int32 else jnp.zeros_like(row))
            full = jax.lax.dynamic_update_index_in_dim(
                full, fill, s, axis=ax
            )
        return full

    return jax.tree.map(wipe, cache, axes)
