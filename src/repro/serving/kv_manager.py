"""Slot-based KV cache manager for the real inference engine.

The engine pre-allocates caches for `n_slots` sequences of up to
`max_len` tokens (the TPU-friendly layout: static shapes, per-sequence
slot rows).  This manager tracks slot occupancy and provides the
tree-surgery helpers to insert a freshly prefilled sequence into its
slot and to clear slots on completion.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


class SlotManager:
    def __init__(self, n_slots: int):
        self.n_slots = n_slots
        self._free = list(range(n_slots))
        self.owner: dict[int, object] = {}

    def alloc(self, owner=None) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop(0)
        self.owner[slot] = owner
        return slot

    def free(self, slot: int) -> None:
        self.owner.pop(slot, None)
        self._free.append(slot)
        self._free.sort()

    @property
    def n_free(self) -> int:
        return len(self._free)

    def active_slots(self) -> list[int]:
        return sorted(self.owner.keys())


def insert_rows(cache, new, axes, slots, src_rows=None):
    """Copy per-sequence rows of `new` into `cache` at `slots`.

    cache/new: same-structure pytrees; axes: pytree of batch-axis ints;
    slots: list of destination slot indices; src_rows: matching source
    row indices in `new` (default 0..len-1).
    """
    if src_rows is None:
        src_rows = list(range(len(slots)))

    def put(full, part, ax):
        for dst, src in zip(slots, src_rows):
            row = jax.lax.index_in_dim(part, src, axis=ax, keepdims=False)
            full = jax.lax.dynamic_update_index_in_dim(
                full, row.astype(full.dtype), dst, axis=ax
            )
        return full

    return jax.tree.map(put, cache, new, axes)


def clear_rows(cache, axes, slots):
    """Zero the given slots (pos arrays get -1)."""
    def wipe(full, ax):
        for s in slots:
            row = jax.lax.index_in_dim(full, s, axis=ax, keepdims=False)
            fill = (jnp.full_like(row, -1)
                    if full.dtype == jnp.int32 else jnp.zeros_like(row))
            full = jax.lax.dynamic_update_index_in_dim(
                full, fill, s, axis=ax
            )
        return full

    return jax.tree.map(wipe, cache, axes)
