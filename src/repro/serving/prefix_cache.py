"""Prefix cache: refcounted page-level KV reuse across requests.

At millions-of-users scale most prefill work is redundant — shared
system prompts, few-shot templates, and multi-turn history repeat
across requests.  The paged KV plane makes reuse cheap: a page is
already the unit of sharing, so a request whose prompt starts with an
already-computed prefix can point its page table at the cached pages
and prefill only the uncached suffix.

Design (engine plane, :class:`PrefixCache`):

- **Content keys** are a *chained* blake2b digest over token-id blocks
  at page granularity: ``key_k = H(tokens[0 : (k+1) * page_size])``
  computed incrementally.  Chaining makes a key position- and
  prefix-dependent by construction, so two prompts share page ``k``
  iff they agree on ALL tokens up to and including that page — exactly
  the condition under which the K/V contents are identical.
- **Refcounts** pin shared pages: ``lookup`` increments per hit page,
  ``release_page`` (called by ``PagedKVManager.release`` for every
  page a retiring slot holds) decrements.  A page with refs > 0 is
  pinned — the allocator never sees it.  At refs == 0 the page moves
  to an LRU list: contents stay resident (a future lookup revives it)
  but the page is *reclaimable* — ``evict`` returns it to the
  ``PageAllocator`` free list when the pool runs dry or the cache's
  own ``max_pages`` budget is exceeded.
- **Copy-on-write at the first divergent token** is achieved
  structurally: a lookup only ever matches *full* pages strictly
  inside the prompt (capped at ``(l_in - 1) // page_size`` pages, so
  at least one prompt token always re-prefills and yields the
  first-token logits).  The first divergent token therefore lands in
  a freshly-allocated private page at a page-aligned boundary —
  writes never touch a shared page, which is what CoW must guarantee.
- **Publish** happens at prefill completion: the slot's full-page
  prefix span is registered under its chained keys with refs = 1
  (held by the publishing slot).  Pages that came *from* the cache
  (the slot's own hit span) are already registered; duplicate content
  computed concurrently by another slot stays private.

The sim plane mirrors hit/miss accounting with
:class:`SimPrefixIndex` — no token ids exist there, so identity is a
``(prefix_group, prefix_len)`` pair carried by the workload generator
(:func:`repro.serving.workload.shared_prefix_workload`), with the same
page-aligned hit rule and LRU-by-group eviction.
"""

from __future__ import annotations

import hashlib
from collections import OrderedDict
from typing import Optional

import numpy as np


def page_keys(token_ids, page_size: int, n_pages: int) -> list[bytes]:
    """Chained content keys for the first ``n_pages`` full pages of a
    prompt.  ``keys[k]`` digests tokens ``[0, (k+1) * page_size)`` —
    prefix-dependent, so equal keys imply identical K/V contents at
    identical absolute positions."""
    if n_pages <= 0:
        return []
    arr = np.ascontiguousarray(
        np.asarray(token_ids[: n_pages * page_size], dtype=np.int32)
    )
    h = hashlib.blake2b(digest_size=16)
    keys: list[bytes] = []
    for k in range(n_pages):
        h.update(arr[k * page_size: (k + 1) * page_size].tobytes())
        keys.append(h.digest())
    return keys


class PrefixCache:
    """Hash-indexed store of immutable prefix pages over a
    :class:`~repro.serving.kv_manager.PageAllocator`.

    The cache never owns device memory — it tracks *which* pool pages
    hold published prefix content and arbitrates their lifetime
    between sharers (refcounts) and the allocator (LRU eviction).
    """

    def __init__(self, allocator, page_size: int,
                 max_pages: Optional[int] = None):
        if max_pages is not None and max_pages <= 0:
            raise ValueError("max_pages must be positive (None = bound "
                             "only by the page pool)")
        self.alloc = allocator
        self.page_size = page_size
        self.max_pages = max_pages
        self._index: dict[bytes, int] = {}        # content key -> page id
        self._entries: dict[int, list] = {}       # page id -> [key, refs]
        # refs-0 pages, least-recently-released first (eviction order);
        # contents stay resident until evicted, so a later lookup revives
        self._lru: "OrderedDict[int, None]" = OrderedDict()
        # telemetry
        self.n_lookups = 0
        self.n_hit_tokens = 0
        self.n_published = 0
        self.n_evicted = 0

    # -- introspection -------------------------------------------------------
    @property
    def n_cached(self) -> int:
        return len(self._entries)

    @property
    def n_reclaimable(self) -> int:
        """Cached pages with no sharers — reclaimable by ``evict``."""
        return len(self._lru)

    def is_cached(self, page: int) -> bool:
        return page in self._entries

    def refs(self, page: int) -> int:
        e = self._entries.get(page)
        return e[1] if e is not None else 0

    def stats(self) -> dict:
        return {
            "n_lookups": self.n_lookups,
            "n_hit_tokens": self.n_hit_tokens,
            "n_published": self.n_published,
            "n_evicted": self.n_evicted,
            "n_cached": self.n_cached,
            "n_reclaimable": self.n_reclaimable,
        }

    # -- the hit path --------------------------------------------------------
    def max_hit_pages(self, n_tokens: int) -> int:
        """Longest hit allowed for an ``n_tokens`` prompt: full pages
        strictly inside it, so >= 1 token always re-prefills (the
        first-token logits must come from somewhere) and the first
        private write lands page-aligned past the shared span."""
        return max(0, (n_tokens - 1) // self.page_size)

    def peek(self, token_ids) -> int:
        """Hit length (tokens) a ``lookup`` would return — read-only,
        no pinning.  The Dispatcher's Eq. 5 admission budget charges
        ``l_in - peek(...)``."""
        n = self.max_hit_pages(len(token_ids))
        hit = 0
        for key in page_keys(token_ids, self.page_size, n):
            if key not in self._index:
                break
            hit += self.page_size
        return hit

    def lookup(self, token_ids) -> tuple[list[int], int]:
        """Pin the longest cached prefix of ``token_ids``; returns
        ``(page_ids, hit_tokens)``.  Every returned page's refcount is
        incremented — the caller installs them in a slot's page table
        and releases via :meth:`release_page` when the slot retires."""
        self.n_lookups += 1
        n = self.max_hit_pages(len(token_ids))
        pages: list[int] = []
        for key in page_keys(token_ids, self.page_size, n):
            p = self._index.get(key)
            if p is None:
                break
            e = self._entries[p]
            e[1] += 1
            if e[1] == 1:           # revived from the reclaimable list
                self._lru.pop(p, None)
            pages.append(p)
        hit = len(pages) * self.page_size
        self.n_hit_tokens += hit
        return pages, hit

    # -- the publish path ----------------------------------------------------
    def publish(self, slot_pages: list[int], token_ids) -> int:
        """Register a prefill-complete slot's full-page prefix span.

        Newly-registered pages get refs = 1 (held by the publishing
        slot; its ``release_page`` at retirement drops them to the LRU
        list).  Pages already cache-owned (the slot's own hit span)
        and content another slot published concurrently are skipped —
        the latter stays a private page.  Returns pages newly
        published."""
        n = min(len(token_ids) // self.page_size, len(slot_pages))
        new = 0
        for k, key in enumerate(page_keys(token_ids, self.page_size, n)):
            p = slot_pages[k]
            if p in self._entries:
                continue            # already shared (came from lookup)
            if key in self._index:
                continue            # duplicate content: keep private
            if (self.max_pages is not None
                    and len(self._entries) >= self.max_pages
                    and not self._evict_one()):
                break               # budget full of pinned pages
            self._index[key] = p
            self._entries[p] = [key, 1]
            new += 1
        self.n_published += new
        return new

    # -- lifetime ------------------------------------------------------------
    def release_page(self, page: int) -> bool:
        """One sharer of ``page`` is gone.  True if the page is
        cache-owned (the caller must NOT free it to the allocator);
        False means the page is private and the caller frees it."""
        e = self._entries.get(page)
        if e is None:
            return False
        assert e[1] > 0, f"refcount underflow on page {page}"
        e[1] -= 1
        if e[1] == 0:
            self._lru[page] = None  # most-recently-released at the end
        return True

    def _evict_one(self) -> bool:
        if not self._lru:
            return False
        p, _ = self._lru.popitem(last=False)
        key, refs = self._entries.pop(p)
        assert refs == 0, f"evicting pinned page {p}"
        del self._index[key]
        self.alloc.free([p])
        self.n_evicted += 1
        return True

    def evict(self, n: int) -> int:
        """Reclaim up to ``n`` unreferenced cached pages into the
        allocator's free list (LRU first); returns pages freed."""
        freed = 0
        while freed < n and self._evict_one():
            freed += 1
        return freed


class SimPrefixIndex:
    """Sim-plane mirror of the prefix cache: cluster-shared hit/miss
    accounting keyed by ``(prefix_group, prefix_len)`` instead of
    token content (the simulator has no token ids).

    Semantics match the engine cache: hits are page-aligned and capped
    so >= 1 token always prefills; a group's cached length only grows
    (agent loops extend their history); groups with in-flight sharers
    are pinned against eviction; capacity is enforced LRU-by-group.
    """

    def __init__(self, page_size: int = 16,
                 capacity_pages: Optional[int] = None):
        if page_size <= 0:
            raise ValueError("page_size must be positive")
        self.page_size = page_size
        self.capacity_pages = capacity_pages
        self._cached: "OrderedDict[int, int]" = OrderedDict()  # group -> toks
        self._pins: dict[int, int] = {}     # group -> in-flight sharers
        self._rids: dict[int, int] = {}     # rid -> group (release key)
        self.n_lookups = 0
        self.n_hit_tokens = 0
        self.n_evicted = 0

    def _aligned(self, n_tokens: int) -> int:
        return (n_tokens // self.page_size) * self.page_size

    def peek(self, r) -> int:
        """Hit length (tokens) for request ``r`` — read-only."""
        if r.prefix_group is None:
            return 0
        cached = self._cached.get(r.prefix_group, 0)
        cap = self._aligned(max(r.l_in - 1, 0))
        return min(self._aligned(min(cached, r.prefix_len)), cap)

    def acquire(self, r) -> int:
        """Pin ``r``'s group and return the hit length; called at the
        first prefill touch (mirrors the engine's lookup-at-admission).
        """
        self.n_lookups += 1
        hit = self.peek(r)
        g = r.prefix_group
        if g is not None:
            self._pins[g] = self._pins.get(g, 0) + 1
            self._rids[r.rid] = g
            if g in self._cached:
                self._cached.move_to_end(g)
        self.n_hit_tokens += hit
        return hit

    def publish(self, r) -> None:
        """Prefill complete: the group's cached span grows to the
        page-aligned shared-prefix length of ``r``'s prompt."""
        g = r.prefix_group
        if g is None:
            return
        n = self._aligned(min(r.prefix_len, r.l_in))
        if n > self._cached.get(g, 0):
            self._cached[g] = n
        if g in self._cached:
            self._cached.move_to_end(g)
        self._evict_to_capacity()

    def release(self, rid: int) -> None:
        """Request ``rid`` left the plane (finished / freed); unpin its
        group.  Cluster-shared, so this works across a P/D migration —
        whichever worker finishes the request releases the same pin."""
        g = self._rids.pop(rid, None)
        if g is None:
            return
        left = self._pins.get(g, 0) - 1
        if left <= 0:
            self._pins.pop(g, None)
        else:
            self._pins[g] = left

    def _evict_to_capacity(self) -> None:
        if self.capacity_pages is None:
            return
        total = sum(v // self.page_size for v in self._cached.values())
        for g in list(self._cached):
            if total <= self.capacity_pages:
                break
            if self._pins.get(g, 0):
                continue            # in-flight sharers: pinned
            total -= self._cached.pop(g) // self.page_size
            self.n_evicted += 1

    def stats(self) -> dict:
        return {
            "n_lookups": self.n_lookups,
            "n_hit_tokens": self.n_hit_tokens,
            "n_evicted": self.n_evicted,
            "n_groups": len(self._cached),
        }
