"""Per-replica weight ownership + real provisioning transports (§6).

The paper's Fast Scaling claim (Table 2) is that a scaled-out instance
pulls weights **device-to-device from a live replica** instead of
re-reading them from disk, cutting cold-start latency by an order of
magnitude.  For that claim to be testable on the engine plane, replicas
cannot alias one shared params tree — each
:class:`~repro.serving.engine.InferenceEngine` must *own* its weights,
and scale-out must actually move bytes through the selected transport.

:class:`WeightManager` is that ownership registry plus the three
Table-2 transports:

- ``d2d``  — pull from a live donor replica's params tree via
  ``jax.device_put`` onto the new replica's device (true D2D reshard
  when source and destination devices differ; an on-device copy — the
  single-host stand-in for an ICI pull — when they coincide, so the
  new replica never aliases the donor's buffers).
- ``cpu``  — copy from the host-resident offload of the seed params
  (host -> device over PCIe/host links).
- ``disk`` — load the seed checkpoint written via
  :mod:`repro.distributed.checkpoint` (the scale-from-zero path: it
  needs no live donor and no warm host copy).

Every provision is wall-clock measured and reported to the
:class:`~repro.core.tlmanager.TLManager`, whose
``weight_load_time`` then predicts from *observed* bandwidth — the
Scaler's Algorithm-3 tick picks the provisioning path from measured,
not analytic, costs.

Placement reuses the sharding plumbing: under an active
:func:`repro.distributed.sharding.use_rules` context the target keeps
the rules' mesh sharding; otherwise replicas round-robin over local
devices via ``SingleDeviceSharding`` (on a 1-device CPU host every
replica lands on the same device but still owns distinct buffers).
"""

from __future__ import annotations

import tempfile
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.checkpoint import (
    checkpoint_nbytes,
    load_checkpoint,
    save_checkpoint,
)
from repro.distributed.sharding import current_rules

STRATEGIES = ("d2d", "cpu", "disk")


class WeightManager:
    """Owns the per-replica params trees of one served model.

    ``seed_params`` is retained only as provisioning *source* material
    (host offload + disk checkpoint) — replicas never alias it; every
    ``provision``/``adopt`` hands a replica its own tree, and
    ``release`` drops it (scale-in reclaims the copy's memory).
    """

    def __init__(self, seed_params: Any, tl=None,
                 ckpt_dir: Optional[str] = None):
        self._owned: dict[int, Any] = {}
        self.tl = tl
        # "cpu" source: host-resident offload of the seed tree.  A real
        # copy, not np.asarray — on the CPU backend asarray zero-copies
        # the device buffer and the "offload" would alias the live tree
        self.host = jax.tree.map(lambda x: np.array(x), seed_params)
        self.nbytes = float(sum(leaf.nbytes
                                for leaf in jax.tree.leaves(self.host)))
        # "disk" source: a real checkpoint written through the same
        # atomic-write path training restores from (scale-from-zero
        # needs neither a donor nor a warm host copy — only this file)
        self._tmp = None
        if ckpt_dir is None:
            self._tmp = tempfile.TemporaryDirectory(prefix="hfx-weights-")
            ckpt_dir = self._tmp.name
        self.ckpt_dir = ckpt_dir
        save_checkpoint(self.ckpt_dir, 0, seed_params)
        assert checkpoint_nbytes(self.ckpt_dir, 0) == self.nbytes

    # -- ownership registry ----------------------------------------------------
    def owns(self, wid: int) -> bool:
        return wid in self._owned

    def params_of(self, wid: int) -> Any:
        return self._owned[wid]

    def donors(self) -> list[int]:
        """Replicas a ``d2d`` provision could pull from right now."""
        return sorted(self._owned)

    def adopt(self, wid: int, params: Any) -> None:
        """Register an externally materialized tree (e.g. the seed
        replica constructed before this manager existed)."""
        if wid in self._owned:
            raise ValueError(f"replica {wid} already owns a params tree")
        self._owned[wid] = params

    def release(self, wid: int) -> None:
        """Scale-in: drop the replica's tree so its memory is
        reclaimable (and it stops being a d2d donor candidate)."""
        self._owned.pop(wid, None)

    # -- placement -------------------------------------------------------------
    def placement(self, wid: int):
        """Target sharding for replica ``wid``'s params.  Inside a
        sharding-rules context the mesh placement wins (a replica may
        span a TP device group); otherwise replicas round-robin over
        local devices."""
        rules = current_rules()
        if rules is not None and rules.mesh is not None:
            return None  # device_put target resolved per-leaf by rules
        devs = jax.devices()
        if len(devs) == 1:
            # single-device host: a committed sharding would defeat the
            # warmup's jit cache (committed args lower differently than
            # the uncommitted seed tree, forcing a recompile inside the
            # first measured step); ownership comes from the explicit
            # copies, so no placement pin is needed
            return None
        return jax.sharding.SingleDeviceSharding(devs[wid % len(devs)])

    # -- Table-2 transports ----------------------------------------------------
    def provision(self, wid: int, strategy: str,
                  donor: Optional[int] = None) -> tuple[Any, float]:
        """Materialize replica ``wid``'s own params tree through
        ``strategy``; returns ``(params, measured_seconds)``.

        The measured wall time is reported to the TLManager so the
        Scaler's next cost query predicts from observed bandwidth.
        """
        if strategy not in STRATEGIES:
            raise ValueError(f"unknown weight strategy {strategy!r}")
        if wid in self._owned:
            raise ValueError(f"replica {wid} already owns a params tree")
        sh = self.placement(wid)
        t0 = time.perf_counter()
        if strategy == "d2d":
            if donor is None or donor not in self._owned:
                raise ValueError(
                    f"d2d provisioning for replica {wid} needs a live "
                    f"donor (have {sorted(self._owned)}, got {donor!r}); "
                    f"scale-from-zero must fall back to 'disk'"
                )
            src = self._owned[donor]

            def pull(x):
                x = jnp.asarray(x)
                if sh is not None and x.devices() != sh.device_set:
                    return jax.device_put(x, sh)  # true cross-device
                # same device: on-device copy — owned buffers, no alias
                return jnp.copy(x)

            params = jax.tree.map(pull, src)
        elif strategy == "cpu":
            # the copy after device_put matters: a CPU-device put of a
            # host array is zero-copy, and every "cpu" replica would
            # otherwise share the offload's buffers instead of owning
            # its own tree
            params = jax.tree.map(
                lambda h: jnp.copy(jax.device_put(h, sh)), self.host
            )
        else:  # disk
            shardings = (None if sh is None
                         else jax.tree.map(lambda _: sh, self.host))
            params, _ = load_checkpoint(
                self.ckpt_dir, 0, self.host, shardings=shardings
            )
        params = jax.block_until_ready(params)
        dt = time.perf_counter() - t0
        self._owned[wid] = params
        if self.tl is not None:
            self.tl.observe_weight_load(strategy, self.nbytes, dt)
        return params, dt
