"""Workload generation (paper §7.3).

Poisson inter-arrivals per task, equal share per task, fixed seed; plus
the Fig. 6 dynamic ramp (priority classes joining every 20 s).

Request ids are assigned exactly once, AFTER arrival-sorting, so
``rid`` always equals the request's arrival rank and callers never see
an id change under them (the pre-sort ids a caller might have kept were
previously silently reassigned — see PR 2).

``materialize_prompts`` turns a length-only workload into an
engine-plane workload by synthesizing deterministic token ids, so the
same generators feed both the simulator and the real JAX engine.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.request import FOUR_TASK_SET, TASKS, TWO_TASK_SET, Request, TaskSpec


def _finalize(reqs: list[Request]) -> list[Request]:
    """Arrival-sort, then assign rids (the only assignment ever made)."""
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def poisson_workload(task_names: Sequence[str], qps: float,
                     n_per_task: int = 300, seed: int = 0,
                     use_priority: bool = False) -> list[Request]:
    """Total rate `qps`, split equally across tasks; n_per_task samples."""
    rng = np.random.default_rng(seed)
    per_task_rate = qps / len(task_names)
    reqs: list[Request] = []
    for name in task_names:
        spec = TASKS[name]
        t = 0.0
        for _ in range(n_per_task):
            t += rng.exponential(1.0 / per_task_rate)
            l_in, l_out = spec.sample_lengths(rng)
            reqs.append(Request(
                rid=-1, task=name, arrival=t, l_in=l_in, l_out=l_out,
                ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
                priority=spec.priority if use_priority else None,
            ))
    return _finalize(reqs)


def ramp_workload(task_names: Sequence[str], qps_per_class: float = 15.0,
                  join_every: float = 20.0, duration: float = 100.0,
                  n_per_class: Optional[int] = None,
                  seed: int = 0) -> list[Request]:
    """Fig. 6 dynamic ramp: the lowest-priority class starts first and
    every `join_every` seconds the next (higher) class joins; all active
    classes keep arriving until `duration` (total rate ramps up)."""
    rng = np.random.default_rng(seed)
    specs = sorted((TASKS[n] for n in task_names),
                   key=lambda s: -s.priority)  # lowest priority first
    reqs: list[Request] = []
    for k, spec in enumerate(specs):
        t = k * join_every
        n_class = 0
        while t < duration:
            t += rng.exponential(1.0 / qps_per_class)
            if t >= duration:
                break
            if n_per_class and n_class >= n_per_class:
                break
            l_in, l_out = spec.sample_lengths(rng)
            reqs.append(Request(
                rid=-1, task=spec.name, arrival=t, l_in=l_in, l_out=l_out,
                ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
                priority=spec.priority,
            ))
            n_class += 1
    return _finalize(reqs)


def single_task_workload(task: str = "wikisql", qps: float = 10.0,
                         n: int = 300, seed: int = 0,
                         ttft: float = 0.7, tpot: float = 0.5):
    """Fig. 7 single-task setting with overridden SLOs."""
    rng = np.random.default_rng(seed)
    spec = TASKS[task]
    reqs = []
    t = 0.0
    for rid in range(n):
        t += rng.exponential(1.0 / qps)
        l_in, l_out = spec.sample_lengths(rng)
        reqs.append(Request(
            rid=rid, task=task, arrival=t, l_in=l_in, l_out=l_out,
            ttft_slo=ttft, tpot_slo=tpot,
        ))
    return reqs


def engine_smoke_workload(task: str = "gsm8k", n: int = 8,
                          qps: float = 24.0, seed: int = 3,
                          clip_in: int = 24,
                          clip_out: int = 6) -> list[Request]:
    """A Table-1 workload sized to the reduced CPU engine: Poisson
    arrivals with prompt/output lengths clipped so every request fits
    ``EngineConfig.smoke()``.  Shared by the engine-plane example,
    benchmark, and CI smoke runs so their setups can't diverge."""
    reqs = poisson_workload([task], qps=qps, n_per_task=n, seed=seed)
    for r in reqs:
        r.l_in = min(r.l_in, clip_in)
        r.l_out = min(r.l_out, clip_out)
    return reqs


def shared_prefix_workload(task: str = "gsm8k", n: int = 64,
                           qps: float = 8.0, seed: int = 0, *,
                           n_groups: int = 8, zipf_a: float = 1.1,
                           shape: str = "chat", prefix_len: int = 128,
                           suffix_len: int = 32, turn_growth: int = 64,
                           max_turns: int = 8,
                           l_out: Optional[int] = None) -> list[Request]:
    """Zipfian shared-prefix workload (the prefix-cache stressor).

    Each request joins one of ``n_groups`` prefix groups, drawn with
    probability proportional to 1/k^zipf_a (group 1 hottest) — a few
    system prompts dominate, matching observed chat traffic.  Two
    shapes:

    - ``"chat"``: every group-k request shares the same ``prefix_len``
      system prompt and appends a private suffix of 1..``suffix_len``
      tokens.
    - ``"agent"``: groups are agent *sessions* whose shared history
      grows by ``turn_growth`` tokens per turn (capped at
      ``max_turns``), so later requests re-prefill an ever-longer
      prefix unless a cache holds it.

    SLOs come from ``TASKS[task]``; ``l_out`` overrides the task's
    sampled output length (benchmarks want short, fixed decodes).
    ``prefix_group``/``prefix_len`` carry the sharing structure to both
    planes: the simulator keys its prefix index on them, the engine
    materializes matching token ids from them.
    """
    if shape not in ("chat", "agent"):
        raise ValueError(f"unknown shape {shape!r} (chat|agent)")
    if n_groups < 1:
        raise ValueError("n_groups must be >= 1")
    rng = np.random.default_rng(seed)
    weights = 1.0 / np.arange(1, n_groups + 1, dtype=np.float64) ** zipf_a
    weights /= weights.sum()
    spec = TASKS[task]
    turns: dict[int, int] = {}
    reqs: list[Request] = []
    t = 0.0
    for _ in range(n):
        t += rng.exponential(1.0 / qps)
        g = int(rng.choice(n_groups, p=weights))
        if shape == "chat":
            plen = prefix_len
        else:
            turn = min(turns.get(g, 0), max_turns - 1)
            turns[g] = turns.get(g, 0) + 1
            plen = prefix_len + turn * turn_growth
        sfx = int(rng.integers(1, suffix_len + 1))
        if l_out is not None:
            lo = int(l_out)
        else:
            lo = max(1, int(rng.normal(spec.out_mean, spec.out_std)))
        reqs.append(Request(
            rid=-1, task=task, arrival=t, l_in=plen + sfx, l_out=lo,
            ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
            prefix_group=g, prefix_len=plen,
        ))
    return _finalize(reqs)


# group-prefix token streams are generated in fixed-size chunks so a
# group's length-L prefix is always a strict prefix of its length-L'
# stream (L < L') — agent sessions grow their history without ever
# rewriting earlier tokens, which is what makes page keys stable
_PREFIX_CHUNK = 256


def _group_prefix_tokens(vocab_size: int, seed: int, group: int,
                         length: int) -> np.ndarray:
    """Deterministic token stream for one prefix group, independent of
    how much of it any particular request consumes."""
    chunks = []
    got, ci = 0, 0
    while got < length:
        chunk_rng = np.random.default_rng([seed, 0x5EED, int(group), ci])
        chunks.append(chunk_rng.integers(0, vocab_size,
                                         size=_PREFIX_CHUNK))
        got += _PREFIX_CHUNK
        ci += 1
    return np.concatenate(chunks)[:length].astype(np.int32)


def materialize_prompts(requests: Sequence[Request], vocab_size: int,
                        seed: int = 0, max_len: Optional[int] = None,
                        rng: Optional[np.random.Generator] = None
                        ) -> Sequence[Request]:
    """Give length-only requests real token ids for the engine plane.

    Deterministic under `seed`; requests that already carry a prompt are
    left untouched.  With `max_len` set, validates that every prompt
    leaves room to generate (the engine would reject it mid-run
    otherwise, which is a much worse failure mode).  Pass a live `rng`
    to draw incrementally (ServingSession materializes per submit with
    one persistent generator, so an online replay is prompt-identical
    to a batch run that materialized the whole list up front)."""
    if rng is None:
        rng = np.random.default_rng(seed)
    for r in requests:
        if r.prompt is None:
            if r.prefix_group is not None and r.prefix_len > 0:
                # shared-prefix request: the group span comes from the
                # group's deterministic stream (keyed by `seed`, NOT
                # the live rng — group-mates materialized in any order
                # get byte-identical prefixes), the private suffix from
                # the sequential rng like any other prompt
                plen = min(r.prefix_len, max(1, r.l_in))
                prefix = _group_prefix_tokens(
                    vocab_size, seed, r.prefix_group, plen
                )
                sfx = rng.integers(
                    0, vocab_size, size=max(1, r.l_in) - plen
                ).astype(np.int32)
                r.prompt = np.concatenate([prefix, sfx]).astype(np.int32)
            else:
                r.prompt = rng.integers(
                    0, vocab_size, size=max(1, r.l_in)
                ).astype(np.int32)
            r.l_in = int(len(r.prompt))
        if max_len is not None and len(r.prompt) >= max_len:
            raise ValueError(
                f"request {r.rid}: prompt of {len(r.prompt)} tokens "
                f"cannot generate within engine max_len={max_len}; size "
                f"the workload to the engine (or raise max_len)"
            )
    return requests
