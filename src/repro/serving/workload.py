"""Workload generation (paper §7.3).

Poisson inter-arrivals per task, equal share per task, fixed seed; plus
the Fig. 6 dynamic ramp (priority classes joining every 20 s).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.request import FOUR_TASK_SET, TASKS, TWO_TASK_SET, Request, TaskSpec


def poisson_workload(task_names: Sequence[str], qps: float,
                     n_per_task: int = 300, seed: int = 0,
                     use_priority: bool = False) -> list[Request]:
    """Total rate `qps`, split equally across tasks; n_per_task samples."""
    rng = np.random.default_rng(seed)
    per_task_rate = qps / len(task_names)
    reqs: list[Request] = []
    rid = 0
    for name in task_names:
        spec = TASKS[name]
        t = 0.0
        for _ in range(n_per_task):
            t += rng.exponential(1.0 / per_task_rate)
            l_in, l_out = spec.sample_lengths(rng)
            reqs.append(Request(
                rid=rid, task=name, arrival=t, l_in=l_in, l_out=l_out,
                ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
                priority=spec.priority if use_priority else None,
            ))
            rid += 1
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def ramp_workload(task_names: Sequence[str], qps_per_class: float = 15.0,
                  join_every: float = 20.0, duration: float = 100.0,
                  n_per_class: Optional[int] = None,
                  seed: int = 0) -> list[Request]:
    """Fig. 6 dynamic ramp: the lowest-priority class starts first and
    every `join_every` seconds the next (higher) class joins; all active
    classes keep arriving until `duration` (total rate ramps up)."""
    rng = np.random.default_rng(seed)
    specs = sorted((TASKS[n] for n in task_names),
                   key=lambda s: -s.priority)  # lowest priority first
    reqs: list[Request] = []
    rid = 0
    for k, spec in enumerate(specs):
        t = k * join_every
        while t < duration:
            t += rng.exponential(1.0 / qps_per_class)
            if t >= duration:
                break
            if n_per_class and sum(
                1 for r in reqs if r.task == spec.name
            ) >= n_per_class:
                break
            l_in, l_out = spec.sample_lengths(rng)
            reqs.append(Request(
                rid=rid, task=spec.name, arrival=t, l_in=l_in, l_out=l_out,
                ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
                priority=spec.priority,
            ))
            rid += 1
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def single_task_workload(task: str = "wikisql", qps: float = 10.0,
                         n: int = 300, seed: int = 0,
                         ttft: float = 0.7, tpot: float = 0.5):
    """Fig. 7 single-task setting with overridden SLOs."""
    rng = np.random.default_rng(seed)
    spec = TASKS[task]
    reqs = []
    t = 0.0
    for rid in range(n):
        t += rng.exponential(1.0 / qps)
        l_in, l_out = spec.sample_lengths(rng)
        reqs.append(Request(
            rid=rid, task=task, arrival=t, l_in=l_in, l_out=l_out,
            ttft_slo=ttft, tpot_slo=tpot,
        ))
    return reqs
