"""Workload generation (paper §7.3).

Poisson inter-arrivals per task, equal share per task, fixed seed; plus
the Fig. 6 dynamic ramp (priority classes joining every 20 s).

Request ids are assigned exactly once, AFTER arrival-sorting, so
``rid`` always equals the request's arrival rank and callers never see
an id change under them (the pre-sort ids a caller might have kept were
previously silently reassigned — see PR 2).

``materialize_prompts`` turns a length-only workload into an
engine-plane workload by synthesizing deterministic token ids, so the
same generators feed both the simulator and the real JAX engine.
"""

from __future__ import annotations

from typing import Optional, Sequence

import numpy as np

from repro.core.request import FOUR_TASK_SET, TASKS, TWO_TASK_SET, Request, TaskSpec


def _finalize(reqs: list[Request]) -> list[Request]:
    """Arrival-sort, then assign rids (the only assignment ever made)."""
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def poisson_workload(task_names: Sequence[str], qps: float,
                     n_per_task: int = 300, seed: int = 0,
                     use_priority: bool = False) -> list[Request]:
    """Total rate `qps`, split equally across tasks; n_per_task samples."""
    rng = np.random.default_rng(seed)
    per_task_rate = qps / len(task_names)
    reqs: list[Request] = []
    for name in task_names:
        spec = TASKS[name]
        t = 0.0
        for _ in range(n_per_task):
            t += rng.exponential(1.0 / per_task_rate)
            l_in, l_out = spec.sample_lengths(rng)
            reqs.append(Request(
                rid=-1, task=name, arrival=t, l_in=l_in, l_out=l_out,
                ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
                priority=spec.priority if use_priority else None,
            ))
    return _finalize(reqs)


def ramp_workload(task_names: Sequence[str], qps_per_class: float = 15.0,
                  join_every: float = 20.0, duration: float = 100.0,
                  n_per_class: Optional[int] = None,
                  seed: int = 0) -> list[Request]:
    """Fig. 6 dynamic ramp: the lowest-priority class starts first and
    every `join_every` seconds the next (higher) class joins; all active
    classes keep arriving until `duration` (total rate ramps up)."""
    rng = np.random.default_rng(seed)
    specs = sorted((TASKS[n] for n in task_names),
                   key=lambda s: -s.priority)  # lowest priority first
    reqs: list[Request] = []
    for k, spec in enumerate(specs):
        t = k * join_every
        n_class = 0
        while t < duration:
            t += rng.exponential(1.0 / qps_per_class)
            if t >= duration:
                break
            if n_per_class and n_class >= n_per_class:
                break
            l_in, l_out = spec.sample_lengths(rng)
            reqs.append(Request(
                rid=-1, task=spec.name, arrival=t, l_in=l_in, l_out=l_out,
                ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
                priority=spec.priority,
            ))
            n_class += 1
    return _finalize(reqs)


def single_task_workload(task: str = "wikisql", qps: float = 10.0,
                         n: int = 300, seed: int = 0,
                         ttft: float = 0.7, tpot: float = 0.5):
    """Fig. 7 single-task setting with overridden SLOs."""
    rng = np.random.default_rng(seed)
    spec = TASKS[task]
    reqs = []
    t = 0.0
    for rid in range(n):
        t += rng.exponential(1.0 / qps)
        l_in, l_out = spec.sample_lengths(rng)
        reqs.append(Request(
            rid=rid, task=task, arrival=t, l_in=l_in, l_out=l_out,
            ttft_slo=ttft, tpot_slo=tpot,
        ))
    return reqs


def engine_smoke_workload(task: str = "gsm8k", n: int = 8,
                          qps: float = 24.0, seed: int = 3,
                          clip_in: int = 24,
                          clip_out: int = 6) -> list[Request]:
    """A Table-1 workload sized to the reduced CPU engine: Poisson
    arrivals with prompt/output lengths clipped so every request fits
    ``EngineConfig.smoke()``.  Shared by the engine-plane example,
    benchmark, and CI smoke runs so their setups can't diverge."""
    reqs = poisson_workload([task], qps=qps, n_per_task=n, seed=seed)
    for r in reqs:
        r.l_in = min(r.l_in, clip_in)
        r.l_out = min(r.l_out, clip_out)
    return reqs


def materialize_prompts(requests: Sequence[Request], vocab_size: int,
                        seed: int = 0, max_len: Optional[int] = None,
                        rng: Optional[np.random.Generator] = None
                        ) -> Sequence[Request]:
    """Give length-only requests real token ids for the engine plane.

    Deterministic under `seed`; requests that already carry a prompt are
    left untouched.  With `max_len` set, validates that every prompt
    leaves room to generate (the engine would reject it mid-run
    otherwise, which is a much worse failure mode).  Pass a live `rng`
    to draw incrementally (ServingSession materializes per submit with
    one persistent generator, so an online replay is prompt-identical
    to a batch run that materialized the whole list up front)."""
    if rng is None:
        rng = np.random.default_rng(seed)
    for r in requests:
        if r.prompt is None:
            r.prompt = rng.integers(
                0, vocab_size, size=max(1, r.l_in)
            ).astype(np.int32)
            r.l_in = int(len(r.prompt))
        if max_len is not None and len(r.prompt) >= max_len:
            raise ValueError(
                f"request {r.rid}: prompt of {len(r.prompt)} tokens "
                f"cannot generate within engine max_len={max_len}; size "
                f"the workload to the engine (or raise max_len)"
            )
    return requests
