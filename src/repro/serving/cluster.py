"""Discrete-event multi-instance serving cluster.

Runs the full HyperFlexis stack — Dispatcher (Algorithm 1), Migrator,
Monitor, Scaler (Algorithm 3), TLManager, priority SLO mapping
(Algorithm 2) — or any baseline policy, over simulated workers whose
ground-truth step latencies come from the analytic roofline model of the
chosen LLM (§7.2 models).  Schedulers only observe *fitted* latency
coefficients (Appendix A) and periodic Monitor snapshots, preserving the
paper's information structure.

Supports collocated and P/D-disaggregated execution, scaling with warm
pool + D2D fast weight transfer, and Fig. 6-style dynamic SLO mapping.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.latency_model import (
    AnalyticLatencyModel,
    FittedLatencyModel,
    Hardware,
    TPU_V5E,
)
from repro.core.migrator import Migrator
from repro.core.monitor import Monitor
from repro.core.policies import make_policy
from repro.core.request import Request
from repro.core.scaler import ScaleAction, Scaler, ScalerConfig
from repro.core.slo_mapper import PrioritySLOMapper
from repro.core.tlmanager import TLManager
from repro.serving.metrics import COST_UNIT, RunMetrics, compute_metrics
from repro.serving.worker import SimWorker


@dataclasses.dataclass
class ClusterConfig:
    model: ModelConfig
    n_workers: int = 2
    policy: str = "hyperflexis"
    mode: str = "collocated"        # "collocated" | "pd"
    n_prefill: int = 1              # pd mode initial split
    n_decode: int = 1
    scaling: bool = False
    scaler: ScalerConfig = dataclasses.field(default_factory=ScalerConfig)
    monitor_interval: float = 0.05  # Fig. 8 knob
    # chunked prefill (mirrors the engine's paged plane): bound on
    # prompt tokens per prefill step, interleaved 1:1 with decode
    # iterations; None = monolithic (legacy) prefill
    chunk_tokens: Optional[int] = None
    tp: int = 1
    hw: Hardware = TPU_V5E
    seed: int = 0
    noise: float = 0.02
    # one-shot decode assignment at arrival (the anti-pattern §5.1 fixes);
    # only meaningful with mode="pd" and baseline policies
    one_shot_pd: bool = False
    slo_mapper: Optional[PrioritySLOMapper] = None
    drain_timeout: float = 3600.0


@dataclasses.dataclass
class ClusterResult:
    metrics: RunMetrics
    requests: list
    timeline: list          # (time, wid, event) trace of scaling actions
    monitor: Monitor
    n_scale_out: int = 0
    n_scale_in: int = 0
    n_role_flips: int = 0
    kv_transfers: int = 0


class Cluster:
    def __init__(self, cfg: ClusterConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.truth = AnalyticLatencyModel(cfg.model, cfg.hw, tp=cfg.tp)
        self.fitted = FittedLatencyModel.from_profile(self.truth, self.rng)
        self.monitor = Monitor(cfg.monitor_interval)
        self.tl = TLManager(cfg.hw)

        kv_cap = self._kv_capacity()
        self.workers: list[SimWorker] = []
        roles = self._initial_roles()
        for i, role in enumerate(roles):
            self.workers.append(SimWorker(
                i, role, self.truth, kv_cap,
                np.random.default_rng(cfg.seed + 1000 + i),
                noise=cfg.noise, chunk_tokens=cfg.chunk_tokens,
            ))
        self._next_wid = len(self.workers)
        self._kv_cap = kv_cap

        self.policy = make_policy(
            cfg.policy, self.fitted, self.monitor, self._do_dispatch
        )
        for w in self.workers:
            if w.role in ("collocated", "prefill"):
                self.policy.add_worker(w, 0.0)

        self.migrator = None
        if cfg.mode == "pd" and not cfg.one_shot_pd:
            self.migrator = Migrator(
                self.fitted, self.monitor, self.tl, cfg.model, tp=cfg.tp
            )
        self.scaler = None
        if cfg.scaling:
            self.scaler = Scaler(
                cfg.scaler, self.monitor, self.tl, cfg.model, tp=cfg.tp
            )

        # event loop state
        self._events: list = []
        self._eseq = itertools.count()
        self._dispatch_at: Optional[float] = None
        self._migrate_scheduled = False
        self._rr_decode = 0
        self.timeline: list = []

    # -- setup -----------------------------------------------------------------
    def _initial_roles(self) -> list[str]:
        if self.cfg.mode == "pd":
            return (["prefill"] * self.cfg.n_prefill
                    + ["decode"] * self.cfg.n_decode)
        return ["collocated"] * self.cfg.n_workers

    def _kv_capacity(self) -> int:
        cfg = self.cfg
        weight_bytes = cfg.model.param_count() * 2 / max(cfg.tp, 1)
        free = max(cfg.hw.hbm_capacity - weight_bytes, 2e9)
        kv_per_tok = AnalyticLatencyModel._kv_bytes_per_token(cfg.model, 2)
        if kv_per_tok <= 0:  # SSM: state only; token capacity is huge
            return 10_000_000
        return int(cfg.tp * free / kv_per_tok)

    # -- event machinery ----------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def _schedule_dispatch(self, t: float) -> None:
        if self._dispatch_at is None or t < self._dispatch_at - 1e-12:
            self._dispatch_at = t
            self._push(t, "dispatch")

    def _schedule_worker(self, w: SimWorker, t: float) -> None:
        if not w.step_pending and w.active:
            w.step_pending = True
            self._push(t, "worker_step", w.wid)

    # -- dispatch callback (policy -> worker) ----------------------------------------
    def _do_dispatch(self, worker: SimWorker, reqs: Sequence[Request],
                     now: float) -> None:
        for r in reqs:
            r.prefill_worker = worker.wid
        worker.waiting.extend(reqs)
        if self.cfg.mode == "pd" and self.cfg.one_shot_pd:
            # one-shot: decode instance fixed at arrival time (RR)
            decodes = [w for w in self.workers if w.role == "decode"
                       and w.active]
            for r in reqs:
                if decodes:
                    r.decode_worker = decodes[
                        self._rr_decode % len(decodes)
                    ].wid
                    self._rr_decode += 1
        if worker.busy_until <= now:
            self._schedule_worker(worker, now)

    # -- main loop ---------------------------------------------------------------
    def run(self, requests: Sequence[Request]) -> ClusterResult:
        cfg = self.cfg
        by_wid = {w.wid: w for w in self.workers}
        for r in requests:
            self._push(r.arrival, "arrival", r)
        self._push(0.0, "monitor")
        if self.scaler is not None:
            self._push(cfg.scaler.tau, "scaler")
        higher_pending = {p: 0 for p in range(8)}

        n_left = len(requests)
        now = 0.0
        horizon = (max(r.arrival for r in requests)
                   + cfg.drain_timeout) if requests else 0.0

        while self._events and n_left > 0 and now <= horizon:
            now, _, kind, payload = heapq.heappop(self._events)

            if kind == "arrival":
                r: Request = payload
                if cfg.slo_mapper is not None and r.priority is not None:
                    hp = any(
                        q.priority is not None and q.priority < r.priority
                        for q in self.policy.queued_requests()
                    )
                    r.ttft_slo, r.tpot_slo = cfg.slo_mapper.assign(
                        r.priority, higher_priority_pending=hp
                    )
                self.monitor.note_arrival()
                self.policy.on_request_arrive(r)
                self._schedule_dispatch(now)

            elif kind == "dispatch":
                if self._dispatch_at is not None and now >= (
                    self._dispatch_at - 1e-12
                ):
                    self._dispatch_at = None
                self.policy.dispatch_pass(now)
                nw = self.policy.next_wakeup()
                if self.policy.pending() and nw is not None:
                    self._schedule_dispatch(max(nw, now + 1e-6))
                elif self.policy.pending():
                    self._schedule_dispatch(now + 0.01)

            elif kind == "worker_step":
                w = by_wid[payload]
                w.step_pending = False
                if not w.active or now < w.busy_until - 1e-12:
                    pass
                else:
                    action = w.next_action()
                    if action == "prefill":
                        batch, dur = w.start_prefill(now)
                        self._push(now + dur, "prefill_done",
                                   (w.wid, batch))
                        w.step_pending = True
                    elif action == "decode":
                        dur = w.start_decode(now)
                        self._push(now + dur, "decode_done", w.wid)
                        w.step_pending = True

            elif kind == "prefill_done":
                wid, batch = payload
                w = by_wid[wid]
                w.step_pending = False
                for r in batch:
                    r.first_token_time = now
                    r.tokens_done = 1
                    if r.tokens_done >= r.l_out:
                        r.finish_time = now
                        self._finish(r, cfg, higher_pending, now)
                        n_left -= 1
                        continue
                    if cfg.mode == "pd":
                        w.parked.append(r)
                        if self.migrator is not None:
                            self.migrator.on_prefill_complete(r)
                        else:  # one-shot: start transfer immediately
                            dst = by_wid.get(r.decode_worker)
                            t_x = self.tl.kv_transfer_time(
                                cfg.model, r.l_in, wid,
                                dst.wid if dst else wid, tp=cfg.tp,
                            )
                            self._push(now + t_x, "kv_ready",
                                       (r, r.decode_worker))
                    else:
                        w.running.append(r)
                if self.migrator is not None:
                    self._schedule_migrate(now)
                if w.has_work():
                    self._schedule_worker(w, now)
                self.policy.notify_worker_free(w.wid, now)
                self._schedule_dispatch(now)

            elif kind == "decode_done":
                w = by_wid[payload]
                w.step_pending = False
                still = []
                for r in w.running:
                    r.tokens_done += 1
                    if r.tokens_done >= r.l_out:
                        r.finish_time = now
                        self._finish(r, cfg, higher_pending, now)
                        n_left -= 1
                    else:
                        still.append(r)
                w.running = still
                if self.migrator is not None:
                    self._schedule_migrate(now)
                if w.has_work():
                    self._schedule_worker(w, now)
                # NOTE: no maturity correction here — decode iterations
                # are the slack Eq. 5 budgets against; only a *prefill*
                # finishing early frees the worker ahead of estimate.
                self._schedule_dispatch(now)

            elif kind == "migrate":
                self._migrate_scheduled = False
                decodes = [w for w in self.workers if w.role == "decode"]
                moves = self.migrator.migrate_pass(now, decodes)
                for r, dst, t_x in moves:
                    self._push(now + t_x, "kv_ready", (r, dst.wid))

            elif kind == "kv_ready":
                r, dst_wid = payload
                src = by_wid.get(r.prefill_worker)
                if src is not None and r in src.parked:
                    src.parked.remove(r)
                dst = by_wid.get(dst_wid)
                if dst is None or not dst.active:
                    # destination vanished (scale-in): re-queue
                    if self.migrator is not None:
                        self.migrator.on_prefill_complete(r)
                        self._schedule_migrate(now)
                    continue
                dst.running.append(r)
                self._schedule_worker(dst, now)

            elif kind == "monitor":
                self.monitor.update(now, [w for w in self.workers
                                          if w.active])
                self._push(now + self.monitor.interval, "monitor")

            elif kind == "scaler":
                self._scaler_tick(now, by_wid)
                self._push(now + cfg.scaler.tau, "scaler")

            elif kind == "worker_up":
                wid, role = payload
                w = by_wid[wid]
                w.activate(now, role)
                self.tl.ensure_links(wid, [x.wid for x in self.workers
                                           if x.wid != wid])
                if role in ("collocated", "prefill"):
                    self.policy.add_worker(w, now)
                self.timeline.append((now, wid, f"up:{role}"))
                self._schedule_dispatch(now)
                if self.migrator is not None:
                    self._schedule_migrate(now)

            elif kind == "role_flip":
                wid, role = payload
                w = by_wid[wid]
                was = w.role
                w.role = role
                if role in ("collocated", "prefill"):
                    self.policy.add_worker(w, now)
                elif was in ("collocated", "prefill"):
                    self.policy.remove_worker(wid)
                self.timeline.append((now, wid, f"role:{was}->{role}"))
                self._schedule_dispatch(now)
                if self.migrator is not None:
                    self._schedule_migrate(now)

        makespan = now
        cost = sum(w.total_up_time(makespan) for w in self.workers) / (
            COST_UNIT
        )
        m = compute_metrics(list(requests), cost, makespan)
        return ClusterResult(
            metrics=m,
            requests=list(requests),
            timeline=self.timeline,
            monitor=self.monitor,
            n_scale_out=self.scaler.n_scale_out if self.scaler else 0,
            n_scale_in=self.scaler.n_scale_in if self.scaler else 0,
            n_role_flips=self.scaler.n_role_flips if self.scaler else 0,
            kv_transfers=self.tl.n_kv_transfers,
        )

    # -- helpers ------------------------------------------------------------------
    def _finish(self, r: Request, cfg, higher_pending, now) -> None:
        self.monitor.note_completion()
        if cfg.slo_mapper is not None and r.priority is not None:
            q_time = (r.dispatch_time or r.arrival) - r.arrival
            if r.ttft is not None and r.tpot is not None:
                cfg.slo_mapper.observe(
                    r.priority, r.ttft, max(r.tpot, 1e-4), q_time
                )

    def _schedule_migrate(self, now: float) -> None:
        if self.migrator is not None and not self._migrate_scheduled:
            self._migrate_scheduled = True
            self._push(now, "migrate")

    def _scaler_tick(self, now: float, by_wid) -> None:
        cfg = self.cfg
        queued = self.policy.queued_requests()
        if cfg.mode == "pd":
            dq = self.migrator.queue.items() if self.migrator else []
            actions = self.scaler.tick_pd(now, self.workers, queued, dq)
        else:
            actions = self.scaler.tick(now, self.workers, queued,
                                       pool="any")
        for a in actions:
            if a.kind == "out":
                role = a.role if a.role != "any" else "collocated"
                w = SimWorker(
                    self._next_wid, role, self.truth, self._kv_cap,
                    np.random.default_rng(
                        cfg.seed + 1000 + self._next_wid
                    ),
                    noise=cfg.noise, active=False,
                    chunk_tokens=cfg.chunk_tokens,
                )
                self.workers.append(w)
                by_wid[w.wid] = w
                self._next_wid += 1
                self._push(now + a.delay, "worker_up", (w.wid, role))
                self.timeline.append(
                    (now, w.wid, f"scale_out({a.delay:.2f}s)")
                )
            elif a.kind == "in":
                w = by_wid[a.worker_id]
                w.deactivate(now)
                if w.role in ("collocated", "prefill"):
                    self.policy.remove_worker(w.wid)
                self.timeline.append((now, w.wid, "scale_in"))
            elif a.kind == "role":
                w = by_wid[a.worker_id]
                self._push(now + a.delay, "role_flip", (w.wid, a.role))


def run_cluster(cfg: ClusterConfig, requests) -> ClusterResult:
    return Cluster(cfg).run(requests)
