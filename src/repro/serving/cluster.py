"""Backend-agnostic multi-instance serving control loop.

Runs the full HyperFlexis stack — Dispatcher (Algorithm 1), Migrator,
Monitor, Scaler (Algorithm 3), TLManager, priority SLO mapping
(Algorithm 2) — or any baseline policy, over workers that implement the
:class:`~repro.serving.backend.Backend` protocol.  Two planes exist:

- ``backend="sim"`` (default): :class:`SimWorker` instances whose
  ground-truth step latencies come from the analytic roofline model of
  the chosen LLM (§7.2 models).  Schedulers only observe *fitted*
  latency coefficients (Appendix A) and periodic Monitor snapshots,
  preserving the paper's information structure.
- ``backend="engine"``: :class:`EngineWorker` instances wrapping real
  :class:`InferenceEngine` replicas.  Every step runs jitted model
  compute; measured wall times become event durations, and the
  engines' shared profiler IS the Dispatcher's FittedLatencyModel, so
  Eq. 5 budgets are grounded in real latencies.

The same Dispatcher/Scaler/PrioritySLOMapper instances drive either
plane unmodified.  Supports collocated and P/D-disaggregated execution
on BOTH planes (engine P/D moves real paged KV: the source engine's
``export_kv`` payload is installed on the decode engine when the
TLManager-costed transfer lands), scaling with warm pool + D2D fast
weight transfer, and Fig. 6-style dynamic SLO mapping.
"""

from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import TYPE_CHECKING, Optional, Sequence

import numpy as np

from repro.configs.base import ModelConfig
from repro.core.latency_model import (
    AnalyticLatencyModel,
    FittedLatencyModel,
    Hardware,
    TPU_V5E,
)
from repro.core.faults import FaultInjector
from repro.core.instance_load import (
    InstanceLoadCalculator,
    ReservationLedger,
)
from repro.core.migrator import (
    MigrationConfig,
    MigrationCoordinator,
    Migrator,
)
from repro.core.monitor import Monitor
from repro.core.policies import make_policy
from repro.core.request import Request, RequestState
from repro.core.scaler import ScaleAction, Scaler, ScalerConfig
from repro.core.slo_mapper import PrioritySLOMapper
from repro.core.tlmanager import TLManager
from repro.serving.backend import Backend, EngineWorker
from repro.serving.metrics import COST_UNIT, RunMetrics, compute_metrics
from repro.serving.recovery import RecoveryConfig, RecoveryManager
from repro.serving.worker import SimWorker

if TYPE_CHECKING:  # engine plane imported lazily at runtime
    from repro.serving.engine import EngineConfig


@dataclasses.dataclass
class ClusterConfig:
    model: ModelConfig
    n_workers: int = 2
    policy: str = "hyperflexis"
    backend: str = "sim"            # "sim" | "engine"
    # engine-plane knobs (n_slots, max_len, page_size, chunk_size, ...);
    # None = EngineConfig() defaults.  Only read when backend="engine".
    engine: Optional["EngineConfig"] = None
    mode: str = "collocated"        # "collocated" | "pd"
    n_prefill: int = 1              # pd mode initial split
    n_decode: int = 1
    scaling: bool = False
    scaler: ScalerConfig = dataclasses.field(default_factory=ScalerConfig)
    monitor_interval: float = 0.05  # Fig. 8 knob
    # chunked prefill (sim plane; the engine plane chunks natively):
    # bound on prompt tokens per prefill step, interleaved 1:1 with
    # decode iterations; None = monolithic (legacy) prefill
    chunk_tokens: Optional[int] = None
    # prefix cache: page-level KV reuse across requests.  Engine plane:
    # every replica gets a PrefixCache over its page pool (overrides
    # EngineConfig.prefix_cache); sim plane: one cluster-shared
    # SimPrefixIndex mirrors hit/miss accounting.  prefix_cache_pages
    # caps the cache footprint (pages; None = bounded by the pool).
    prefix_cache: bool = False
    prefix_cache_pages: Optional[int] = None
    # SLO-customized speculative decoding.  Engine plane: every replica
    # runs the n-gram drafter + one-dispatch verify with per-lane depth
    # from Eq. 5 / TPOT slack (overrides EngineConfig.spec_decode); sim
    # plane: decode ticks are acceptance-rate-scaled with the same
    # controller, so the Dispatcher/Scaler see one throughput model.
    spec_decode: bool = False
    max_spec_len: int = 8
    spec_accept_rate: float = 0.7   # sim-plane modeled acceptance
    # live migration: a MigrationCoordinator plans decode-to-decode
    # moves every monitor tick (rescue predicted-TPOT-miss requests,
    # rebalance bursty ramps) and the Scaler's flip / scale-in targets
    # are *evacuated* (migrate-then-flip) instead of waiting for a
    # natural drain.  ``migration`` tunes the planner; None = defaults.
    live_migration: bool = False
    migration: Optional[MigrationConfig] = None
    tp: int = 1
    hw: Hardware = TPU_V5E
    seed: int = 0
    noise: float = 0.02
    # one-shot decode assignment at arrival (the anti-pattern §5.1 fixes);
    # only meaningful with mode="pd" and baseline policies
    one_shot_pd: bool = False
    slo_mapper: Optional[PrioritySLOMapper] = None
    drain_timeout: float = 3600.0
    # fault tolerance: a seeded FaultInjector the event loop consults
    # (crashes, transfer drops, weight-load failures, stragglers) and
    # the recovery switch — recovery=False is the ablation arm where a
    # crash sheds its residents instead of re-queueing them
    faults: Optional[FaultInjector] = None
    recovery: bool = True
    recovery_cfg: Optional[RecoveryConfig] = None


@dataclasses.dataclass
class ClusterResult:
    metrics: RunMetrics
    requests: list
    timeline: list          # (time, wid, event) trace of scaling actions
    monitor: Monitor
    n_scale_out: int = 0
    n_scale_in: int = 0
    n_role_flips: int = 0
    kv_transfers: int = 0
    # engine plane only: fused-decode telemetry summed over workers —
    # block-size histogram {K: n_blocks}, decode tokens emitted, and
    # total jitted dispatches (= host syncs), the figure decode blocks
    # amortize
    decode_block_hist: dict = dataclasses.field(default_factory=dict)
    n_decode_tokens: int = 0
    n_dispatches: int = 0
    # prompt tokens that actually ran prefill compute (engine plane;
    # with a prefix cache this is the FLOPs-saved denominator's
    # complement) and per-plane prefix-cache telemetry
    n_prefill_tokens: int = 0
    prefix_stats: dict = dataclasses.field(default_factory=dict)
    # live migration telemetry: landed decode-to-decode moves, and the
    # coordinator's split of planned moves by reason
    n_live_migrations: int = 0
    n_rescues: int = 0
    n_evacuations: int = 0
    # fault tolerance: injected faults, requests re-queued/retried by
    # recovery, requests lost (FAILED), transfer retries landed, and
    # the summed fault -> re-admission latency over recovered requests
    n_faults: int = 0
    n_recovered: int = 0
    n_lost: int = 0
    n_transfer_retries: int = 0
    recovery_latency_s: float = 0.0
    # speculative decoding: propose-verify dispatches, drafted tokens
    # sent to verify, and drafted tokens accepted (both planes)
    spec_dispatches: int = 0
    spec_proposed: int = 0
    spec_accepted: int = 0


class Cluster:
    def __init__(self, cfg: ClusterConfig):
        if cfg.backend not in ("sim", "engine"):
            raise ValueError(f"unknown backend {cfg.backend!r}")
        self.cfg = cfg
        # set before the initial _make_worker calls: weight-load faults
        # can fire on the very first provisioning attempts (and those
        # stamp self.now, re-zeroed with the event-loop state below)
        self.faults = cfg.faults
        self.now = 0.0
        self.rng = np.random.default_rng(cfg.seed)
        self.monitor = Monitor(cfg.monitor_interval)
        self.tl = TLManager(cfg.hw)
        # engine plane: per-replica weight ownership (set in
        # _init_engine_plane); None on the sim plane
        self.weights = None
        self._provision_s: Optional[float] = None
        self._provision_strategy: Optional[str] = None
        # sim plane: one cluster-shared prefix index (the engine plane
        # builds a per-replica PrefixCache in _make_worker instead)
        self.prefix_index = None
        if cfg.prefix_cache and cfg.backend == "sim":
            from repro.serving.prefix_cache import SimPrefixIndex

            self.prefix_index = SimPrefixIndex(
                page_size=(cfg.engine.page_size if cfg.engine is not None
                           else 16),
                capacity_pages=cfg.prefix_cache_pages,
            )
        if cfg.backend == "engine":
            self._init_engine_plane()
        else:
            self.truth = AnalyticLatencyModel(cfg.model, cfg.hw, tp=cfg.tp)
            self.fitted = FittedLatencyModel.from_profile(
                self.truth, self.rng
            )
            self._kv_cap = self._kv_capacity()

        self.workers: list[Backend] = []
        for i, role in enumerate(self._initial_roles()):
            self.workers.append(self._make_worker(i, role))
        self._next_wid = len(self.workers)

        # one per-instance load signal (Llumnix-style) shared by the
        # Dispatcher (placement tie-break), the MigrationCoordinator
        # (victim/destination pairing), and the Scaler (target choice).
        # Its ReservationLedger charges every in-flight migration to
        # its destination, so no consumer overcommits a worker that a
        # scheduled-but-not-landed transfer is about to fill.
        self._mig_ledger = ReservationLedger()
        self.load_calc = InstanceLoadCalculator(
            self.fitted, ledger=self._mig_ledger
        )

        self.policy = make_policy(
            cfg.policy, self.fitted, self.monitor, self._do_dispatch,
            load_calc=self.load_calc,
        )
        for w in self.workers:
            if w.role in ("collocated", "prefill"):
                self.policy.add_worker(w, 0.0)

        self.migrator = None
        if cfg.mode == "pd" and not cfg.one_shot_pd:
            # engine plane: transfers are costed on the *measured*
            # payload bytes the source engine would export, not the
            # analytic per-token estimate
            measure = (self._measured_kv_bytes if cfg.backend == "engine"
                       else None)
            self.migrator = Migrator(
                self.fitted, self.monitor, self.tl, cfg.model, tp=cfg.tp,
                measure_bytes=measure, ledger=self._mig_ledger,
            )
        self.coordinator = None
        if cfg.live_migration:
            measure_live = None
            if cfg.backend == "engine":
                measure_live = self._measured_kv_bytes
            self.coordinator = MigrationCoordinator(
                self.load_calc, self.fitted, self.tl, cfg.model,
                tp=cfg.tp, cfg=cfg.migration,
                measure_bytes=measure_live,
            )
        self.scaler = None
        if cfg.scaling:
            self.scaler = Scaler(
                cfg.scaler, self.monitor, self.tl, cfg.model, tp=cfg.tp,
                load_calc=self.load_calc,
                evacuate=cfg.live_migration,
            )

        # event loop state (stepped incrementally by ServingSession)
        self._events: list = []
        self._eseq = itertools.count()
        self._dispatch_at: Optional[float] = None
        self._migrate_scheduled = False
        # evacuations in progress: wid -> deferred ScaleAction, committed
        # by _check_evacuations the moment the worker drains
        self._evac: dict[int, ScaleAction] = {}
        self.n_live_migrations = 0
        self._rr_decode = 0
        self._fit_seen = 0      # profiler samples consumed by last fit
        self.timeline: list = []
        self.now = 0.0          # virtual clock: time of last processed event
        self._started = False
        self._by_wid: dict[int, Backend] = {w.wid: w for w in self.workers}
        # streaming sinks, installed by ServingSession: per-token
        # emission (rid, token_id|None, t) and request completion
        self.on_token: Optional[callable] = None
        self.on_finish: Optional[callable] = None
        # fault-tolerance sinks + machinery: on_failed fires when a
        # request is shed (terminal), on_retried when recovery re-queues
        # or re-routes one (non-terminal)
        self.on_failed: Optional[callable] = None
        self.on_retried: Optional[callable] = None
        self.recovery = RecoveryManager(
            self, cfg.recovery_cfg, enabled=cfg.recovery
        )

    # -- setup -----------------------------------------------------------------
    def _init_engine_plane(self) -> None:
        """Build the shared model/params for real-engine workers; the
        shared FittedLatencyModel doubles as every engine's profiler,
        so the paper's Appendix-A path (measure -> fit -> budget) runs
        on real step times."""
        import jax

        from repro.models import build_model
        from repro.serving.engine import EngineConfig, InferenceEngine
        from repro.serving.weights import WeightManager

        self._engine_cfg = self.cfg.engine or EngineConfig()
        if self.cfg.prefix_cache:
            # cluster-level opt-in overrides the engine config: every
            # replica (including scale-out arrivals) gets a PrefixCache
            self._engine_cfg = dataclasses.replace(
                self._engine_cfg, prefix_cache=True,
                prefix_cache_pages=self.cfg.prefix_cache_pages,
            )
        if self.cfg.spec_decode:
            # same override pattern: every replica speculates, and
            # warm_decode_blocks below compiles the verify buckets too
            self._engine_cfg = dataclasses.replace(
                self._engine_cfg, spec_decode=True,
                max_spec_len=self.cfg.max_spec_len,
            )
        self._engine_model = build_model(self.cfg.model)
        self._engine_params = self._engine_model.init(
            jax.random.key(self.cfg.seed)
        )
        # per-replica weight ownership: the seed tree is provisioning
        # SOURCE material only (host offload + disk checkpoint + the
        # warmup engine below) — every replica gets its OWN tree via a
        # real Table-2 transport, and scale-out measures the move
        self.weights = WeightManager(self._engine_params, tl=self.tl)
        self._fn_cache: dict = {}   # share jitted steps across replicas
        self.truth = None
        self._kv_cap = 0
        self.fitted = FittedLatencyModel()
        # warm the jitted step functions into the shared fn_cache with a
        # throwaway engine and a DETACHED profiler: XLA compile time
        # must pollute neither the run's virtual clock (every queued
        # request's TTFT) nor the Eq. 5 fit the Dispatcher budgets with
        warm = InferenceEngine(
            self._engine_model, self._engine_params, self._engine_cfg,
            profiler=FittedLatencyModel(), fn_cache=self._fn_cache,
        )
        n_warm = max(1, min(4, self._engine_cfg.max_len - 2))
        warm.submit(Request.from_prompt(
            -1, np.arange(1, n_warm + 1, dtype=np.int32), max_new=2))
        warm.run_until_done(max_steps=64)
        # the fused decode-block jits (one per power-of-two K bucket)
        # compile here too — a tiny warm request never reaches K > 1,
        # and the first real block must not pay XLA inside a measured
        # step (it would pollute TTFTs and the Eq. 5 fit)
        warm.warm_decode_blocks()
        if self.cfg.mode == "pd" and not warm.paged:
            raise ValueError(
                "engine-plane P/D needs the paged KV plane (this "
                "model/config falls back to the slot plane); use "
                "mode='collocated' or a chunk-capable model"
            )
        if self.cfg.live_migration and not warm.paged:
            raise ValueError(
                "engine-plane live migration moves paged KV; this "
                "model/config falls back to the slot plane, which "
                "cannot export mid-decode state"
            )
        if not warm.paged:
            # the slot-plane fallback jits prefill per (batch, padded
            # len) shape; compile the whole (bounded) shape lattice now
            # — model.prefill is pure, so direct calls have no engine
            # side effects.  One-time init cost instead of per-shape
            # compile stalls polluting mid-run TTFTs and the Eq. 5 fit.
            import jax.numpy as jnp

            ecfg = self._engine_cfg
            pads, p = [8], 8
            while p < ecfg.max_len - 1:   # mirror engine._pad_to
                p *= 2
                pads.append(p)
            for b in range(1, ecfg.prefill_batch + 1):
                for pad in pads:
                    fn = warm._prefill_fn(pad)
                    out, _ = fn(self._engine_params,
                                jnp.zeros((b, pad), jnp.int32),
                                jnp.ones((b,), jnp.int32))
                    jax.block_until_ready(out)

    def _make_worker(self, wid: int, role: str, active: bool = True,
                     strategy: str = "cpu",
                     donor: Optional[int] = None) -> Backend:
        cfg = self.cfg
        if cfg.backend == "engine":
            from repro.serving.engine import InferenceEngine

            # materialize this replica's OWN params tree through the
            # selected transport; the measured wall time is kept for
            # the scale-out delay and feeds the TLManager's observed
            # transfer model (via WeightManager.provision).  A transport
            # can fail (injected fault, or the d2d donor died mid-pull):
            # fall back along the chain of slower-but-surer sources.
            chain = {"d2d": ("d2d", "cpu", "disk"),
                     "cpu": ("cpu", "disk")}.get(strategy, (strategy,))
            params = None
            last_err: Optional[Exception] = None
            for i, s in enumerate(chain):
                if (self.faults is not None and i + 1 < len(chain)
                        and self.faults.fail_weight_load(self.now, s)):
                    self.timeline.append(
                        (self.now, wid, f"weight_fail:{s}")
                    )
                    continue
                try:
                    params, self._provision_s = self.weights.provision(
                        wid, s, donor=donor if s == "d2d" else None
                    )
                except ValueError as e:   # e.g. donor no longer owns
                    last_err = e
                    continue
                self._provision_strategy = s
                break
            if params is None:
                raise last_err or ValueError(
                    f"no weight source available for worker {wid}"
                )
            eng = InferenceEngine(
                self._engine_model, params, self._engine_cfg,
                profiler=self.fitted, fn_cache=self._fn_cache,
            )
            return EngineWorker(wid, role, eng, active=active)
        return SimWorker(
            wid, role, self.truth, self._kv_cap,
            np.random.default_rng(cfg.seed + 1000 + wid),
            noise=cfg.noise, active=active, chunk_tokens=cfg.chunk_tokens,
            prefix_index=self.prefix_index, spec_decode=cfg.spec_decode,
            max_spec_len=cfg.max_spec_len,
            spec_accept_rate=cfg.spec_accept_rate,
        )

    def _initial_roles(self) -> list[str]:
        if self.cfg.mode == "pd":
            return (["prefill"] * self.cfg.n_prefill
                    + ["decode"] * self.cfg.n_decode)
        return ["collocated"] * self.cfg.n_workers

    def _kv_capacity(self) -> int:
        cfg = self.cfg
        weight_bytes = cfg.model.param_count() * 2 / max(cfg.tp, 1)
        free = max(cfg.hw.hbm_capacity - weight_bytes, 2e9)
        kv_per_tok = AnalyticLatencyModel._kv_bytes_per_token(cfg.model, 2)
        if kv_per_tok <= 0:  # SSM: state only; token capacity is huge
            return 10_000_000
        return int(cfg.tp * free / kv_per_tok)

    def _materialize_prompts(self, requests: Sequence[Request]) -> None:
        """Engine plane needs real token ids; workloads that only carry
        lengths get deterministic synthetic prompts.  Every request is
        validated against the engine's full admission constraints
        (max_len AND the paged fit-alone page bound) up front, so an
        impossible workload fails before the run, not mid-dispatch."""
        from repro.serving.workload import materialize_prompts

        materialize_prompts(
            requests, self.cfg.model.vocab_size, seed=self.cfg.seed,
        )
        # engine.validate is the single validation authority (max_len
        # AND the paged fit-alone bound); replicas share one config
        probe = self.workers[0].engine
        for r in requests:
            probe.validate(r)

    def _measured_kv_bytes(self, r: Request,
                           src: Optional[int] = None) -> Optional[float]:
        """Measured payload bytes a migration of ``r`` would move,
        from the holding worker (``src``; defaults to the prefill
        worker for the P/D hand-off path).  Resolved through the
        ``_by_wid`` index, which retains deactivated workers — a
        scaled-in source's KV stays resident until the transfer lands,
        and its bytes must still cost the move (never silently fall
        back to the analytic estimate mid-scale-in)."""
        w = self._by_wid.get(r.prefill_worker if src is None else src)
        return w.kv_payload_bytes(r) if w is not None else None

    def _pick_donor(self) -> Optional[int]:
        """d2d weight-donor selection: the least-loaded ACTIVE replica
        still owning a live params tree (queue+batch occupancy first,
        monitor utilization as tie-break) — pulling from the idlest
        donor keeps the copy off the hot path.  None = no live donor
        (scale-from-zero); the caller falls back to ``disk``."""
        if self.weights is None:
            return None
        cands = [w for w in self.workers
                 if w.active and not w.evacuating and not w.crashed
                 and self.weights.owns(w.wid)]
        if not cands:
            return None

        def load(w):
            snap = self.monitor.snapshot(w.wid)
            return (len(w.waiting) + len(w.running),
                    snap.utilization if snap else 0.0, w.wid)

        return min(cands, key=load).wid

    # -- event machinery ----------------------------------------------------------
    def _push(self, t: float, kind: str, payload=None) -> None:
        heapq.heappush(self._events, (t, next(self._eseq), kind, payload))

    def _schedule_dispatch(self, t: float) -> None:
        if self._dispatch_at is None or t < self._dispatch_at - 1e-12:
            self._dispatch_at = t
            self._push(t, "dispatch")

    def _schedule_worker(self, w: Backend, t: float) -> None:
        if not w.step_pending and w.active:
            w.step_pending = True
            self._push(t, "worker_step", w.wid)

    # -- dispatch callback (policy -> worker) ----------------------------------------
    def _do_dispatch(self, worker: Backend, reqs: Sequence[Request],
                     now: float) -> None:
        for r in reqs:
            r.prefill_worker = worker.wid
        worker.submit(list(reqs), now)
        if self.cfg.mode == "pd" and self.cfg.one_shot_pd:
            # one-shot: decode instance fixed at arrival time (RR)
            decodes = [w for w in self.workers if w.role == "decode"
                       and w.active]
            for r in reqs:
                if decodes:
                    r.decode_worker = decodes[
                        self._rr_decode % len(decodes)
                    ].wid
                    self._rr_decode += 1
        if worker.busy_until <= now:
            self._schedule_worker(worker, now)

    # -- incremental event-loop API (driven by ServingSession) ---------------------
    def start(self) -> None:
        """Arm the recurring control-plane events (monitor, scaler).
        Idempotent; called once by the first ServingSession attach."""
        if self._started:
            return
        self._started = True
        self._push(self.now, "monitor")
        if self.scaler is not None:
            self._push(self.now + self.cfg.scaler.tau, "scaler")
        if self.faults is not None:
            # scripted crashes enter the event stream up front — they
            # are part of the deterministic replay, not RNG draws
            for c in self.faults.crashes:
                self._push(max(c.t, self.now), "replica_crash", c.wid)

    def enqueue(self, r: Request) -> None:
        """Schedule ``r``'s arrival.  An arrival stamped before the
        processed clock (wall-clock submissions racing the loop) is
        delivered immediately — the virtual clock never runs backwards,
        while ``r.arrival`` keeps the true submit time for metrics."""
        self._push(max(r.arrival, self.now), "arrival", r)

    def next_event_time(self) -> Optional[float]:
        return self._events[0][0] if self._events else None

    def process_next(self) -> Optional[str]:
        """Pop and handle one event; returns its kind (None if idle).
        Advances ``self.now`` to the event's time."""
        if not self._events:
            return None
        now, _, kind, payload = heapq.heappop(self._events)
        self.now = now
        self._handle(kind, payload, now)
        return kind

    def _handle(self, kind: str, payload, now: float) -> None:
        cfg = self.cfg
        by_wid = self._by_wid

        if kind == "arrival":
            r: Request = payload
            if cfg.slo_mapper is not None and r.priority is not None:
                hp = any(
                    q.priority is not None and q.priority < r.priority
                    for q in self.policy.queued_requests()
                )
                r.ttft_slo, r.tpot_slo = cfg.slo_mapper.assign(
                    r.priority, higher_priority_pending=hp
                )
            self.monitor.note_arrival()
            self.policy.on_request_arrive(r)
            self._schedule_dispatch(now)

        elif kind == "dispatch":
            if self._dispatch_at is not None and now >= (
                self._dispatch_at - 1e-12
            ):
                self._dispatch_at = None
            self.policy.dispatch_pass(now)
            nw = self.policy.next_wakeup()
            if self.policy.pending() and nw is not None:
                self._schedule_dispatch(max(nw, now + 1e-6))
            elif self.policy.pending():
                self._schedule_dispatch(now + 0.01)

        elif kind == "worker_step":
            w = by_wid[payload]
            w.step_pending = False
            if not w.active or now < w.busy_until - 1e-12:
                pass
            else:
                out = w.run_step(now)
                if out is not None:
                    if (self.faults is not None
                            and self.faults.has_stragglers()):
                        f = self.faults.slowdown(w.wid, now)
                        if f > 1.0:
                            # stretch the in-flight step: the worker
                            # stays busy (and billed) for the slowdown
                            delta = out.duration * (f - 1.0)
                            out.duration += delta
                            w.busy_until += delta
                            w.busy_time += delta
                    self._push(now + out.duration, "step_done",
                               (w.wid, out))
                    w.step_pending = True

        elif kind == "step_done":
            wid, out = payload
            w = by_wid[wid]
            w.step_pending = False
            if w.crashed:
                # the step died with the process; its residents were
                # (or will be) re-homed by the watchdog
                return
            ev = w.finish_step(out, now)
            # stream tokens before completions so a FIRST_TOKEN always
            # precedes its own FINISHED in any subscriber's log
            if self.on_token is not None:
                for rid, tok, t in ev.tokens:
                    self.on_token(rid, tok, t)
            for r in ev.finished:
                self._finish(r, now)
            if out.kind == "prefill":
                for r in ev.parked:
                    if self.migrator is not None:
                        self.migrator.on_prefill_complete(r)
                    else:  # one-shot: start transfer immediately
                        dst = by_wid.get(r.decode_worker)
                        t_x = self.tl.kv_transfer_time(
                            cfg.model, r.l_in, wid,
                            dst.wid if dst else wid, tp=cfg.tp,
                        )
                        self._push(now + t_x, "kv_ready",
                                   (r, r.decode_worker, wid))
            if self.migrator is not None:
                self._schedule_migrate(now)
            if self._evac:
                # a finishing request may have been the last thing
                # pinning an evacuating worker
                self._check_evacuations(now)
            if w.has_work():
                self._schedule_worker(w, now)
            if out.kind == "prefill":
                # maturity correction applies to prefill only —
                # decode iterations are the slack Eq. 5 budgets
                # against; only a *prefill* finishing early frees
                # the worker ahead of estimate.
                self.policy.notify_worker_free(w.wid, now)
            self._schedule_dispatch(now)

        elif kind == "migrate":
            self._migrate_scheduled = False
            decodes = [w for w in self.workers if w.role == "decode"
                       and not w.evacuating]
            moves = self.migrator.migrate_pass(now, decodes)
            for r, dst, t_x in moves:
                self._push(now + t_x, "kv_ready",
                           (r, dst.wid, r.prefill_worker))

        elif kind == "kv_ready":
            r, dst_wid, src_wid = payload
            # release only OUR reservation: a crash may have re-queued
            # this request and a fresh transfer (new dst) may already
            # hold a new charge this stale event must not drop
            if self._mig_ledger.dst_of(r.rid) == dst_wid:
                self._mig_ledger.release(r.rid)
            live = r.migrating
            r.migrating = False
            src = by_wid.get(src_wid)
            dst = by_wid.get(dst_wid)
            if (r.state in (RequestState.FINISHED, RequestState.FAILED)
                    or src is None or src.crashed
                    or not src.holds_kv(r)):
                # nothing left to move: the request finished during the
                # flight (a live-migration source keeps decoding until
                # the transfer lands) or was recompute-preempted at the
                # source (its KV is gone; the re-prefill owns it now)
                r.migrate_ready = None
                if self._evac:
                    self._check_evacuations(now)
                return
            if dst is None or not dst.active or dst.evacuating:
                # destination vanished (scale-in) or began evacuating
                # mid-transfer: the source keeps the KV resident until
                # a transfer actually lands somewhere.  Clear the stale
                # placement — a dead wid in decode_worker would
                # misdirect anything keying on it.
                r.decode_worker = None
                r.migrate_ready = None
                if not live and self.migrator is not None:
                    self.migrator.on_prefill_complete(r)
                    self._schedule_migrate(now)
                # live moves just stay on their source; the next
                # coordinator pass re-plans them
                return
            if (self.faults is not None
                    and self.faults.drop_kv_transfer(now, r.rid,
                                                     src_wid, dst_wid)):
                # the transfer failed in flight: KV stays resident at
                # the source; recovery retries (capped backoff,
                # alternate destination) or falls back
                self.timeline.append(
                    (now, src_wid, f"kv_drop:{r.rid}->{dst_wid}")
                )
                self.recovery.on_transfer_fail(
                    r, src_wid, dst_wid, now, live
                )
                return
            if src is not None:
                # engine plane: materialize the pages + generation
                # state (captured at transfer completion, so a
                # mid-decode source contributes its newest tokens);
                # sim plane: nothing physical to move
                pk = src.export_kv(r)
                if pk is not None:
                    r.kv_payload = pk
                src.free_kv(r)
                if src.active and src.has_work():
                    # the freed slot/pages may unblock prompts that
                    # queued while the source was fully parked
                    self._schedule_worker(src, now)
            dst.accept_migrated(r, now)
            r.decode_worker = dst.wid
            r.n_migrations += 1
            r.last_migrated = now
            self.recovery.on_transfer_landed(r)
            if live:
                self.n_live_migrations += 1
            self._schedule_worker(dst, now)
            if self._evac:
                # the export above may have drained an evacuating source
                self._check_evacuations(now)

        elif kind == "monitor":
            self.monitor.update(now, [w for w in self.workers
                                      if w.active])
            # health watchdog rides the monitor cadence: detection
            # latency for a crash is at most one monitor interval
            self.recovery.watchdog(now)
            if cfg.backend == "engine":
                # refit Eq. 1/2 from the engines' measured steps so
                # the Dispatcher budgets on live coefficients —
                # but only when new samples landed since last tick
                n = self.fitted.n_samples()
                if n > self._fit_seen:
                    self.fitted.fit(min_samples=4)
                    self._fit_seen = n
            if self.coordinator is not None:
                # live-migration planning rides the monitor cadence:
                # rescue predicted-miss requests, rebalance ramps, and
                # retry evacuations whose victims had nowhere to go
                self._rebalance(now)
                if self._evac:
                    self._check_evacuations(now)
            self._push(now + self.monitor.interval, "monitor")

        elif kind == "scaler":
            self._scaler_tick(now, by_wid)
            self._push(now + cfg.scaler.tau, "scaler")

        elif kind == "worker_up":
            wid, role = payload
            w = by_wid[wid]
            w.activate(now, role)
            self.tl.ensure_links(wid, [x.wid for x in self.workers
                                       if x.wid != wid])
            if role in ("collocated", "prefill"):
                self.policy.add_worker(w, now)
            self.timeline.append((now, wid, f"up:{role}"))
            self._schedule_dispatch(now)
            if self.migrator is not None:
                self._schedule_migrate(now)

        elif kind == "role_flip":
            wid, role = payload
            self._apply_role_flip(by_wid[wid], role, now)
            self._schedule_dispatch(now)
            if self.migrator is not None:
                self._schedule_migrate(now)

        elif kind == "replica_crash":
            w = by_wid.get(payload)
            if w is not None and w.active and not w.crashed:
                # the process is gone NOW; recovery (resident re-homing,
                # weight release) runs at the next watchdog tick, which
                # models the detection latency
                w.crashed = True
                w.deactivate(now)
                if self.faults is not None:
                    self.faults.note(now, "crash", f"wid={w.wid}")
                self.recovery.note_crash(w.wid, now)
                self.timeline.append((now, w.wid, "crash"))

        elif kind == "kv_retry":
            self.recovery.retry_transfer(payload, now)

    def collect_result(self, requests: Sequence[Request]) -> ClusterResult:
        makespan = self.now
        cost = sum(w.total_up_time(makespan) for w in self.workers) / (
            COST_UNIT
        )
        m = compute_metrics(list(requests), cost, makespan)
        hist: dict[int, int] = {}
        n_dec_tok = n_disp = n_pf = 0
        sp_disp = sp_prop = sp_acc = 0
        pstats: dict = {}
        if self.cfg.backend == "engine":
            for w in self.workers:
                for k, n in w.engine.decode_block_hist.items():
                    hist[k] = hist.get(k, 0) + n
                n_dec_tok += w.engine.n_decode_tokens
                n_disp += w.engine.n_dispatches
                n_pf += w.engine.n_prefill_tokens
                sp_disp += w.engine.n_spec_dispatches
                sp_prop += w.engine.n_spec_proposed
                sp_acc += w.engine.n_spec_accepted
                if w.engine.prefix is not None:
                    for k, v in w.engine.prefix.stats().items():
                        pstats[k] = pstats.get(k, 0) + v
        else:
            for w in self.workers:
                sp_disp += w.spec_dispatches
                sp_prop += w.spec_proposed
                sp_acc += w.spec_accepted
            if self.prefix_index is not None:
                pstats = self.prefix_index.stats()
        return ClusterResult(
            metrics=m,
            requests=list(requests),
            timeline=self.timeline,
            monitor=self.monitor,
            n_scale_out=self.scaler.n_scale_out if self.scaler else 0,
            n_scale_in=self.scaler.n_scale_in if self.scaler else 0,
            n_role_flips=self.scaler.n_role_flips if self.scaler else 0,
            kv_transfers=self.tl.n_kv_transfers,
            decode_block_hist=hist,
            n_decode_tokens=n_dec_tok,
            n_dispatches=n_disp,
            n_prefill_tokens=n_pf,
            prefix_stats=pstats,
            n_live_migrations=self.n_live_migrations,
            n_rescues=(self.coordinator.n_rescues
                       if self.coordinator else 0),
            n_evacuations=(self.coordinator.n_evacuations
                           if self.coordinator else 0),
            n_faults=self.faults.n_injected if self.faults else 0,
            n_recovered=self.recovery.n_recovered,
            n_lost=self.recovery.n_lost,
            n_transfer_retries=self.recovery.n_transfer_retries,
            recovery_latency_s=round(self.recovery.recovery_latency_s, 4),
            spec_dispatches=sp_disp,
            spec_proposed=sp_prop,
            spec_accepted=sp_acc,
        )

    # -- batch adapter -------------------------------------------------------------
    def run(self, requests: Sequence[Request]) -> ClusterResult:
        """Closed-world replay: submit the whole workload through a
        ServingSession and drain it.  Thin adapter — the event loop
        lives in :class:`~repro.serving.session.ServingSession`, so the
        batch and online paths cannot diverge."""
        from repro.serving.session import ServingSession

        if self.cfg.backend == "engine":
            self._materialize_prompts(requests)
        for r in requests:
            if r.arrival is None:  # open-loop default: all at t=0
                r.arrival = 0.0
        session = ServingSession(self, admission="none")
        for r in requests:
            session.submit_request(r)
        session.drain()
        return session.close(requests=list(requests))

    # -- helpers ------------------------------------------------------------------
    def _finish(self, r: Request, now: float) -> None:
        self.monitor.note_completion()
        cfg = self.cfg
        if cfg.slo_mapper is not None and r.priority is not None:
            q_time = (r.dispatch_time or r.arrival) - r.arrival
            if r.ttft is not None and r.tpot is not None:
                cfg.slo_mapper.observe(
                    r.priority, r.ttft, max(r.tpot, 1e-4), q_time
                )
        if self.on_finish is not None:
            self.on_finish(r, now)

    def _apply_role_flip(self, w: Backend, role: str, now: float) -> bool:
        """Commit a scheduled role transition.  The scaler only flips
        drained workers, but demand can land during the transition
        delay — re-check at commit time and abort rather than strand
        freshly-dispatched work on a wrong-role worker (a sim prefill
        worker flipped to decode would never drain its waiting queue)."""
        if role != w.role and not w.is_drained():
            self.timeline.append((now, w.wid, f"role_flip_skipped:{role}"))
            return False
        was = w.role
        w.role = role
        if role in ("collocated", "prefill"):
            self.policy.add_worker(w, now)
        elif was in ("collocated", "prefill"):
            self.policy.remove_worker(w.wid)
        self.timeline.append((now, w.wid, f"role:{was}->{role}"))
        return True

    def _schedule_migrate(self, now: float) -> None:
        if self.migrator is not None and not self._migrate_scheduled:
            self._migrate_scheduled = True
            self._push(now, "migrate")

    # -- live migration (decode-to-decode) -----------------------------------------
    def _rebalance(self, now: float) -> None:
        """One MigrationCoordinator planning pass: evacuate workers the
        scaler wants emptied and rescue predicted-TPOT-miss requests
        onto less-loaded decode instances.  Each planned move schedules
        a ``kv_ready`` after the TLManager-costed transfer time; the
        victim keeps decoding on its source until the transfer lands."""
        moves = self.coordinator.plan(now, self.workers,
                                      evacuating=self._evac.keys())
        for r, src, dst, t_x, reason in moves:
            r.migrate_ready = now + t_x
            self._push(now + t_x, "kv_ready", (r, dst.wid, src.wid))
            self.timeline.append(
                (now, src.wid, f"migrate:{reason}:{r.rid}->{dst.wid}")
            )

    def _begin_evacuation(self, w: Backend, a, now: float) -> None:
        """Start emptying ``w`` for a deferred scale-in / role flip.
        The worker stops taking new placements immediately (policy
        removal + ``evacuating`` flag, which the Migrator/coordinator
        destination filters honor); its residents are live-migrated off
        and the pending action commits in :meth:`_check_evacuations`
        the moment it drains."""
        if w.evacuating or w.wid in self._evac:
            return
        w.evacuating = True
        self._evac[w.wid] = a
        if w.role in ("collocated", "prefill"):
            self.policy.remove_worker(w.wid)
        self.timeline.append(
            (now, w.wid, f"evacuate:{a.kind}:{a.role}")
        )
        self._rebalance(now)
        self._check_evacuations(now)

    def _check_evacuations(self, now: float) -> None:
        """Commit pending evacuations whose worker has drained: the
        deferred scale-in deactivates it, the deferred role flip is
        pushed with its normal transition delay.  In-flight exports
        keep the source undrained (running/parked non-empty) until
        their ``kv_ready`` frees the KV, so committing here can never
        strand a resident request."""
        done = [wid for wid, a in self._evac.items()
                if self._by_wid[wid].is_drained()]
        for wid in done:
            a = self._evac.pop(wid)
            w = self._by_wid[wid]
            w.evacuating = False
            if a.kind == "role":
                self._push(now + a.delay, "role_flip", (wid, a.role))
            else:
                self._commit_scale_in(w, now)

    def _commit_scale_in(self, w: Backend, now: float) -> None:
        w.deactivate(now)
        if self.cfg.backend == "engine":
            # reclaim the replica's owned weight copy (it also
            # stops being a d2d donor candidate)
            self.weights.release(w.wid)
            w.engine.release_weights()
        if w.role in ("collocated", "prefill"):
            self.policy.remove_worker(w.wid)
        self.timeline.append((now, w.wid, "scale_in"))

    def _scaler_tick(self, now: float, by_wid) -> None:
        cfg = self.cfg
        queued = self.policy.queued_requests()
        if cfg.mode == "pd":
            dq = self.migrator.queue.items() if self.migrator else []
            actions = self.scaler.tick_pd(now, self.workers, queued, dq)
        else:
            actions = self.scaler.tick(now, self.workers, queued,
                                       pool="any")
        for a in actions:
            if a.kind == "out":
                role = a.role if a.role != "any" else "collocated"
                strategy = a.strategy or cfg.scaler.weight_strategy
                donor = None
                if cfg.backend == "engine":
                    donor = self._pick_donor()
                    if strategy == "d2d" and donor is None:
                        # commit-time re-check: the donor the scaler
                        # assumed may have scaled in since its tick
                        strategy = "disk"
                if cfg.backend != "engine" and self.faults is not None:
                    # sim plane: weight-load faults walk the same
                    # fallback chain; the slower transport's modeled
                    # time replaces the scaler's assumed delay
                    chain = {"d2d": ("d2d", "cpu", "disk"),
                             "cpu": ("cpu", "disk")}.get(strategy,
                                                         (strategy,))
                    for i, s in enumerate(chain):
                        if (i + 1 < len(chain)
                                and self.faults.fail_weight_load(now, s)):
                            self.timeline.append(
                                (now, self._next_wid, f"weight_fail:{s}")
                            )
                            continue
                        if s != strategy:
                            strategy = s
                            a.delay = self.tl.weight_load_time(
                                cfg.model, s, tp=cfg.tp, warm=a.warm
                            )
                        break
                w = self._make_worker(self._next_wid, role, active=False,
                                      strategy=strategy, donor=donor)
                delay = a.delay
                if cfg.backend == "engine":
                    # the provisioning transfer really ran: the
                    # measured wall time (plus runtime init when the
                    # warm pool was dry) IS the cold-start delay
                    delay = self._provision_s + (
                        0.0 if a.warm else self.tl.costs.runtime_warmup
                    )
                    # the fallback chain may have demoted the transport
                    strategy = self._provision_strategy or strategy
                self.workers.append(w)
                by_wid[w.wid] = w
                self._next_wid += 1
                self._push(now + delay, "worker_up", (w.wid, role))
                self.timeline.append(
                    (now, w.wid, f"scale_out:{strategy}({delay:.2f}s)")
                )
            elif a.kind == "in":
                w = by_wid[a.worker_id]
                if w.evacuating:
                    continue  # already being emptied for another action
                if self.coordinator is not None and not w.is_drained():
                    # migrate-then-scale-in: empty the target first,
                    # commit the moment it drains
                    self._begin_evacuation(w, a, now)
                else:
                    self._commit_scale_in(w, now)
            elif a.kind == "role":
                w = by_wid[a.worker_id]
                if w.evacuating:
                    continue
                if self.coordinator is not None and not w.is_drained():
                    # migrate-then-flip: residents move off live instead
                    # of the pool waiting for a natural drain
                    self._begin_evacuation(w, a, now)
                else:
                    self._push(now + a.delay, "role_flip",
                               (w.wid, a.role))


def run_cluster(cfg: ClusterConfig, requests) -> ClusterResult:
    return Cluster(cfg).run(requests)
