"""Backend protocol: the control plane <-> execution plane contract.

The HFX control plane (Dispatcher/Algorithm 1, Migrator, Monitor,
Scaler/Algorithm 3, PrioritySLOMapper/Algorithm 2) never talks to an
execution engine directly — it talks to a :class:`Backend`: a worker
that accepts dispatched :class:`~repro.core.request.Request` objects,
runs bounded steps (one prefill chunk or one decode iteration), and
reports telemetry via :class:`~repro.core.monitor.WorkerSnapshot`.

Two implementations share the contract:

- :class:`~repro.serving.worker.SimWorker` — discrete-event simulation;
  step durations come from an analytic roofline latency model.
- :class:`EngineWorker` (here) — wraps a real
  :class:`~repro.serving.engine.InferenceEngine`; steps run actual
  jitted model compute, and the *measured* wall time of each step
  becomes the event duration, so the cluster's virtual clock advances
  by real latencies and the engine's profiler grounds the dispatcher's
  Eq. 5 budgets.

The step contract is two-phase so the event loop can schedule the
completion at ``now + duration``:

    outcome = worker.run_step(now)          # pick + start (or execute)
    ...at now + outcome.duration...
    events = worker.finish_step(outcome, t) # apply token/time bookkeeping

``run_step`` returns ``None`` when the worker has nothing to do.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Protocol, Sequence, runtime_checkable

from repro.core.monitor import WorkerSnapshot
from repro.core.request import Request, RequestState


@dataclasses.dataclass
class StepOutcome:
    """One started (sim) or executed (engine) worker step.

    A decode step may be a fused multi-token *block* (engine plane):
    ``info`` then carries ``k`` (fused iterations) and ``tokens``
    (tokens actually emitted), ``duration`` spans the whole block, and
    per-request TTFT/TPOT stamps are interpolated inside it by the
    engine — so control-plane accounting needs no per-token events.
    """

    kind: str                  # "prefill" | "decode"
    duration: float            # seconds of (virtual or measured) time
    # requests whose prefill completed during this step
    prefilled: list = dataclasses.field(default_factory=list)
    # requests that finished during this step (engine plane fills this
    # during run_step; the sim plane derives it in finish_step)
    finished: list = dataclasses.field(default_factory=list)
    info: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class StepEvents:
    """What ``finish_step`` reports back to the control loop."""

    finished: list             # completed at step end
    parked: list               # prefilled, awaiting migration (P/D)
    # per-token stream: (rid, token_id | None, t_emit) for every token
    # this step produced, in emission order per request.  The engine
    # fills real token ids with per-lane interpolated stamps from the
    # fused decode block (no extra host syncs — the block's single
    # sync already brought the (n_slots, K) token matrix over); the
    # simulator emits id-less ticks timed by the latency model.
    tokens: list = dataclasses.field(default_factory=list)


@runtime_checkable
class Backend(Protocol):
    """Structural interface both execution planes implement."""

    wid: int
    role: str                  # "collocated" | "prefill" | "decode" | "warm"
    active: bool
    busy_until: float
    step_pending: bool
    kv_capacity: int
    evacuating: bool           # being emptied for a flip/scale-in
    crashed: bool              # process died (fault injection)

    def submit(self, reqs: Sequence[Request], now: float) -> None: ...
    def drop_all(self, now: float) -> list: ...
    def accept_migrated(self, r: Request, now: float) -> None: ...
    def export_kv(self, r: Request): ...
    def holds_kv(self, r: Request) -> bool: ...
    def kv_payload_bytes(self, r: Request) -> Optional[float]: ...
    def run_step(self, now: float) -> Optional[StepOutcome]: ...
    def finish_step(self, out: StepOutcome, now: float) -> StepEvents: ...
    def kv_tokens(self) -> int: ...
    def prefix_peek(self, r: Request) -> int: ...
    def free_kv(self, r: Request) -> bool: ...
    def is_drained(self) -> bool: ...
    def snapshot(self, now: float, utilization: float) -> WorkerSnapshot: ...
    def has_work(self) -> bool: ...
    def is_busy(self, now: float) -> bool: ...
    def activate(self, now: float, role: Optional[str] = None) -> None: ...
    def deactivate(self, now: float) -> None: ...
    def total_up_time(self, end: float) -> float: ...


class WorkerBase:
    """Shared lifecycle/telemetry plumbing for both planes.

    Subclasses provide ``waiting`` / ``running`` / ``parked`` views
    (lists of Request) plus the step methods of the protocol.
    """

    def __init__(self, wid: int, role: str, kv_capacity: int,
                 active: bool = True):
        self.wid = wid
        self.role = role
        self.kv_capacity = kv_capacity
        self.active = active
        self.busy_until = 0.0
        self.busy_time = 0.0
        self.up_since: Optional[float] = 0.0 if active else None
        self.up_time = 0.0
        self.step_pending = False  # a worker_step event is in flight
        # live migration: the cluster is emptying this worker for a
        # pending role flip / scale-in — no new placements, no new
        # migration destinations; cleared when the action commits
        self.evacuating = False
        # fault injection: the replica process died — its in-flight
        # step results are dropped and the RecoveryManager re-homes
        # its residents on the next watchdog pass
        self.crashed = False

    # -- state ---------------------------------------------------------------
    def kv_tokens(self) -> int:
        return (sum(r.cur_len for r in self.running)
                + sum(r.l_in for r in self.waiting)
                + sum(r.cur_len for r in self.parked))

    def is_busy(self, now: float) -> bool:
        return self.busy_until > now or bool(self.waiting or self.running)

    def is_drained(self) -> bool:
        """True when this worker can safely flip roles or scale in: no
        queued or running work AND no parked KV awaiting migration
        (dropping a prefill worker that still holds exported-pending
        pages would strand them).  Load-bearing for the Scaler's
        flip/scale-in candidate choice and the Cluster's role-flip
        commit re-check."""
        return (self.active and not self.waiting and not self.running
                and not self.parked)

    def has_work(self) -> bool:
        if self.role == "prefill":
            return bool(self.waiting)
        if self.role == "decode":
            return bool(self.running)
        return bool(self.waiting or self.running)

    def free_kv(self, r: Request) -> bool:
        return False

    def prefix_peek(self, r: Request) -> int:
        """Prefix-cache hit (tokens) ``r`` would get if prefilled on
        this worker now; 0 when the plane has no prefix cache.  The
        Dispatcher charges only the uncached suffix against Eq. 5."""
        return 0

    def export_kv(self, r: Request):
        """Materialize ``r``'s KV for a hand-off; None when the plane
        has nothing physical to move (the simulator's caches are
        implicit — transfer time alone models the move)."""
        return None

    def holds_kv(self, r: Request) -> bool:
        """True while ``r``'s KV is still resident here in an
        exportable state.  The source-side guard a pending migration
        checks when its transfer lands: the request may have finished
        or been recompute-preempted during the flight, in which case
        there is nothing left to move."""
        return r in self.running or r in self.parked

    def kv_payload_bytes(self, r: Request) -> Optional[float]:
        """Measured size of the KV state a migration would move; None
        when only the analytic per-token estimate exists."""
        return None

    def accept_migrated(self, r: Request, now: float) -> None:
        """A migrated request's KV landed on this worker (P/D decode
        placement).  Planes that can't receive foreign KV must say so
        loudly rather than silently dropping the request."""
        raise NotImplementedError(
            f"{type(self).__name__} cannot accept migrated KV"
        )

    def snapshot(self, now: float, utilization: float) -> WorkerSnapshot:
        waiting = list(self.waiting)
        running = list(self.running)
        return WorkerSnapshot(
            wid=self.wid,
            role=self.role,
            time=now,
            busy=self.is_busy(now),
            n_waiting=len(waiting),
            n_running=len(running),
            kv_tokens=self.kv_tokens(),
            cur_lens=tuple(r.cur_len for r in running),
            waiting_tokens=sum(r.l_in for r in waiting),
            utilization=utilization,
        )

    # -- lifecycle ------------------------------------------------------------
    def activate(self, now: float, role: Optional[str] = None) -> None:
        self.active = True
        if role:
            self.role = role
        if self.up_since is None:
            self.up_since = now

    def deactivate(self, now: float) -> None:
        self.active = False
        if self.up_since is not None:
            self.up_time += now - self.up_since
            self.up_since = None

    def total_up_time(self, end: float) -> float:
        t = self.up_time
        if self.up_since is not None:
            t += end - self.up_since
        return t


class EngineWorker(WorkerBase):
    """Backend over a real :class:`InferenceEngine`.

    The cluster's control plane drives jitted model compute: each
    ``run_step`` executes one engine step (prefill chunk or decode
    iteration) immediately, and the measured wall time becomes the
    event duration, so cluster virtual time tracks real latencies.
    The engine's clock is re-synced to cluster time before every step,
    which makes the engine's own first-token / finish stamps land in
    cluster time with no translation layer.

    The wrapped engine's ``profiler`` is (by construction in
    ``Cluster``) the same :class:`FittedLatencyModel` instance the
    Dispatcher budgets with — the paper's Appendix-A profiler path,
    fed by real step times.

    P/D roles run on the engine's paged plane: a ``role="prefill"``
    engine parks prefill-complete requests (KV resident, no decode)
    until the Migrator places them; ``export_kv`` materializes the
    pages + generation state and ``accept_migrated`` installs them on
    the decode engine, which continues the stream token-identically.
    """

    def __init__(self, wid: int, role: str, engine, active: bool = True):
        self.engine = engine  # before super(): the role setter syncs it
        super().__init__(wid, role, kv_capacity=engine.kv_token_capacity(),
                         active=active)
        # the engine executes steps eagerly in run_step, so a request
        # can complete (and leave every engine pool) while its step is
        # still in flight in cluster time; track those until the step's
        # events surface, or a crash teardown would strand them
        self._inflight_done: list[Request] = []

    # -- role (drives the engine's park-on-prefill behavior) -------------------
    @property
    def role(self) -> str:
        return self._role

    @role.setter
    def role(self, value: str) -> None:
        if value in ("prefill", "decode") and not self.engine.paged:
            raise ValueError(
                f"P/D roles need the engine's paged plane (worker "
                f"{self.wid} runs the slot fallback); use collocated"
            )
        self._role = value
        self.engine.park_on_prefill = (value == "prefill")

    # -- views over engine state ----------------------------------------------
    @property
    def waiting(self) -> list[Request]:
        e = self.engine
        return list(e.queue) + list(e.prefilling.values())

    @property
    def running(self) -> list[Request]:
        return list(self.engine.active.values())

    @property
    def parked(self) -> list[Request]:
        return list(self.engine.parked.values())

    def kv_tokens(self) -> int:
        e = self.engine
        resident = sum(int(e.pos[s]) for s in e.active)
        resident += sum(int(e.pos[s]) for s in e.parked)
        resident += sum(r.prefill_progress for r in e.prefilling.values())
        # queued prompts are committed budget, mirroring SimWorker
        resident += sum(len(r.prompt) for r in e.queue)
        return resident

    def has_work(self) -> bool:
        # unlike the sim plane, an engine progresses whatever it holds
        # regardless of role (e.g. a decode engine re-prefills its own
        # recompute-preempted requests); roles only steer *placement*
        e = self.engine
        return bool(e.queue or e.prefilling or e.active)

    # -- intake ----------------------------------------------------------------
    def submit(self, reqs: Sequence[Request], now: float) -> None:
        e = self.engine
        e.clock = max(e.clock, now)
        for r in reqs:
            if r.prompt is None:
                raise ValueError(
                    f"request {r.rid} has no token ids; materialize "
                    f"prompts before dispatching to the engine plane"
                )
            e.submit(r)

    # -- step contract ---------------------------------------------------------
    def run_step(self, now: float) -> Optional[StepOutcome]:
        e = self.engine
        e.clock = now
        n_fin = len(e.finished)
        n_parked = len(e.parked)
        info = e.step()
        if info.get("kind") in (None, "idle"):
            return None
        dur = float(info.get("time", 0.0))
        kind = "prefill" if info["kind"].startswith("prefill") else "decode"
        out = StepOutcome(kind=kind, duration=dur, info=info)
        out.finished = list(e.finished[n_fin:])
        self._inflight_done = list(out.finished)
        # requests parked during this step (prefill-role engines) —
        # steps only ever append to `parked`, so the tail is exact
        out.info["parked_now"] = list(e.parked.values())[n_parked:]
        self.busy_until = now + dur
        self.busy_time += dur
        return out

    def finish_step(self, out: StepOutcome, now: float) -> StepEvents:
        # compute (and its request bookkeeping) already happened in
        # run_step at engine level; just report the events
        self._inflight_done = []
        return StepEvents(finished=list(out.finished),
                          parked=out.info.pop("parked_now", []),
                          tokens=out.info.pop("token_events", []))

    def prefix_peek(self, r: Request) -> int:
        return self.engine.peek_prefix(r.prompt)

    # -- P/D hand-off ----------------------------------------------------------
    def export_kv(self, r: Request):
        return self.engine.export_kv(r.rid)

    def holds_kv(self, r: Request) -> bool:
        return self.engine.exportable(r.rid)

    def kv_payload_bytes(self, r: Request) -> Optional[float]:
        return self.engine.kv_bytes_of(r.rid)

    def accept_migrated(self, r: Request, now: float) -> None:
        e = self.engine
        e.clock = max(e.clock, now)
        payload, r.kv_payload = r.kv_payload, None
        if payload is None:
            raise ValueError(
                f"request {r.rid} arrived at worker {self.wid} without "
                f"a KV payload; engine-plane migration requires "
                f"export_kv at the source"
            )
        while not e.import_kv(payload, r):
            # destination momentarily full: recompute-preempt the
            # youngest resident (validate() guarantees any single
            # request fits alone, so this terminates)
            if not e._preempt_youngest(exclude=-1):
                raise RuntimeError(
                    f"worker {self.wid} cannot place migrated request "
                    f"{r.rid}: no slot/pages and nothing preemptible"
                )

    def free_kv(self, r: Request) -> bool:
        e = self.engine
        if r.slot is not None and (r in e.active.values()
                                   or r in e.prefilling.values()
                                   or r in e.parked.values()):
            e.evict(r.slot)
            return True
        if r in e.queue:
            e.queue.remove(r)
            return True
        return False

    def drop_all(self, now: float) -> list[Request]:
        """Crash teardown: evict every resident (queued, prefilling,
        decoding, parked) and return them for re-homing.  Leaves the
        engine fully empty so ``release_weights`` succeeds."""
        e = self.engine
        residents = (list(e.queue) + list(e.prefilling.values())
                     + list(e.active.values()) + list(e.parked.values()))
        for s in list(e.prefilling):
            e.evict(s)
        for s in list(e.active):
            e.evict(s)
        for s in list(e.parked):
            e.evict(s)
        e.queue.clear()
        # requests that completed inside the still-in-flight step: the
        # step died with the process, so in cluster time those
        # completions never happened — revert them and hand them to
        # recovery with everything else
        for r in self._inflight_done:
            r.state = RequestState.PREEMPTED
            r.finish_time = None
        residents += self._inflight_done
        self._inflight_done = []
        return residents
