"""Evaluation metrics (paper §7.5): attainment, E2E latency, cost."""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.request import Request

COST_UNIT = 0.05  # one unit = one instance active for 50 ms


@dataclasses.dataclass
class RunMetrics:
    attainment: float
    ttft_attainment: float
    tpot_attainment: float
    mean_e2e: float
    p99_e2e: float
    mean_ttft: float
    cost_units: float
    makespan: float
    n_finished: int
    n_total: int
    per_task: dict

    def row(self) -> dict:
        """Canonical flat/JSON payload — identical schema for simulator
        and engine-backed runs, including the per-task SLO-attainment
        breakdown (TTFT and TPOT separately), so multi-SLO claims are
        inspectable per task class."""
        return {
            "attainment": round(self.attainment, 4),
            "ttft_attainment": round(self.ttft_attainment, 4),
            "tpot_attainment": round(self.tpot_attainment, 4),
            "mean_e2e": round(self.mean_e2e, 3),
            "p99_e2e": round(self.p99_e2e, 3),
            "mean_ttft": round(self.mean_ttft, 4),
            "cost_units": round(self.cost_units, 1),
            "makespan": round(self.makespan, 2),
            "n_finished": self.n_finished,
            "n_total": self.n_total,
            "per_task": {
                t: {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in stats.items()}
                for t, stats in self.per_task.items()
            },
        }


def compute_metrics(requests: Sequence[Request], cost_units: float,
                    makespan: float) -> RunMetrics:
    fin = [r for r in requests if r.finish_time is not None]
    n = len(requests)
    att = sum(1 for r in fin if r.attained()) / max(n, 1)
    ttft_att = sum(1 for r in fin if r.ttft_ok()) / max(n, 1)
    tpot_att = sum(1 for r in fin if r.tpot_ok()) / max(n, 1)
    e2e = np.array([r.e2e for r in fin]) if fin else np.array([0.0])
    ttfts = np.array([r.ttft for r in fin]) if fin else np.array([0.0])
    per_task: dict[str, dict] = {}
    tasks = sorted({r.task for r in requests})
    for t in tasks:
        tf = [r for r in fin if r.task == t]
        tn = sum(1 for r in requests if r.task == t)
        per_task[t] = {
            "attainment": sum(1 for r in tf if r.attained()) / max(tn, 1),
            "ttft_attainment": sum(
                1 for r in tf if r.ttft_ok()) / max(tn, 1),
            "tpot_attainment": sum(
                1 for r in tf if r.tpot_ok()) / max(tn, 1),
            "mean_e2e": float(np.mean([r.e2e for r in tf])) if tf else 0.0,
            "mean_ttft": float(np.mean([r.ttft for r in tf])) if tf else 0.0,
            "n": tn,
            "n_finished": len(tf),
        }
    return RunMetrics(
        attainment=att,
        ttft_attainment=ttft_att,
        tpot_attainment=tpot_att,
        mean_e2e=float(np.mean(e2e)),
        p99_e2e=float(np.percentile(e2e, 99)),
        mean_ttft=float(np.mean(ttfts)),
        cost_units=cost_units,
        makespan=makespan,
        n_finished=len(fin),
        n_total=n,
        per_task=per_task,
    )
