"""Evaluation metrics (paper §7.5): attainment, E2E latency, cost.

Two views over the same request records:

- :func:`compute_metrics` — the closed-world post-run summary
  (:class:`RunMetrics`), identical schema for simulator and engine
  runs.
- Streaming/incremental — :meth:`RunMetrics.partial` computes a
  *rolling* snapshot mid-run (attainment over finished-so-far, not a
  denominator that counts still-in-flight work as misses), and
  :class:`StreamingStats` accumulates per-event figures the batch
  summary can't see (TTFB from the event stream, inter-token latency,
  admit/reject counters) without ever scanning the request list.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.request import Request, RequestState

COST_UNIT = 0.05  # one unit = one instance active for 50 ms


@dataclasses.dataclass
class RunMetrics:
    attainment: float
    ttft_attainment: float
    tpot_attainment: float
    mean_e2e: float
    p99_e2e: float
    mean_ttft: float
    cost_units: float
    makespan: float
    n_finished: int
    n_total: int
    per_task: dict
    # refused at submit time by admission control (online sessions);
    # rejected requests count in n_total and against attainment
    n_rejected: int = 0
    # lost to a fault (replica crash / unrecoverable transfer) after
    # admission; like rejected, they count in n_total and against
    # attainment — a shed request IS the degradation the fault caused
    n_failed: int = 0
    # prefix cache: prompt tokens served from cached KV pages instead
    # of prefilled, and the hit fraction over all offered prompt tokens
    # (non-rejected requests).  Zero when the cache is off — the schema
    # is identical either way, and on both planes.
    prefix_hit_tokens: int = 0
    prefix_hit_rate: float = 0.0
    # requests that experienced >= 1 landed KV migration (P/D hand-off
    # or live decode-to-decode) and total landed moves — zero without
    # migration, same schema on both planes
    n_migrated: int = 0
    n_kv_moves: int = 0

    def row(self) -> dict:
        """Canonical flat/JSON payload — identical schema for simulator
        and engine-backed runs, including the per-task SLO-attainment
        breakdown (TTFT and TPOT separately), so multi-SLO claims are
        inspectable per task class."""
        return {
            "attainment": round(self.attainment, 4),
            "ttft_attainment": round(self.ttft_attainment, 4),
            "tpot_attainment": round(self.tpot_attainment, 4),
            "mean_e2e": round(self.mean_e2e, 3),
            "p99_e2e": round(self.p99_e2e, 3),
            "mean_ttft": round(self.mean_ttft, 4),
            "cost_units": round(self.cost_units, 1),
            "makespan": round(self.makespan, 2),
            "n_finished": self.n_finished,
            "n_total": self.n_total,
            "n_rejected": self.n_rejected,
            "n_failed": self.n_failed,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "prefix_hit_rate": round(self.prefix_hit_rate, 4),
            "n_migrated": self.n_migrated,
            "n_kv_moves": self.n_kv_moves,
            "per_task": {
                t: {k: (round(v, 4) if isinstance(v, float) else v)
                    for k, v in stats.items()}
                for t, stats in self.per_task.items()
            },
        }

    @classmethod
    def partial(cls, requests: Sequence[Request], cost_units: float,
                now: float) -> "RunMetrics":
        """Rolling mid-run snapshot: attainment rates are over the
        requests *finished so far* (an in-flight request is not yet a
        miss), while ``n_total`` / ``n_rejected`` still report the full
        offered load.  ``makespan`` is the current clock."""
        fin = [r for r in requests if r.finish_time is not None]
        m = compute_metrics(fin, cost_units, now)
        m.n_total = len(requests)
        m.n_rejected = sum(
            1 for r in requests if r.state == RequestState.REJECTED
        )
        m.n_failed = sum(
            1 for r in requests if r.state == RequestState.FAILED
        )
        return m


def compute_metrics(requests: Sequence[Request], cost_units: float,
                    makespan: float) -> RunMetrics:
    fin = [r for r in requests if r.finish_time is not None]
    n = len(requests)
    att = sum(1 for r in fin if r.attained()) / max(n, 1)
    ttft_att = sum(1 for r in fin if r.ttft_ok()) / max(n, 1)
    tpot_att = sum(1 for r in fin if r.tpot_ok()) / max(n, 1)
    e2e = np.array([r.e2e for r in fin]) if fin else np.array([0.0])
    ttfts = np.array([r.ttft for r in fin]) if fin else np.array([0.0])
    per_task: dict[str, dict] = {}
    tasks = sorted({r.task for r in requests})
    for t in tasks:
        tf = [r for r in fin if r.task == t]
        tn = sum(1 for r in requests if r.task == t)
        per_task[t] = {
            "attainment": sum(1 for r in tf if r.attained()) / max(tn, 1),
            "ttft_attainment": sum(
                1 for r in tf if r.ttft_ok()) / max(tn, 1),
            "tpot_attainment": sum(
                1 for r in tf if r.tpot_ok()) / max(tn, 1),
            "mean_e2e": float(np.mean([r.e2e for r in tf])) if tf else 0.0,
            "mean_ttft": float(np.mean([r.ttft for r in tf])) if tf else 0.0,
            "n": tn,
            "n_finished": len(tf),
        }
    served = [r for r in requests if r.state != RequestState.REJECTED]
    hit_tok = sum(r.prefix_hit_tokens for r in served)
    offered_tok = sum(r.l_in for r in served)
    return RunMetrics(
        attainment=att,
        ttft_attainment=ttft_att,
        tpot_attainment=tpot_att,
        mean_e2e=float(np.mean(e2e)),
        p99_e2e=float(np.percentile(e2e, 99)),
        mean_ttft=float(np.mean(ttfts)),
        cost_units=cost_units,
        makespan=makespan,
        n_finished=len(fin),
        n_total=n,
        per_task=per_task,
        n_rejected=sum(
            1 for r in requests if r.state == RequestState.REJECTED
        ),
        n_failed=sum(
            1 for r in requests if r.state == RequestState.FAILED
        ),
        prefix_hit_tokens=int(hit_tok),
        prefix_hit_rate=hit_tok / max(offered_tok, 1),
        n_migrated=sum(1 for r in requests if r.n_migrations > 0),
        n_kv_moves=sum(r.n_migrations for r in requests),
    )


class StreamingStats:
    """Incremental accounting over a live stream of serving events.

    Fed one event at a time by :class:`~repro.serving.session.
    ServingSession` (kinds: ``admitted`` / ``rejected`` /
    ``first_token`` / ``token`` / ``finished``).  Tracks what the
    post-run summary cannot: TTFB as the client observed it on the
    stream, inter-token latencies (per handle, from consecutive token
    stamps), and the admission split.  O(1) per event.
    """

    # latency samples are ring-capped so a long-lived session's
    # footprint stays bounded; percentiles then cover the most recent
    # window, which is what a live dashboard wants anyway
    MAX_SAMPLES = 65536

    def __init__(self):
        self.n_admitted = 0
        self.n_rejected = 0
        self.n_finished = 0
        self.n_failed = 0
        self.n_retried = 0
        self.n_tokens = 0
        self._ttfb: list[float] = []
        self._itl: list[float] = []
        self._ttfb_i = 0
        self._itl_i = 0
        self._last_tok: dict[int, float] = {}  # rid -> last token stamp

    def _push(self, buf: list, cursor: int, x: float) -> int:
        if len(buf) < self.MAX_SAMPLES:
            buf.append(x)
            return cursor
        buf[cursor] = x
        return (cursor + 1) % self.MAX_SAMPLES

    def observe(self, kind: str, rid: int, t: float,
                arrival: Optional[float] = None) -> None:
        if kind == "admitted":
            self.n_admitted += 1
        elif kind == "rejected":
            self.n_rejected += 1
        elif kind == "first_token":
            self.n_tokens += 1
            if arrival is not None:
                self._ttfb_i = self._push(self._ttfb, self._ttfb_i,
                                          t - arrival)
            self._last_tok[rid] = t
        elif kind == "token":
            self.n_tokens += 1
            last = self._last_tok.get(rid)
            if last is not None:
                self._itl_i = self._push(self._itl, self._itl_i,
                                         t - last)
            self._last_tok[rid] = t
        elif kind == "finished":
            self.n_finished += 1
            self._last_tok.pop(rid, None)
        elif kind == "failed":
            self.n_failed += 1
            self._last_tok.pop(rid, None)
        elif kind == "retried":
            self.n_retried += 1
            # a crash re-prefill re-emits from scratch: the next token
            # stamp must not be compared to a pre-fault one (the gap is
            # recovery latency, not steady-state inter-token latency)
            self._last_tok.pop(rid, None)

    @staticmethod
    def _pct(xs: list, q: float) -> float:
        return float(np.percentile(np.array(xs), q)) if xs else 0.0

    def row(self) -> dict:
        """Flat JSON payload (the BENCH_streaming.json schema)."""
        return {
            "n_admitted": self.n_admitted,
            "n_rejected": self.n_rejected,
            "n_finished": self.n_finished,
            "n_failed": self.n_failed,
            "n_retried": self.n_retried,
            "n_tokens": self.n_tokens,
            "mean_ttfb": round(float(np.mean(self._ttfb))
                               if self._ttfb else 0.0, 5),
            "p99_ttfb": round(self._pct(self._ttfb, 99), 5),
            "mean_itl": round(float(np.mean(self._itl))
                              if self._itl else 0.0, 6),
            "p99_itl": round(self._pct(self._itl, 99), 6),
        }
