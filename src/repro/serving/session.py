"""Online serving session: the system's front door (paper §1, §5).

HFX is a *production serving system*: requests arrive continuously,
clients stream tokens as they are generated, and the scheduler's
proactive budget estimation decides admission *at arrival time*.
:class:`ServingSession` is that front door over the existing cluster —
it owns the event loop incrementally instead of replaying a closed
world:

    session = ServingSession(Cluster(cfg), admission="reject")
    handle = session.submit(prompt, task="chat", ttft_slo=0.8,
                            tpot_slo=0.25, l_out=64)
    for ev in handle.events():       # ADMITTED, FIRST_TOKEN, TOKEN...,
        print(ev.kind, ev.time)      # FINISHED — typed + timestamped
    session.drain(); session.close()

Key properties:

- **Submit-time admission** — the dispatcher's Eq. 5 budget estimate
  (:meth:`~repro.core.dispatcher.Dispatcher.admission_verdict`) is
  evaluated when ``submit`` is called.  ``admission="reject"`` refuses
  doomed requests immediately (REJECTED event, state
  ``RequestState.REJECTED``); ``admission="degrade"`` renegotiates the
  TTFT SLO to the achievable estimate and admits best-effort;
  ``admission="none"`` restores the closed-world behavior (everything
  queues).
- **Per-token streaming with no extra host syncs** — the engine's
  fused decode blocks already bring an ``(n_slots, K)`` token matrix
  over in their single sync; the session just relays each lane with
  its interpolated stamp.  The simulator streams id-less token ticks
  timed by its latency model.
- **Two clock drivers** — ``clock="virtual"`` (default) advances time
  event-to-event (deterministic; what benchmarks and tests use);
  ``clock="wall"`` paces event processing against the real clock, so
  a closed-loop client experiences live latencies.
- ``Cluster.run`` is a thin batch adapter over this class — the batch
  and online paths share one event loop by construction.

Single-threaded by design: generators returned by
:meth:`ResponseHandle.events` *drive* the loop while they wait, which
is what makes closed-loop clients work without threads.
"""

from __future__ import annotations

import dataclasses
import enum
import itertools
import time
from typing import TYPE_CHECKING, Callable, Iterator, Optional, Sequence

import numpy as np

from repro.core.request import Request, RequestState
from repro.serving.metrics import COST_UNIT, RunMetrics, StreamingStats

if TYPE_CHECKING:
    from repro.serving.cluster import Cluster, ClusterResult


class EventKind(str, enum.Enum):
    """Typed stream-event vocabulary (the JSONL ``event`` field)."""

    ADMITTED = "admitted"
    REJECTED = "rejected"
    FIRST_TOKEN = "first_token"
    TOKEN = "token"
    FINISHED = "finished"
    # fault recovery: RETRIED marks a re-queued/retried request (the
    # stream continues); FAILED is terminal — the request was lost to a
    # fault and recovery shed it, so events() always terminates
    RETRIED = "retried"
    FAILED = "failed"


@dataclasses.dataclass
class StreamEvent:
    """One timestamped occurrence on a response stream."""

    kind: EventKind
    rid: int
    time: float                 # cluster-clock seconds
    token: Optional[int] = None  # token id; None on the sim plane
    data: dict = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        d = {"event": self.kind.value, "rid": self.rid,
             "t": round(self.time, 6)}
        if self.token is not None:
            d["token"] = int(self.token)
        d.update(self.data)
        return d


# event kinds whose processing constitutes forward progress on
# in-flight work — they extend the drain deadline (see drain())
_PROGRESS_KINDS = frozenset(
    {"arrival", "step_done", "kv_ready", "worker_up", "role_flip"}
)


class _WallClock:
    """Real-time driver: event times are paced against the wall."""

    def __init__(self):
        self._t0 = time.monotonic()

    def now(self) -> float:
        return time.monotonic() - self._t0


class ResponseHandle:
    """Client-side view of one submitted request's event stream."""

    def __init__(self, session: "ServingSession", request: Request):
        self.session = session
        self.request = request
        self.rid = request.rid
        self._log: list[StreamEvent] = []
        self.n_tokens = 0
        self._terminal = False

    # -- state ----------------------------------------------------------------
    @property
    def done(self) -> bool:
        """Terminal: FINISHED, REJECTED or FAILED has been delivered."""
        return self._terminal

    @property
    def rejected(self) -> bool:
        return self.request.state == RequestState.REJECTED

    @property
    def failed(self) -> bool:
        """Lost to a fault (replica crash / unrecoverable transfer)."""
        return self.request.state == RequestState.FAILED

    @property
    def log(self) -> list[StreamEvent]:
        """Events delivered so far (does not advance the loop)."""
        return list(self._log)

    # -- consumption ----------------------------------------------------------
    def events(self, wait: bool = True) -> Iterator[StreamEvent]:
        """Yield this handle's events in order.  With ``wait`` (the
        default) the iterator *drives the session's event loop* until
        the stream is terminal — this is how a single-threaded
        closed-loop client blocks on its response.  ``wait=False``
        yields only what has already been delivered."""
        i = 0
        while True:
            while i < len(self._log):
                yield self._log[i]
                i += 1
            if self._terminal or not wait:
                return
            if not self.session._pump(self):
                return  # loop can make no further progress

    def result(self) -> Request:
        """Drive the loop until terminal; returns the request record
        (generated tokens, timing stamps, final state)."""
        for _ in self.events():
            pass
        return self.request

    # -- session side ---------------------------------------------------------
    def _deliver(self, ev: StreamEvent) -> None:
        self._log.append(ev)
        if ev.kind in (EventKind.FINISHED, EventKind.REJECTED,
                       EventKind.FAILED):
            self._terminal = True


class ServingSession:
    """Online front door over a :class:`~repro.serving.cluster.Cluster`.

    Parameters
    ----------
    cluster:
        The cluster to serve on (either plane, any policy/mode).
    admission:
        ``"reject"`` (default) — refuse requests whose Eq. 5 verdict
        fails; ``"degrade"`` — renegotiate the TTFT SLO to the
        achievable estimate and admit; ``"none"`` — admit everything
        (closed-world behavior; what ``Cluster.run`` uses).
    clock:
        ``"virtual"`` — time advances event-to-event; ``"wall"`` —
        event processing is paced against real time.
    on_event:
        Optional callback invoked with every :class:`StreamEvent`
        across all handles (the ``serve --online`` JSONL emitter).
    degrade_factor:
        Safety stretch applied to the estimated-achievable TTFT when
        ``admission="degrade"`` renegotiates an SLO.
    """

    def __init__(self, cluster: "Cluster", *, admission: str = "reject",
                 clock: str = "virtual",
                 on_event: Optional[Callable[[StreamEvent], None]] = None,
                 degrade_factor: float = 1.25):
        if admission not in ("none", "reject", "degrade"):
            raise ValueError(f"unknown admission policy {admission!r}")
        if clock not in ("virtual", "wall"):
            raise ValueError(f"unknown clock driver {clock!r}")
        self.cluster = cluster
        self.admission = admission
        self.degrade_factor = degrade_factor
        self.on_event = on_event
        self._wall = _WallClock() if clock == "wall" else None
        # live (non-terminal) handles only — terminal ones are dropped
        # so a long-lived session's footprint tracks in-flight work,
        # not total tokens ever streamed (clients keep their own
        # handle/log alive for exactly as long as they hold it).
        # _requests retains one small record per request for final
        # metrics; callers running unbounded sessions should window
        # via partial() + fresh sessions.
        self._handles: dict[int, ResponseHandle] = {}
        self._requests: list[Request] = []   # submit order, incl. rejected
        # every rid ever used (terminal handles leave _handles, but a
        # rid must stay unique for the session's whole lifetime — a
        # JSONL consumer attributes events by rid)
        self._used_rids: set[int] = set()
        self._inflight = 0
        self._rid_auto = itertools.count()
        # deterministic prompt synthesis for length-only submissions:
        # same rng seed + draw order as workload.materialize_prompts,
        # so online and batch runs are token-identical
        self._mat_rng = np.random.default_rng(cluster.cfg.seed)
        self._max_arrival = 0.0
        self._last_progress = 0.0
        self._closed = False
        self._result: Optional["ClusterResult"] = None
        self.streaming = StreamingStats()
        if cluster.on_token is not None or cluster.on_finish is not None:
            raise RuntimeError(
                "cluster already has (or had) a ServingSession attached; "
                "a Cluster's clock and cost accounting span one session "
                "— build a fresh Cluster per run/session"
            )
        cluster.on_token = self._on_token
        cluster.on_finish = self._on_finish
        cluster.on_failed = self._on_failed
        cluster.on_retried = self._on_retried
        cluster.start()

    # -- clock ----------------------------------------------------------------
    @property
    def now(self) -> float:
        """Current session time: the wall driver's clock, or the
        cluster's virtual clock."""
        if self._wall is not None:
            return max(self._wall.now(), self.cluster.now)
        return self.cluster.now

    # -- submission ------------------------------------------------------------
    def submit(self, prompt=None, *, task: str = "default",
               l_in: Optional[int] = None, l_out: int = 1,
               ttft_slo: float = 10.0, tpot_slo: float = 1.0,
               arrival: Optional[float] = None,
               priority: Optional[int] = None,
               rid: Optional[int] = None) -> ResponseHandle:
        """Submit one request; returns its :class:`ResponseHandle`.

        ``prompt`` is real token ids (engine plane); omit it and give
        ``l_in`` for length-only workloads (the sim plane always, the
        engine plane synthesizes deterministic ids).  ``arrival=None``
        stamps the current session time — the natural choice for
        closed-loop clients."""
        if prompt is not None:
            r = Request.from_prompt(
                -1 if rid is None else rid, prompt, max_new=l_out,
                task=task, ttft_slo=ttft_slo, tpot_slo=tpot_slo,
                arrival=arrival, priority=priority,
            )
        else:
            if l_in is None:
                raise ValueError("submit needs a prompt or l_in")
            r = Request(rid=-1 if rid is None else rid, task=task,
                        arrival=arrival, l_in=int(l_in),
                        l_out=int(l_out), ttft_slo=ttft_slo,
                        tpot_slo=tpot_slo, priority=priority)
        return self.submit_request(r)

    def submit_request(self, r: Request) -> ResponseHandle:
        """Submit a pre-built :class:`Request` (the workload-replay and
        batch-adapter path).  Performs arrival stamping, engine-plane
        prompt materialization/validation, and the admission verdict."""
        if self._closed:
            raise RuntimeError("session is closed")
        cl = self.cluster
        if r.arrival is None:
            r.arrival = self.now
        if r.rid is None or r.rid < 0:
            r.rid = self._next_rid()
        if r.rid in self._used_rids:
            raise ValueError(f"duplicate rid {r.rid}")
        self._used_rids.add(r.rid)
        self._max_arrival = max(self._max_arrival, r.arrival)
        handle = ResponseHandle(self, r)
        self._handles[r.rid] = handle
        self._requests.append(r)

        reason = None
        if cl.cfg.backend == "engine":
            if r.prompt is None:
                from repro.serving.workload import materialize_prompts

                # same draw as the batch path, from one persistent rng:
                # online submits are prompt-identical to a batch
                # materialization of the same requests in the same
                # order.  `seed` keys the group-prefix streams, which
                # bypass the rng so group-mates match across planes.
                materialize_prompts([r], cl.cfg.model.vocab_size,
                                    seed=cl.cfg.seed, rng=self._mat_rng)
            try:
                cl.workers[0].engine.validate(r)
            except ValueError:
                if self.admission == "none":
                    raise
                reason = "request can never fit this engine"
        if r.generated is None:
            r.generated = []

        data: dict = {}
        if reason is None and self.admission != "none":
            verdict = cl.policy.admission_verdict(
                r, max(cl.now, r.arrival)
            )
            data = {"p": round(verdict.p, 4)}
            if np.isfinite(verdict.est_ttft):
                data["est_ttft"] = round(verdict.est_ttft, 4)
            if not verdict.admit:
                if self.admission == "degrade" and verdict.wid is not None:
                    # renegotiate: stretch the TTFT SLO to what the
                    # budget estimate says is achievable, keep serving
                    new_slo = max(
                        r.ttft_slo,
                        verdict.est_ttft * self.degrade_factor,
                    )
                    if np.isfinite(new_slo):
                        r.ttft_slo = new_slo
                    data["degraded"] = True
                    data["ttft_slo"] = round(r.ttft_slo, 4)
                else:
                    # wid=None means no worker could EVER hold the
                    # prompt — no SLO renegotiation can fix that, so
                    # degrade mode refuses too instead of queueing
                    # permanently unplaceable work
                    reason = verdict.reason
        if reason is not None:
            r.state = RequestState.REJECTED
            self._emit(handle, StreamEvent(
                EventKind.REJECTED, r.rid, r.arrival,
                data={**data, "reason": reason},
            ))
            self._handles.pop(r.rid, None)  # terminal: session-side drop
            return handle

        self._inflight += 1
        cl.enqueue(r)
        self._emit(handle, StreamEvent(
            EventKind.ADMITTED, r.rid, r.arrival, data=data,
        ))
        return handle

    def _next_rid(self) -> int:
        while True:
            rid = next(self._rid_auto)
            if rid not in self._used_rids:
                return rid

    # -- event-loop driving ----------------------------------------------------
    def _deadline(self) -> float:
        """Drain horizon: ``drain_timeout`` past the last *progress*
        (arrival, step completion, KV landing, scale-up) rather than
        the last arrival — in-flight work keeps extending it, so a
        long-decode tail request is never cut off mid-stream, while
        queued work that can never be placed still times out."""
        return (max(self._max_arrival, self._last_progress)
                + self.cluster.cfg.drain_timeout)

    def _advance(self) -> bool:
        """Process one due cluster event (wall clock: wait for it).
        Returns False when the loop can make no further progress."""
        cl = self.cluster
        t = cl.next_event_time()
        if t is None:
            return False
        if t > self._deadline():
            return False
        if self._wall is not None:
            lag = t - self._wall.now()
            if lag > 0:
                time.sleep(min(lag, 0.05))
                if t > self._wall.now():
                    return True  # waited; re-check (new submits may land)
        kind = cl.process_next()
        if kind in _PROGRESS_KINDS:
            self._last_progress = cl.now
        return True

    def _pump(self, handle: ResponseHandle) -> bool:
        """Advance the loop until ``handle`` gains events or terminates;
        False when no further progress is possible."""
        n = len(handle._log)
        while len(handle._log) == n and not handle._terminal:
            if not self._advance():
                return False
        return True

    def poll(self) -> int:
        """Process every event due *now* without blocking on future
        ones; returns the number processed.  Useful between submits in
        an open-loop replay."""
        n = 0
        cl = self.cluster
        while True:
            t = cl.next_event_time()
            if t is None or t > self.now or not self._advance():
                return n
            n += 1

    def run_until(self, t: float) -> None:
        """Advance the virtual clock through every event at or before
        ``t`` (replaying a trace with explicit arrival stamps)."""
        while True:
            nt = self.cluster.next_event_time()
            if nt is None or nt > t or not self._advance():
                return

    def drain(self) -> None:
        """Serve until every admitted request has finished (or the
        progress deadline expires for work that can never be placed)."""
        while self._inflight > 0:
            if not self._advance():
                break

    def close(self, requests: Optional[Sequence[Request]] = None
              ) -> "ClusterResult":
        """Stop accepting submissions and build the final
        :class:`ClusterResult` (idempotent)."""
        if not self._closed:
            self._closed = True
            self._result = self.cluster.collect_result(
                self._requests if requests is None else requests
            )
            # sinks stay attached: a Cluster's virtual clock and cost
            # accounting span its lifetime, so re-running one would
            # silently corrupt metrics (arrivals clamped past the old
            # makespan) — a second attach fails loudly instead; build
            # a fresh Cluster per run/session
        return self._result

    # -- incremental metrics ----------------------------------------------------
    def partial(self) -> RunMetrics:
        """Rolling metrics snapshot over everything submitted so far
        (attainment over finished-so-far; see RunMetrics.partial)."""
        cl = self.cluster
        cost = sum(
            w.total_up_time(cl.now) for w in cl.workers
        ) / COST_UNIT
        return RunMetrics.partial(self._requests, cost, cl.now)

    # -- cluster sinks -----------------------------------------------------------
    def _emit(self, handle: ResponseHandle, ev: StreamEvent) -> None:
        handle._deliver(ev)
        self.streaming.observe(ev.kind.value, ev.rid, ev.time,
                               arrival=handle.request.arrival)
        if self.on_event is not None:
            self.on_event(ev)

    def _on_token(self, rid: int, token: Optional[int],
                  t: float) -> None:
        h = self._handles.get(rid)
        if h is None:
            return
        kind = (EventKind.FIRST_TOKEN if h.n_tokens == 0
                else EventKind.TOKEN)
        h.n_tokens += 1
        self._emit(h, StreamEvent(kind, rid, t, token=token))

    def _on_finish(self, r: Request, t: float) -> None:
        self._inflight -= 1
        h = self._handles.get(r.rid)
        if h is None:
            return
        # the engine interpolates finish stamps to the emitting lane
        # inside a fused block; prefer that over the event-loop time so
        # FINISHED never precedes its own last TOKEN stamp
        t_fin = r.finish_time if r.finish_time is not None else t
        self._emit(h, StreamEvent(
            EventKind.FINISHED, r.rid, t_fin,
            data={"n_tokens": r.tokens_done, "attained": r.attained()},
        ))
        self._handles.pop(r.rid, None)  # terminal: session-side drop

    def _on_failed(self, r: Request, t: float, reason: str) -> None:
        """Recovery shed ``r``: the fault is unrecoverable, so its
        stream must terminate — a FAILED event is terminal, keeping
        every events() consumer (and drain()) from hanging."""
        self._inflight -= 1
        h = self._handles.get(r.rid)
        if h is None:
            return
        self._emit(h, StreamEvent(
            EventKind.FAILED, r.rid, t,
            data={"reason": reason, "n_tokens": r.tokens_done},
        ))
        self._handles.pop(r.rid, None)  # terminal: session-side drop

    def _on_retried(self, r: Request, t: float, info: dict) -> None:
        """Recovery re-queued ``r`` (crash re-prefill) or retried its
        KV transfer — non-terminal, the stream continues."""
        h = self._handles.get(r.rid)
        if h is None:
            return
        self._emit(h, StreamEvent(EventKind.RETRIED, r.rid, t,
                                  data=dict(info)))
