"""Real JAX inference engine: continuous batching over an actual model.

This is the execution plane the simulator abstracts: jitted step
functions, KV caches, greedy sampling, and the paper's SLO-aware
admission (Eq. 5 token budget) at the engine boundary.  It doubles as
the latency profiler — measured step times feed FittedLatencyModel
exactly like the paper's request profiler (Appendix A).

Requests are unified :class:`repro.core.request.Request` objects, so
the engine can be driven standalone (``submit``/``step``/``run_until_done``)
or cluster-backed through
:class:`repro.serving.backend.EngineWorker` — the same control plane
that schedules the simulator.

Two execution planes:

- **Paged / chunked (default)**: attention K/V lives in a shared pool
  of fixed-size pages (``PagedKVManager``); prompts prefill in chunks
  sized by the Eq. 5 token budget, and the engine alternates one
  prefill chunk with one decode iteration whenever both have work — so
  a long prompt never stalls in-flight decodes for more than one
  bounded chunk (the head-of-line blocking §5.1 schedules around).
  Prefill chunks and decode share one jitted ``Model.chunk_step``
  (decode is the chunk-length-1 case).

- **Slot-based (fallback)**: monolithic full-prompt prefill into
  contiguous per-slot rows; kept for architectures the chunked plane
  doesn't cover (sliding-window rings, encoder frontends).

P/D disaggregation runs on the paged plane: with ``park_on_prefill``
set (a prefill-role engine), requests whose prompt completes *park* —
their pages stay resident but they never join the decode batch — until
``export_kv`` materializes the cache + generation state into a
:class:`~repro.serving.kv_manager.KVPayload` and ``import_kv`` installs
it on the decode engine, which continues generating token-identically
(greedy decode over the same cache contents).


Designed for reduced configs on CPU (tests/examples) and full configs
on TPU; the compute path is the same model code the dry-run lowers.
"""

from __future__ import annotations

import dataclasses
import time
from functools import partial
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import FittedLatencyModel
from repro.core.request import Request, RequestState
from repro.core.token_budget import ntoken_limit
from repro.models.build import Model
from repro.serving.kv_manager import (
    KVPayload,
    PagedKVManager,
    SlotManager,
    clear_rows,
    gather_slot_kv,
    insert_rows,
    scatter_slot_kv,
)
from repro.serving.spec_decode import NGramDrafter, SpecConfig, slo_spec_len


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 128
    prefill_batch: int = 4          # max sequences per prefill step
    slo_aware: bool = True          # Eq. 5 admission at the engine
    eos_token: Optional[int] = None
    # paged / chunked execution plane
    paged: Optional[bool] = None    # None = auto (paged when supported)
    page_size: int = 16
    n_pages: Optional[int] = None   # default: n_slots * ceil(max_len/ps)
    chunk_size: int = 32            # static ceiling per prefill chunk
    # fused decode blocks: max decode iterations per jitted dispatch
    # (one host sync per block instead of per token).  1 = legacy
    # per-token stepping; the engine collapses to 1 under queue
    # pressure so chunked prefill keeps its Eq. 5 interleave turn.
    decode_block: int = 8
    # prefix cache: page-level KV reuse across requests (paged plane,
    # pure-attention models only — SSM/conv state is slot-resident and
    # cannot ride along with shared pages)
    prefix_cache: bool = False
    prefix_cache_pages: Optional[int] = None  # cache footprint cap
    # SLO-customized speculative decoding (paged plane): an n-gram /
    # prompt-lookup drafter proposes per-lane continuations, one
    # verify dispatch scores them, and the longest greedy-matching
    # prefix is accepted (rollback = page-table truncation).  Per-lane
    # depth is picked from each request's Eq. 5 / TPOT slack, capped
    # at max_spec_len.
    spec_decode: bool = False
    max_spec_len: int = 8

    @classmethod
    def smoke(cls, **overrides) -> "EngineConfig":
        """The canonical CPU-sized engine shape examples, benchmarks,
        and CI smoke runs share (pair with smoke model configs and
        clipped workloads, e.g. ``workload.engine_smoke_workload``)."""
        kw = dict(n_slots=4, max_len=48, prefill_batch=2, page_size=8,
                  chunk_size=16)
        kw.update(overrides)
        return cls(**kw)


class InferenceEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig,
                 profiler: Optional[FittedLatencyModel] = None,
                 fn_cache: Optional[dict] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.paged = (model.supports_chunked if cfg.paged is None
                      else cfg.paged)
        if self.paged and not model.supports_chunked:
            raise ValueError(
                "model has segments the chunked/paged plane does not "
                "support; use paged=False"
            )
        # fn_cache shares jitted step functions between engines wrapping
        # the same model/params (e.g. scaled-out EngineWorkers), so a
        # new replica doesn't pay recompilation
        cache = fn_cache if fn_cache is not None else {}
        self.slots = SlotManager(cfg.n_slots)
        self.prefix = None  # PrefixCache, attached on the paged plane
        if self.paged:
            self.kv = PagedKVManager(
                cfg.n_slots, cfg.max_len, cfg.page_size, cfg.n_pages
            )
            self.caches = model.init_paged_cache(
                cfg.n_slots, cfg.max_len, cfg.page_size, self.kv.n_pages
            )
            self.axes = model.paged_cache_axes()
            if "chunk" not in cache:
                cache["chunk"] = jax.jit(model.chunk_step)
            self._chunk = cache["chunk"]
            if cfg.prefix_cache:
                if not model.supports_prefix_cache:
                    raise ValueError(
                        "prefix caching needs pure-attention paged "
                        "caches: SSM/conv state is slot-resident, so a "
                        "shared page cannot reproduce it; disable "
                        "prefix_cache for this model"
                    )
                from repro.serving.prefix_cache import PrefixCache

                self.prefix = PrefixCache(
                    self.kv.alloc, cfg.page_size,
                    max_pages=cfg.prefix_cache_pages,
                )
                self.kv.attach_prefix_cache(self.prefix)
        else:
            if cfg.prefix_cache:
                raise ValueError(
                    "prefix caching requires the paged plane (pages are "
                    "the unit of sharing); this model/config runs the "
                    "slot fallback"
                )
            self.kv = None
            self.caches = model.init_cache(cfg.n_slots, cfg.max_len)
            self.axes = model.cache_axes()
            if "decode" not in cache:
                cache["decode"] = jax.jit(model.decode_step)
            self._decode = cache["decode"]
        self.queue: list[Request] = []
        self.prefilling: dict[int, Request] = {}  # slot -> req
        self.active: dict[int, Request] = {}
        # P/D: prefill-complete requests whose decode runs elsewhere.
        # Pages stay resident (awaiting export), slots stay occupied,
        # but parked slots never join a decode batch.
        self.parked: dict[int, Request] = {}
        self.park_on_prefill = False  # set for prefill-role engines
        self.pos = np.zeros(cfg.n_slots, np.int32)
        self.last_token = np.zeros(cfg.n_slots, np.int32)
        # measured step times -> Appendix-A fit; an injected profiler
        # lets the cluster's Dispatcher budget on the same instance
        self.profiler = profiler if profiler is not None else (
            FittedLatencyModel()
        )
        self.finished: list[Request] = []
        self.clock = 0.0  # virtual clock advanced by measured step times

        self._prefill_fns: dict[int, Callable] = cache.setdefault(
            "prefill", {}
        )
        # fused decode blocks: jitted scan per (plane, K) bucket
        self._block_fns: dict[tuple, Callable] = cache.setdefault(
            "decode_block", {}
        )
        self._turn = "prefill"  # round-robin fairness when both planes busy
        self._seq = 0           # submit-order stamp (preemption age)
        # rid -> slot for every slotted request (prefilling / active /
        # parked) — export_kv / kv_bytes_of are O(1), not a pool scan
        self._rid_slot: dict[int, int] = {}
        # device-resident (last_token, pos): the decode-block scan's
        # final state feeds the next block directly; host-side
        # mutations (prefill completion, retire, import, preemption)
        # set the dirty flag and force a re-upload
        self._dev_state: Optional[tuple] = None
        self._host_state_dirty = True
        # telemetry for the perf trajectory (bench_decode_block)
        self.n_dispatches = 0       # jitted dispatches (= host syncs)
        self.n_decode_tokens = 0    # tokens emitted by decode steps
        self.n_prefill_tokens = 0   # prompt tokens actually prefilled
        # (cache hits skip prefill compute, so with a prefix cache this
        # undercounts l_in — exactly the FLOPs-saved figure)
        self.decode_block_hist: dict[int, int] = {}  # K -> n blocks
        # speculative decoding: drafter + jitted verify fns per pow2
        # proposal-width bucket, and acceptance telemetry
        self.drafter: Optional[NGramDrafter] = None
        self._spec_cfg: Optional[SpecConfig] = None
        self._spec_fns: dict[int, Callable] = cache.setdefault(
            "spec_block", {}
        )
        self.n_spec_dispatches = 0   # propose-verify dispatches
        self.n_spec_proposed = 0     # drafted tokens sent to verify
        self.n_spec_accepted = 0     # drafted tokens accepted
        self.spec_depth_hist: dict[int, int] = {}  # pad width -> n
        # per-task acceptance stats (the SLO tiers differ by task), for
        # the per-tier speculation-depth trajectory in BENCH_spec
        self.spec_task_stats: dict[str, dict] = {}
        if cfg.page_size <= 0 or cfg.chunk_size <= 0:
            raise ValueError("page_size and chunk_size must be positive")
        if cfg.decode_block < 1:
            raise ValueError("decode_block must be >= 1")
        if cfg.spec_decode:
            if not self.paged:
                raise ValueError(
                    "spec_decode requires the paged plane: rollback is "
                    "page-table truncation"
                )
            if not model.supports_spec_decode:
                raise ValueError(
                    "spec_decode needs pure-attention paged caches: "
                    "slot-resident SSM/conv state has no per-position "
                    "record to truncate rejected tokens back to"
                )
            if cfg.max_spec_len < 1:
                raise ValueError("max_spec_len must be >= 1")
            self._spec_cfg = SpecConfig(max_spec_len=cfg.max_spec_len)
            self.drafter = NGramDrafter(
                max_ngram=self._spec_cfg.max_ngram,
                min_ngram=self._spec_cfg.min_ngram,
            )

    def peek_prefix(self, prompt) -> int:
        """Hit length (tokens) a prefix-cache lookup would return for
        ``prompt`` right now — read-only.  The Dispatcher's admission
        budget charges only the uncached suffix ``l_in - peek``."""
        if self.prefix is None or prompt is None:
            return 0
        return self.kv.peek_prefix(prompt)

    def kv_token_capacity(self) -> int:
        """Token capacity of this engine's KV plane (Backend protocol)."""
        if self.paged:
            return self.kv.n_pages * self.cfg.page_size
        return self.cfg.n_slots * self.cfg.max_len

    # -- intake -------------------------------------------------------------
    def validate(self, req: Request) -> None:
        """Raise if this engine could never serve ``req``.  Shared by
        ``submit`` and by the cluster's pre-run workload check, so an
        impossible request fails before the run, not mid-workload."""
        if req.prompt is None or len(req.prompt) == 0:
            raise ValueError(f"request {req.rid}: empty prompt")
        if len(req.prompt) >= self.cfg.max_len:
            # the slot plane fails loudly on oversized prompts; the paged
            # plane would livelock waiting for pages that can never exist
            raise ValueError(
                f"request {req.rid}: prompt of {len(req.prompt)} tokens "
                f"leaves no room to generate within "
                f"max_len={self.cfg.max_len}"
            )
        if self.paged:
            # the request must fit the pool *alone*, so preemption can
            # always drain the pool far enough for someone to finish
            need = -(-min(len(req.prompt) + req.l_out, self.cfg.max_len)
                     // self.cfg.page_size)
            if need > self.kv.n_pages:
                raise ValueError(
                    f"request {req.rid}: needs up to {need} pages but "
                    f"the pool has {self.kv.n_pages}; raise n_pages or "
                    f"max_len/page_size"
                )

    def submit(self, req: Request) -> None:
        self.validate(req)
        if req.generated is None:
            req.generated = []
        if req.arrival is None:
            # standalone engine use: submit time is arrival time; a
            # workload generator that owns the clock sets arrival itself
            req.arrival = self.clock
        if not req.l_in:
            req.l_in = len(req.prompt)
        req.state = RequestState.ADMITTED
        req.admit_seq = self._seq
        self._seq += 1
        self.queue.append(req)

    def _prefill_fn(self, seq_len: int) -> Callable:
        if seq_len not in self._prefill_fns:
            # close over locals, not `self`: these jitted fns live in a
            # (possibly shared) fn_cache that can outlive this engine —
            # capturing `self` would pin its KV caches forever
            model, cache_len = self.model, self.cfg.max_len

            def fn(params, tokens, lens):
                return model.prefill(
                    params, tokens, lens, cache_len=cache_len
                )
            self._prefill_fns[seq_len] = jax.jit(fn)
        return self._prefill_fns[seq_len]

    # -- one engine step ------------------------------------------------------
    def step(self) -> dict:
        """Run one prefill (chunk) or decode step; returns event info."""
        if self.paged:
            return self._step_paged()
        admitted = self._admit()
        if admitted:
            return self._prefill(admitted)
        if self.active:
            return self._decode_step()
        return {"kind": "idle"}

    # ==========================================================================
    # Paged / chunked plane
    # ==========================================================================
    def _step_paged(self) -> dict:
        want_prefill = bool(
            self.prefilling or (self.queue and self.slots.n_free)
        )
        if want_prefill and (not self.active or self._turn == "prefill"):
            ev = self._chunk_prefill_step()
            if ev is not None:
                self._turn = "decode"
                return ev
        if self.active:
            self._turn = "prefill"
            return self._decode_paged()
        if want_prefill:
            # decode drained while budget said "wait": force progress
            ev = self._chunk_prefill_step(force=True)
            if ev is not None:
                return ev
        return {"kind": "idle"}

    def _chunk_budget(self, force: bool) -> int:
        """Eq. 5: prompt tokens this step such that the prefill stall,
        amortized over decode iterations, keeps the tightest TPOT."""
        budget = self.cfg.chunk_size
        if force or not (self.cfg.slo_aware and self.active
                         and self.profiler.fitted):
            return budget
        cur_lens = [int(self.pos[s]) for s in self.active]
        e_d = self.profiler.decode_step_time(cur_lens)
        tightest_tpot = min(
            [r.tpot_slo for r in self.active.values()]
            + [r.tpot_slo for r in self.prefilling.values()]
            + [r.tpot_slo for r in self.queue[: self.slots.n_free]]
        )
        ttfts = ([r.ttft_slo for r in self.prefilling.values()]
                 + [r.ttft_slo for r in self.queue[: self.slots.n_free]])
        tightest_ttft = min(ttfts) if ttfts else 10.0
        n = ntoken_limit(tightest_ttft, tightest_tpot, e_d, self.profiler)
        return min(budget, n)

    def _chunk_prefill_step(self, force: bool = False) -> Optional[dict]:
        cfg = self.cfg
        # admit new requests into prefilling slots
        while (self.queue and self.slots.n_free
               and len(self.prefilling) < cfg.prefill_batch):
            r = self.queue.pop(0)
            s = self.slots.alloc(r)
            r.slot = s
            # prefix-cache hit: the slot's table starts at the shared
            # pages and prefill resumes from the hit offset — the
            # chunk-continuation path the chunked plane already runs
            r.prefill_progress = self.kv.lookup_prefix(s, r.prompt)
            r.prefix_hit_tokens = r.prefill_progress
            r.state = RequestState.PREFILLING
            self.prefilling[s] = r
            self._rid_slot[r.rid] = s
        if not self.prefilling:
            return None
        budget = self._chunk_budget(force)
        if budget <= 0:
            return None  # no decode slack: let decode run this step

        takes: dict[int, int] = {}
        rem = budget
        # admission order (dict insertion), not slot id: a later request
        # landing in a recycled low slot must not starve earlier ones
        for s, r in self.prefilling.items():
            take = min(len(r.prompt) - r.prefill_progress, cfg.chunk_size,
                       rem)
            if take > 0 and not self.kv.ensure(
                s, r.prefill_progress + take
            ):
                take = 0  # page pool dry: wait for reclamation
            takes[s] = take
            rem -= take
        if not any(takes.values()):
            if not self.active and len(self.prefilling) > 1:
                # pool dry with nothing decoding (and thus nothing to
                # retire): recompute-preempt the youngest prefill so the
                # oldest can make progress instead of livelocking
                oldest = min(self.prefilling,
                             key=lambda s: self.prefilling[s].admit_seq)
                self._preempt_youngest(exclude=oldest)
            return None

        tokens = np.zeros((cfg.n_slots, cfg.chunk_size), np.int32)
        start = np.array(self.pos)  # decode rows: frozen at cur pos
        lens = np.zeros((cfg.n_slots,), np.int32)
        for s, r in self.prefilling.items():
            t = takes[s]
            tokens[s, :t] = r.prompt[
                r.prefill_progress: r.prefill_progress + t
            ]
            start[s] = r.prefill_progress
            lens[s] = t

        t0 = time.perf_counter()
        logits, self.caches = self._chunk(
            self.params, self.caches, self.kv.device_table(),
            jnp.asarray(tokens), jnp.asarray(start), jnp.asarray(lens),
        )
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.clock += dt
        self.n_dispatches += 1
        chunk_lens = [t for t in takes.values() if t > 0]
        self.profiler.observe_prefill(chunk_lens, dt)
        self.n_prefill_tokens += int(sum(chunk_lens))

        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        n_done = 0
        tok_ev: list[tuple] = []  # (rid, token, t) stream events
        for s, r in list(self.prefilling.items()):
            r.prefill_progress += takes[s]
            if takes[s] > 0 and r.prefill_progress >= len(r.prompt):
                # the slot's full-page prefix span is now immutable KV:
                # publish it so later same-prefix prompts hit
                self.kv.publish_prefix(s, r.prompt)
                tok = int(nxt[s])
                if r.first_token_time is None:
                    r.first_token_time = self.clock
                r.generated.append(tok)
                r.tokens_done = len(r.generated)
                tok_ev.append((r.rid, tok, self.clock))
                self.pos[s] = len(r.prompt)
                self.last_token[s] = tok
                self._host_state_dirty = True
                del self.prefilling[s]
                done = self._is_done(r, s)
                if self.park_on_prefill and not done:
                    # P/D: decode placement is the Migrator's call —
                    # hold the KV resident until export_kv moves it
                    self.parked[s] = r
                else:
                    r.state = RequestState.DECODING
                    self.active[s] = r
                n_done += 1
        self._retire()
        return {"kind": "prefill_chunk", "tokens": int(sum(chunk_lens)),
                "n_seqs": len(chunk_lens), "n_completed": n_done,
                "time": dt, "token_events": tok_ev}

    def _preempt_youngest(self, exclude: int) -> bool:
        """Recompute preemption (the vLLM fallback for an oversubscribed
        pool): evict the youngest request — release its pages, fold its
        generated tokens into the prompt, and requeue it at the head so
        it re-prefills (and then continues generating) once pages free
        up.  Deterministic greedy decode makes the recompute exact."""
        in_flight = {**self.active, **self.prefilling}
        candidates = [s for s in in_flight if s != exclude]
        if not candidates:
            return False
        v = max(candidates, key=lambda s: in_flight[s].admit_seq)
        r = self.active.pop(v, None) or self.prefilling.pop(v)
        self._rid_slot.pop(r.rid, None)
        self._release_slot(v)
        if r.generated:
            # fold generated tokens into the prompt: the re-prefill ends
            # on the last generated token, so its next-token logits
            # continue generation exactly where decode left off.
            # r.generated keeps the full output history (l_out / eos
            # accounting stays correct).
            r.prompt = np.concatenate([
                np.asarray(r.prompt, np.int32),
                np.asarray(r.generated, np.int32),
            ])
        r.prefill_progress = 0
        r.slot = None
        r.state = RequestState.PREEMPTED
        self.queue.insert(0, r)
        return True

    def _release_slot(self, s: int) -> None:
        """Free every per-slot resource (pages, cache rows, batch row)."""
        if self.kv is not None:
            self.kv.release(s)
        self.caches = clear_rows(self.caches, self.axes, [s])
        self.slots.free(s)
        self.pos[s] = 0
        self.last_token[s] = 0
        self._host_state_dirty = True

    def evict(self, s: int) -> Optional[Request]:
        """Drop the request in slot ``s`` from the engine entirely
        (Backend ``free_kv``: its KV now lives elsewhere, e.g. after a
        migration).  Unlike preemption, the request is NOT re-queued."""
        r = (self.active.pop(s, None) or self.prefilling.pop(s, None)
             or self.parked.pop(s, None))
        if r is None:
            return None
        self._rid_slot.pop(r.rid, None)
        self._release_slot(s)
        r.slot = None
        return r

    # -- P/D hand-off (paged plane) -------------------------------------------
    def _slot_of(self, rid: int) -> Optional[int]:
        """O(1) lookup via the rid -> slot index kept in sync by the
        alloc (admission/prefill/import) and release (retire/evict/
        preempt) paths — no three-pool linear scan per export."""
        return self._rid_slot.get(rid)

    def exportable(self, rid: int) -> bool:
        """True while ``rid``'s KV is resident in a state export_kv
        accepts: parked or mid-decode, not still prefilling and not
        recompute-preempted back to the queue.  The source-side guard
        for in-flight migrations — a transfer scheduled while the
        request was exportable may land after it finished or was
        preempted, and then there is nothing left to move."""
        s = self._slot_of(rid)
        return self.paged and s is not None and s not in self.prefilling

    def export_kv(self, rid: int) -> KVPayload:
        """Materialize request ``rid``'s cache + generation state for a
        D2D hand-off.  The request must have completed prefill (parked,
        or mid-decode); its pages stay resident — the caller frees them
        via ``evict`` once the transfer has landed."""
        if not self.paged:
            raise RuntimeError(
                "export_kv requires the paged plane (slot-plane caches "
                "have no page-granular hand-off)"
            )
        s = self._slot_of(rid)
        if s is None:
            raise KeyError(f"request {rid} is not resident on this engine")
        if s in self.prefilling:
            raise RuntimeError(
                f"request {rid} has not finished prefill; its cache is "
                f"not yet a complete prefix"
            )
        n = int(self.pos[s])
        # pad the id list to the engine-constant max_pages so the
        # jitted gather compiles ONCE per leaf shape, not once per
        # prompt-length bucket (-1 entries clamp; the n_tokens slice
        # drops whatever they gather)
        ids = np.full(self.kv.max_pages, -1, np.int32)
        pages = self.kv.pages_of(s)
        ids[: len(pages)] = pages
        payload_kv = gather_slot_kv(self.caches, self.axes, s, ids, n)
        r = self.parked.get(s) or self.active.get(s)
        return KVPayload(rid=rid, n_tokens=n,
                         last_token=int(self.last_token[s]),
                         prefill_progress=r.prefill_progress,
                         kv=payload_kv)

    def import_kv(self, payload: KVPayload, req: Request) -> bool:
        """Install a migrated cache and join ``req`` to the decode
        batch mid-stream.  Allocates a slot + pages (possibly a
        different page size than the source); False if the engine
        can't place it right now (no slot / pool dry) — the caller may
        preempt and retry."""
        if not self.paged:
            raise RuntimeError("import_kv requires the paged plane")
        s = self.slots.alloc(req)
        if s is None:
            return False
        if not self.kv.ensure(s, payload.n_tokens):
            self.slots.free(s)
            return False
        self.caches = scatter_slot_kv(
            self.caches, self.axes, s,
            np.asarray(self.kv.pages_of(s), np.int32), payload.kv,
        )
        if req.generated is None:
            req.generated = []
        req.slot = s
        req.prefill_progress = payload.prefill_progress
        req.state = RequestState.DECODING
        req.admit_seq = self._seq  # fresh age on this engine (preemption)
        self._seq += 1
        self.pos[s] = payload.n_tokens
        self.last_token[s] = payload.last_token
        self._host_state_dirty = True
        self.active[s] = req
        self._rid_slot[req.rid] = s
        return True

    def kv_bytes_of(self, rid: int) -> Optional[float]:
        """Exact byte size export_kv would materialize for ``rid`` —
        computed from cache shapes, nothing gathered.  The TLManager
        costs transfers on this *measured* figure rather than the
        analytic per-token estimate."""
        s = self._slot_of(rid)
        if s is None or not self.paged:
            return None
        n = int(self.pos[s])
        sizes: list[float] = []

        def acc(leaf, ax):
            if ax is None:  # paged pool: n tokens' worth of K/V
                np_, _, ps, _ = leaf.shape[-4:]
                sizes.append(leaf.size / (np_ * ps) * leaf.dtype.itemsize
                             * n)
            else:           # per-slot state: one batch row
                sizes.append((leaf.size // leaf.shape[ax])
                             * leaf.dtype.itemsize)
            return leaf

        jax.tree.map(acc, self.caches, self.axes)
        return float(sum(sizes))

    # -- fused decode blocks (both planes) -------------------------------------
    def _decode_block_k(self) -> int:
        """Pick K, the number of decode iterations to fuse this step.

        Bounded by the config ceiling, then: (a) collapsed to 1 when
        prefill work is pending — a K-block would add (K-1)*E_d to a
        waiting prompt's TTFT for zero per-token decode win, so the
        Eq. 5 chunk/decode 1:1 interleave keeps its turn; (b) capped
        by the smallest remaining output budget and max_len room over
        active requests — the valid mask would tolerate longer blocks
        (frozen lanes), but the cap trades a few extra dispatches on
        staggered completions for zero wasted lanes and a bounded wait
        before a finishing request's slot/pages are reusable by the
        next *arrival* (dispatches land between blocks); (c) rounded
        down to a power of two so the jitted block set stays bounded.
        """
        cfg = self.cfg
        k = max(1, int(cfg.decode_block))
        if k == 1 or not self.active:
            return 1
        if self.prefilling or self.queue:
            return 1
        for s, r in self.active.items():
            k = min(k, max(1, r.l_out - len(r.generated)),
                    max(1, cfg.max_len - 1 - int(self.pos[s])))
        return 1 << (k.bit_length() - 1)

    def _fit_block_k(self, k: int) -> int:
        """Shrink K (halving) until pre-reserving pages for K new
        tokens per active slot fits the free pool; at 1 the legacy
        ensure/preempt-youngest fallback takes over."""
        ps = self.cfg.page_size
        while k > 1:
            need = 0
            for s in self.active:
                tgt = min(int(self.pos[s]) + k, self.cfg.max_len)
                need += max(0, -(-tgt // ps) - self.kv.n_pages_held(s))
            # unreferenced cached prefix pages count as free: ensure()
            # evicts them on demand when the reservation is drawn down
            if need <= self.kv.n_available_pages:
                return k
            k //= 2
        return 1

    def _decode_block_fn(self, k: int) -> Callable:
        key = ("paged" if self.paged else "slot", k)
        if key not in self._block_fns:
            fn = (self.model.decode_block if self.paged
                  else self.model.decode_block_slots)
            self._block_fns[key] = jax.jit(partial(fn, k=k))
        return self._block_fns[key]

    def _device_state(self) -> tuple:
        """(last_token, pos) as device-resident arrays.  The previous
        block's scan outputs are reused directly; any host-side
        mutation in between (prefill completion, retire, import,
        preemption) marks them dirty and forces one re-upload."""
        if self._dev_state is None or self._host_state_dirty:
            self._dev_state = (jnp.asarray(self.last_token),
                               jnp.asarray(self.pos))
            self._host_state_dirty = False
        return self._dev_state

    def warm_decode_blocks(self) -> None:
        """Compile the power-of-two decode-block jits up front.  The
        calls are pure with an all-frozen batch (outputs discarded,
        engine state untouched), so XLA compile time never lands
        inside a measured step."""
        cfg = self.cfg
        zeros = jnp.zeros((cfg.n_slots,), jnp.int32)
        alive = jnp.zeros((cfg.n_slots,), bool)
        k = 2
        while k <= max(1, cfg.decode_block):
            fn = self._decode_block_fn(k)
            args = (self.params, self.caches)
            if self.paged:
                args += (self.kv.device_table(),)
            out, _ = fn(*args, zeros, zeros, alive, zeros + 1,
                        jnp.int32(-1), jnp.int32(cfg.max_len))
            jax.block_until_ready(out)
            k *= 2
        if cfg.spec_decode:
            # verify dispatches land in pow2 proposal-width buckets;
            # warm every bucket up to the max_spec_len ceiling so the
            # first speculative step never pays an XLA compile
            k = 1
            while True:
                fn = self._spec_block_fn(k)
                out, _ = fn(
                    self.params, self.caches, self.kv.device_table(),
                    zeros, zeros, alive, zeros + 1, jnp.int32(-1),
                    jnp.int32(cfg.max_len),
                    jnp.zeros((cfg.n_slots, k), jnp.int32), zeros,
                )
                jax.block_until_ready(out)
                if k >= cfg.max_spec_len:
                    break
                k *= 2

    def _spec_block_fn(self, k: int) -> Callable:
        if k not in self._spec_fns:
            fn = self.model.spec_decode_block
            self._spec_fns[k] = jax.jit(partial(fn, k=k))
        return self._spec_fns[k]

    def _spec_history(self, r: Request) -> list[int]:
        """The request's true token sequence (prompt + generated).
        After a recompute preemption the prompt already contains the
        pre-preemption output, so slice to the original l_in."""
        n_in = r.l_in or len(r.prompt)
        return [int(t) for t in r.prompt[:n_in]] + [
            int(t) for t in r.generated
        ]

    def _spec_decode_step(self) -> Optional[dict]:
        """One propose-verify-accept speculative dispatch (paged plane).

        Per active lane: the SLO controller picks a depth from the
        request's TPOT slack, the n-gram drafter fills it (possibly
        with fewer tokens, possibly none — a zero-proposal lane rides
        along as a plain 1-token decode), one jitted
        ``spec_decode_block`` scores everything, and rejected lanes'
        KV is rolled back by truncating the page table to the accepted
        position.  Returns None when nothing proposes or the page pool
        can't cover the proposals even at depth 1 — the caller falls
        through to the plain block/per-token path.
        """
        cfg = self.cfg
        ps = cfg.page_size
        cur_lens = [int(self.pos[s]) for s in self.active]
        plen: dict[int, int] = {}
        drafts: dict[int, list[int]] = {}
        want_of: dict[int, int] = {}    # controller depth (telemetry)
        for s, r in self.active.items():
            cap = min(
                self._spec_cfg.max_spec_len,
                r.l_out - len(r.generated) - 1,   # lane 0 emits one
                cfg.max_len - 1 - int(self.pos[s]),  # KV write room
            )
            want = min(
                slo_spec_len(r.tpot_slo, self.profiler, cur_lens,
                             self._spec_cfg),
                cap,
            )
            want_of[s] = want
            d = self.drafter.propose(self._spec_history(r), want)
            drafts[s] = d
            plen[s] = len(d)
        if not any(plen.values()):
            return None
        # pre-reserve pages for every lane's verify writes (positions
        # pos .. pos+plen); halve all depths until the pool fits
        while True:
            need = 0
            for s in self.active:
                tgt = min(int(self.pos[s]) + plen[s] + 1, cfg.max_len)
                need += max(0, -(-tgt // ps) - self.kv.n_pages_held(s))
            if need <= self.kv.n_available_pages:
                break
            plen = {s: p // 2 for s, p in plen.items()}
            if not any(plen.values()):
                return None
        for s in self.active:
            ok = self.kv.ensure(
                s, min(int(self.pos[s]) + plen[s] + 1, cfg.max_len)
            )
            assert ok, "spec reservation failed after availability check"

        kmax = max(plen.values())
        kpad = 1 << (kmax - 1).bit_length()  # pow2 compile bucket
        props = np.zeros((cfg.n_slots, kpad), np.int32)
        prop_lens = np.zeros(cfg.n_slots, np.int32)
        alive = np.zeros(cfg.n_slots, bool)
        rem = np.zeros(cfg.n_slots, np.int32)
        pos0: dict[int, int] = {}
        for s, r in self.active.items():
            alive[s] = True
            rem[s] = r.l_out - len(r.generated)
            pos0[s] = int(self.pos[s])
            d = drafts[s][: plen[s]]
            props[s, : len(d)] = d
            prop_lens[s] = len(d)

        last_d, pos_d = self._device_state()
        eos = jnp.int32(-1 if cfg.eos_token is None else cfg.eos_token)
        fn = self._spec_block_fn(kpad)
        t0 = time.perf_counter()
        (toks, valid, last_f, pos_f), self.caches = fn(
            self.params, self.caches, self.kv.device_table(),
            last_d, pos_d, jnp.asarray(alive), jnp.asarray(rem),
            eos, jnp.int32(cfg.max_len),
            jnp.asarray(props), jnp.asarray(prop_lens),
        )
        toks, valid = jax.block_until_ready((toks, valid))
        dt = time.perf_counter() - t0
        self.clock += dt
        self.n_dispatches += 1
        self.n_spec_dispatches += 1
        self.spec_depth_hist[kpad] = self.spec_depth_hist.get(kpad, 0) + 1
        self._dev_state = (last_f, pos_f)
        self._host_state_dirty = False

        tk = np.asarray(toks)   # (n_slots, kpad+1)
        vd = np.asarray(valid)  # (n_slots, kpad+1) bool
        t_start = self.clock - dt
        finish_at: dict[int, float] = {}
        tok_ev: list[tuple] = []
        n_emitted = 0
        for s, r in self.active.items():
            lanes = np.nonzero(vd[s])[0]
            emitted = [int(tk[s][i]) for i in lanes]
            accepted = max(0, len(emitted) - 1)
            self.n_spec_proposed += int(prop_lens[s])
            self.n_spec_accepted += accepted
            st = self.spec_task_stats.setdefault(
                r.task or "default",
                {"lanes": 0, "sum_want": 0, "sum_k": 0, "accepted": 0},
            )
            st["lanes"] += 1
            st["sum_want"] += want_of[s]   # controller's chosen depth
            st["sum_k"] += int(prop_lens[s])
            st["accepted"] += accepted
            if not emitted:
                continue
            r.generated.extend(emitted)
            r.tokens_done = len(r.generated)
            self.pos[s] += len(emitted)
            self.last_token[s] = emitted[-1]
            n_emitted += len(emitted)
            for tok, lane in zip(emitted, lanes):
                tok_ev.append(
                    (r.rid, tok, t_start + dt * (lane + 1) / (kpad + 1))
                )
            last_lane = int(lanes[-1])
            finish_at[s] = t_start + dt * (last_lane + 1) / (kpad + 1)
            # rollback: rejected lanes' KV past the accepted position
            # is dead weight — give whole pages back to the pool
            self.kv.truncate(s, int(self.pos[s]))
        # accepted-only Appendix-A attribution: trailing all-rejected
        # lanes are trimmed by observe_decode_block, so rejected
        # speculation never biases the Eq. 2 fit low
        self.profiler.observe_decode_block(
            [[pos0[s] + i for s in sorted(pos0) if vd[s, i]]
             for i in range(kpad + 1)], dt,
        )
        self.n_decode_tokens += n_emitted
        self._retire(finish_at)
        return {"kind": "decode", "n": len(pos0), "k": kpad + 1,
                "tokens": n_emitted, "time": dt, "spec": True,
                "token_events": tok_ev}

    def _decode_block_step(self, k: int) -> dict:
        """One fused K-iteration decode block (either plane): a single
        jitted dispatch and a single host sync cover K tokens for every
        active slot, with EOS / max-len / l_out stopping evaluated on
        device (a row finishing mid-block freezes and its later lanes
        come back invalid)."""
        cfg = self.cfg
        alive = np.zeros(cfg.n_slots, bool)
        rem = np.zeros(cfg.n_slots, np.int32)
        pos0: dict[int, int] = {}
        for s, r in self.active.items():
            alive[s] = True
            rem[s] = r.l_out - len(r.generated)
            pos0[s] = int(self.pos[s])
        last_d, pos_d = self._device_state()
        eos = jnp.int32(-1 if cfg.eos_token is None else cfg.eos_token)
        fn = self._decode_block_fn(k)
        args = (self.params, self.caches)
        if self.paged:
            args += (self.kv.device_table(),)
        t0 = time.perf_counter()
        (toks, valid, last_f, pos_f), self.caches = fn(
            *args, last_d, pos_d, jnp.asarray(alive), jnp.asarray(rem),
            eos, jnp.int32(cfg.max_len),
        )
        toks, valid = jax.block_until_ready((toks, valid))
        dt = time.perf_counter() - t0
        self.clock += dt
        self.n_dispatches += 1
        self.decode_block_hist[k] = self.decode_block_hist.get(k, 0) + 1
        # the scan's final state IS the next block's input — resident
        self._dev_state = (last_f, pos_f)
        self._host_state_dirty = False

        tk = np.asarray(toks)   # (n_slots, K)
        vd = np.asarray(valid)  # (n_slots, K) bool
        t_start = self.clock - dt
        finish_at: dict[int, float] = {}
        tok_ev: list[tuple] = []  # (rid, token, t) stream events
        n_emitted = 0
        for s, r in self.active.items():
            row = vd[s]
            lanes = np.nonzero(row)[0]
            emitted = [int(tk[s][i]) for i in lanes]
            if not emitted:
                continue
            r.generated.extend(emitted)
            r.tokens_done = len(r.generated)
            self.pos[s] += len(emitted)
            self.last_token[s] = emitted[-1]
            n_emitted += len(emitted)
            # per-token timestamps interpolate inside the block, so
            # TTFT/TPOT (and the streamed token stamps) stay comparable
            # with per-step runs / the sim — no extra host syncs: the
            # block's one sync already delivered the (n_slots, K) matrix
            for tok, lane in zip(emitted, lanes):
                tok_ev.append((r.rid, tok, t_start + dt * (lane + 1) / k))
            last_lane = int(lanes[-1])
            finish_at[s] = t_start + dt * (last_lane + 1) / k
        # Appendix-A attribution: K per-iteration samples of dt/K at
        # the interpolated lengths (what per-token stepping observes)
        self.profiler.observe_decode_block(
            [[pos0[s] + i for s in sorted(pos0) if vd[s, i]]
             for i in range(k)], dt,
        )
        self.n_decode_tokens += n_emitted
        self._retire(finish_at)
        return {"kind": "decode", "n": len(pos0), "k": k,
                "tokens": n_emitted, "time": dt, "token_events": tok_ev}

    def _decode_paged(self) -> dict:
        cfg = self.cfg
        if (cfg.spec_decode and self.active
                and not self.prefilling and not self.queue):
            # speculate only when decode owns the step (pending prefill
            # keeps the Eq. 5 chunk/decode interleave, same as the
            # decode-block collapse-to-1 rule)
            ev = self._spec_decode_step()
            if ev is not None:
                return ev
        k = self._fit_block_k(self._decode_block_k())
        # page pre-reservation: every active slot gets room for K new
        # tokens; _fit_block_k guarantees this fits for K > 1, and at
        # K == 1 the legacy preempt-youngest fallback reclaims pages
        for s in list(self.active):
            if s not in self.active:  # evicted by an earlier preemption
                continue
            while not self.kv.ensure(
                s, min(int(self.pos[s]) + k, cfg.max_len)
            ):
                if not self._preempt_youngest(exclude=s):
                    raise RuntimeError(
                        "page pool exhausted with a single request in "
                        "flight — submit() sizing guard violated"
                    )
        if k > 1:
            return self._decode_block_step(k)
        lens = np.zeros((cfg.n_slots,), np.int32)
        for s in self.active:
            lens[s] = 1  # the new token lands at position pos[s]
        t0 = time.perf_counter()
        logits, self.caches = self._chunk(
            self.params, self.caches, self.kv.device_table(),
            jnp.asarray(self.last_token[:, None]),
            jnp.asarray(self.pos), jnp.asarray(lens),
        )
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.clock += dt
        cur = [int(self.pos[s]) for s in sorted(self.active)]
        self.profiler.observe_decode(cur, dt)

        return self._finish_per_token_decode(
            np.asarray(jnp.argmax(logits, axis=-1), np.int32), dt)

    # ==========================================================================
    # Slot-based plane (monolithic prefill fallback)
    # ==========================================================================
    # -- admission (Eq. 5 at the engine boundary) ------------------------------
    def _admit(self) -> list[Request]:
        free = self.slots.n_free
        if not free or not self.queue:
            return []
        take = self.queue[: min(free, self.cfg.prefill_batch)]
        if self.cfg.slo_aware and self.active:
            cur_lens = [int(self.pos[s]) for s in self.slots.active_slots()]
            e_d = self.profiler.decode_step_time(cur_lens) if (
                self.profiler.fitted
            ) else 0.0
            tightest_tpot = min(
                [r.tpot_slo for r in self.active.values()]
                + [r.tpot_slo for r in take]
            )
            tightest_ttft = min(r.ttft_slo for r in take)
            budget = ntoken_limit(
                tightest_ttft, tightest_tpot, e_d, self.profiler
            ) if self.profiler.fitted else 10 ** 9
            out, used = [], 0
            for r in take:
                if used + len(r.prompt) <= budget:
                    out.append(r)
                    used += len(r.prompt)
            take = out
        for r in take:
            self.queue.remove(r)
        return take

    def _pad_to(self, n: int) -> int:
        # pad prompt batches to a small set of shapes to bound recompiles
        p = 8
        while p < n:
            p *= 2
        return p

    def _prefill(self, reqs: Sequence[Request]) -> dict:
        b = len(reqs)
        max_l = self._pad_to(max(len(r.prompt) for r in reqs))
        tokens = np.zeros((b, max_l), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        fn = self._prefill_fn(max_l)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, jnp.asarray(tokens),
                           jnp.asarray(lens))
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.clock += dt
        self.n_dispatches += 1
        self.profiler.observe_prefill([len(r.prompt) for r in reqs], dt)
        self.n_prefill_tokens += int(sum(len(r.prompt) for r in reqs))

        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        slots = []
        tok_ev: list[tuple] = []
        for i, r in enumerate(reqs):
            s = self.slots.alloc(r)
            assert s is not None
            r.slot = s
            r.prefill_progress = len(r.prompt)
            if r.first_token_time is None:
                r.first_token_time = self.clock
            r.generated.append(int(next_tokens[i]))
            r.tokens_done = len(r.generated)
            tok_ev.append((r.rid, int(next_tokens[i]), self.clock))
            r.state = RequestState.DECODING
            self.active[s] = r
            self._rid_slot[r.rid] = s
            self.pos[s] = int(lens[i])
            self.last_token[s] = int(next_tokens[i])
            slots.append(s)
        self._host_state_dirty = True
        self.caches = insert_rows(self.caches, cache, self.axes, slots,
                                  src_rows=list(range(b)))
        self._retire()
        return {"kind": "prefill", "n": b, "time": dt,
                "token_events": tok_ev}

    def _decode_step(self) -> dict:
        k = self._decode_block_k()
        if k > 1:
            return self._decode_block_step(k)
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.last_token),
            jnp.asarray(self.pos),
        )
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.clock += dt
        cur = [int(self.pos[s]) for s in self.slots.active_slots()]
        self.profiler.observe_decode(cur, dt)

        return self._finish_per_token_decode(
            np.asarray(jnp.argmax(logits, axis=-1), np.int32), dt)

    def _finish_per_token_decode(self, nxt, dt: float) -> dict:
        """Shared K=1 tail for both planes: append the sampled token
        per active slot, advance host state, account telemetry, and
        retire — one place to keep the paged/slot paths in sync."""
        n_tok = len(self.active)
        tok_ev: list[tuple] = []
        for s, r in list(self.active.items()):
            self.pos[s] += 1
            tok = int(nxt[s])
            r.generated.append(tok)
            r.tokens_done = len(r.generated)
            self.last_token[s] = tok
            tok_ev.append((r.rid, tok, self.clock))
        self._host_state_dirty = True
        self.n_dispatches += 1
        self.decode_block_hist[1] = self.decode_block_hist.get(1, 0) + 1
        self.n_decode_tokens += n_tok
        self._retire()
        return {"kind": "decode", "n": n_tok, "k": 1,
                "tokens": n_tok, "time": dt, "token_events": tok_ev}

    # -- completion (both planes) ----------------------------------------------
    def _is_done(self, r: Request, s: int) -> bool:
        """The one completion predicate — shared by ``_retire``, the
        chunk-prefill park decision, and (mirrored in jnp) the
        decode-block device mask: output cap reached, EOS emitted, or
        no room for another token's KV within max_len."""
        eos = (self.cfg.eos_token is not None and r.generated
               and r.generated[-1] == self.cfg.eos_token)
        return bool(len(r.generated) >= r.l_out or eos
                    or int(self.pos[s]) + 1 >= self.cfg.max_len)

    def _retire(self, finish_at: Optional[dict] = None) -> None:
        """Move completed requests out of the decode batch.

        ``finish_at`` (slot -> time) carries interpolated per-token
        stamps from a fused decode block; without it a request
        finishes at the engine clock (the per-step case).
        """
        done = []
        for s, r in list(self.active.items()):
            if self._is_done(r, s):
                r.finish_time = (finish_at or {}).get(s, self.clock)
                r.state = RequestState.FINISHED
                self.finished.append(r)
                done.append(s)
                del self.active[s]
                self._rid_slot.pop(r.rid, None)
        if done:
            self.caches = clear_rows(self.caches, self.axes, done)
            for s in done:
                self.slots.free(s)
                if self.kv is not None:
                    self.kv.release(s)
                self.pos[s] = 0
                self.last_token[s] = 0
            self._host_state_dirty = True

    # -- drive to completion ------------------------------------------------------
    def run_until_done(self, max_steps: int = 10_000) -> list[Request]:
        """Step until idle; returns the requests finished during the call."""
        mark = len(self.finished)
        for _ in range(max_steps):
            if not self.queue and not self.active and not self.prefilling:
                break
            self.step()
        return self.finished[mark:]

    def fit_profiler(self) -> bool:
        return self.profiler.fit(min_samples=4)

    def release_weights(self) -> None:
        """Drop this replica's params tree (scale-in).  Every replica
        OWNS its weights (provisioned per-replica by the cluster's
        WeightManager, never aliased), so dropping the reference here
        makes the copy's device memory reclaimable.  The engine must
        not step again afterwards."""
        if self.queue or self.active or self.prefilling or self.parked:
            raise RuntimeError(
                "release_weights on an engine that still holds work; "
                "drain before scale-in"
            )
        self.params = None
