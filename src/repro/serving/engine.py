"""Real JAX inference engine: continuous batching over an actual model.

This is the execution plane the simulator abstracts: jitted prefill and
decode step functions, slot-based KV caches, greedy sampling, and the
paper's SLO-aware admission (Eq. 5 token budget) at the engine boundary.
It doubles as the latency profiler — measured step times feed
FittedLatencyModel exactly like the paper's request profiler
(Appendix A).

Designed for reduced configs on CPU (tests/examples) and full configs
on TPU; the compute path is the same model code the dry-run lowers.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.latency_model import FittedLatencyModel
from repro.core.request import Request
from repro.core.token_budget import ntoken_limit
from repro.models.build import Model
from repro.serving.kv_manager import SlotManager, clear_rows, insert_rows


@dataclasses.dataclass
class EngineConfig:
    n_slots: int = 8
    max_len: int = 128
    prefill_batch: int = 4          # max sequences per prefill step
    slo_aware: bool = True          # Eq. 5 admission at the engine
    eos_token: Optional[int] = None


@dataclasses.dataclass
class EngineRequest:
    rid: int
    prompt: np.ndarray              # (l_in,) int32
    max_new: int
    ttft_slo: float = 10.0
    tpot_slo: float = 1.0
    arrival: float = 0.0
    # lifecycle
    slot: Optional[int] = None
    generated: Optional[list] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None


class InferenceEngine:
    def __init__(self, model: Model, params, cfg: EngineConfig):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.slots = SlotManager(cfg.n_slots)
        self.caches = model.init_cache(cfg.n_slots, cfg.max_len)
        self.axes = model.cache_axes()
        self.queue: list[EngineRequest] = []
        self.active: dict[int, EngineRequest] = {}
        self.pos = np.zeros(cfg.n_slots, np.int32)
        self.last_token = np.zeros(cfg.n_slots, np.int32)
        self.profiler = FittedLatencyModel()
        self.clock = 0.0  # virtual clock advanced by measured step times

        self._prefill_fns: dict[int, Callable] = {}
        self._decode = jax.jit(model.decode_step)
        self._insert = jax.jit(
            insert_rows, static_argnames=()
        ) if False else insert_rows

    # -- intake -------------------------------------------------------------
    def submit(self, req: EngineRequest) -> None:
        req.generated = []
        req.arrival = self.clock
        self.queue.append(req)

    def _prefill_fn(self, seq_len: int) -> Callable:
        if seq_len not in self._prefill_fns:
            def fn(params, tokens, lens):
                return self.model.prefill(
                    params, tokens, lens, cache_len=self.cfg.max_len
                )
            self._prefill_fns[seq_len] = jax.jit(fn)
        return self._prefill_fns[seq_len]

    # -- admission (Eq. 5 at the engine boundary) -----------------------------
    def _admit(self) -> list[EngineRequest]:
        free = self.slots.n_free
        if not free or not self.queue:
            return []
        take = self.queue[: min(free, self.cfg.prefill_batch)]
        if self.cfg.slo_aware and self.active:
            cur_lens = [int(self.pos[s]) for s in self.slots.active_slots()]
            e_d = self.profiler.decode_step_time(cur_lens) if (
                self.profiler.fitted
            ) else 0.0
            tightest_tpot = min(
                [r.tpot_slo for r in self.active.values()]
                + [r.tpot_slo for r in take]
            )
            tightest_ttft = min(r.ttft_slo for r in take)
            budget = ntoken_limit(
                tightest_ttft, tightest_tpot, e_d, self.profiler
            ) if self.profiler.fitted else 10 ** 9
            out, used = [], 0
            for r in take:
                if used + len(r.prompt) <= budget:
                    out.append(r)
                    used += len(r.prompt)
            take = out
        for r in take:
            self.queue.remove(r)
        return take

    # -- one engine step --------------------------------------------------------
    def step(self) -> dict:
        """Run one prefill or decode step; returns event info."""
        admitted = self._admit()
        if admitted:
            return self._prefill(admitted)
        if self.active:
            return self._decode_step()
        return {"kind": "idle"}

    def _pad_to(self, n: int) -> int:
        # pad prompt batches to a small set of shapes to bound recompiles
        p = 8
        while p < n:
            p *= 2
        return p

    def _prefill(self, reqs: Sequence[EngineRequest]) -> dict:
        b = len(reqs)
        max_l = self._pad_to(max(len(r.prompt) for r in reqs))
        tokens = np.zeros((b, max_l), np.int32)
        lens = np.zeros((b,), np.int32)
        for i, r in enumerate(reqs):
            tokens[i, : len(r.prompt)] = r.prompt
            lens[i] = len(r.prompt)
        fn = self._prefill_fn(max_l)
        t0 = time.perf_counter()
        logits, cache = fn(self.params, jnp.asarray(tokens),
                           jnp.asarray(lens))
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.clock += dt
        self.profiler.observe_prefill([len(r.prompt) for r in reqs], dt)

        next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        slots = []
        for i, r in enumerate(reqs):
            s = self.slots.alloc(r)
            assert s is not None
            r.slot = s
            r.first_token_time = self.clock
            r.generated.append(int(next_tokens[i]))
            self.active[s] = r
            self.pos[s] = int(lens[i])
            self.last_token[s] = int(next_tokens[i])
            slots.append(s)
        self.caches = insert_rows(self.caches, cache, self.axes, slots,
                                  src_rows=list(range(b)))
        self._retire()
        return {"kind": "prefill", "n": b, "time": dt}

    def _decode_step(self) -> dict:
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, self.caches, jnp.asarray(self.last_token),
            jnp.asarray(self.pos),
        )
        logits = jax.block_until_ready(logits)
        dt = time.perf_counter() - t0
        self.clock += dt
        cur = [int(self.pos[s]) for s in self.slots.active_slots()]
        self.profiler.observe_decode(cur, dt)

        nxt = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
        for s, r in list(self.active.items()):
            self.pos[s] += 1
            tok = int(nxt[s])
            r.generated.append(tok)
            self.last_token[s] = tok
        self._retire()
        return {"kind": "decode", "n": len(self.active), "time": dt}

    def _retire(self) -> None:
        done = []
        for s, r in list(self.active.items()):
            eos = (self.cfg.eos_token is not None
                   and r.generated and r.generated[-1] == self.cfg.eos_token)
            full = self.pos[s] + 1 >= self.cfg.max_len
            if len(r.generated) >= r.max_new or eos or full:
                r.finish_time = self.clock
                done.append(s)
                del self.active[s]
        if done:
            self.caches = clear_rows(self.caches, self.axes, done)
            for s in done:
                self.slots.free(s)
                self.pos[s] = 0
                self.last_token[s] = 0

    # -- drive to completion ------------------------------------------------------
    def run_until_done(self, max_steps: int = 10_000) -> list[EngineRequest]:
        finished: list[EngineRequest] = []
        for _ in range(max_steps):
            if not self.queue and not self.active:
                break
            self.step()
        return finished

    def fit_profiler(self) -> bool:
        return self.profiler.fit(min_samples=4)
