"""SLO-customized speculative decoding (model-free drafter + controller).

Two pieces the engine (and the sim plane's mirror) share:

- :class:`NGramDrafter` — prompt-lookup / n-gram proposal over each
  request's ``prompt + generated`` token history.  No second model: the
  drafter finds the latest earlier occurrence of the trailing n-gram
  and proposes its historical continuation.  Fully deterministic, so
  proposals are seed-stable and the greedy verify step keeps token
  identity with plain decode (rejected proposals are rolled back).

- :func:`slo_spec_len` — the per-lane speculation-length controller.
  AdaServe's observation, grounded in the paper's Eq. 5 machinery: the
  right speculation depth is a function of the request's TPOT *slack*.
  A depth-``k`` propose-verify dispatch costs roughly
  ``E_d + b * k`` (one decode step plus ``k`` extra verify lanes at the
  prefill per-token rate ``b``) and in the worst case (nothing
  accepted) still emits one token — so the deepest K that cannot break
  the request's TPOT even on a total miss is

      K = floor((tpot_slo - E_d) / b)

  clamped to ``[0, max_spec_len]``.  Tight-slack requests speculate
  conservatively (or not at all); loose-slack requests go deep.  Both
  planes call this with the same :class:`FittedLatencyModel`, so the
  Dispatcher/Scaler see one throughput model.
"""

from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Knobs shared by the engine drafter and the sim mirror."""

    max_spec_len: int = 8      # proposal-depth ceiling per lane
    max_ngram: int = 3         # longest trailing n-gram to look up
    min_ngram: int = 1
    # controller depth before the profiler has fitted (Eq. 5 needs
    # coefficients): conservative, never zero — some speculation is how
    # acceptance statistics start accumulating
    unfitted_default: int = 2


class NGramDrafter:
    """Prompt-lookup drafter: propose the continuation that followed
    the most recent earlier occurrence of the current trailing n-gram.

    Greedy decode loops and template-heavy prompts repeat themselves;
    whenever the history has seen the current context before, the
    recorded continuation is a strong draft.  Lookup prefers longer
    n-grams (more specific context) and, within an n-gram length, the
    *latest* earlier match (most recent regime).
    """

    def __init__(self, max_ngram: int = 3, min_ngram: int = 1):
        if not (1 <= min_ngram <= max_ngram):
            raise ValueError(
                f"need 1 <= min_ngram <= max_ngram, got "
                f"{min_ngram}..{max_ngram}"
            )
        self.max_ngram = max_ngram
        self.min_ngram = min_ngram

    def propose(self, history, k: int) -> list[int]:
        """Up to ``k`` draft tokens continuing ``history`` (1-D int
        token ids, prompt + generated).  Deterministic; returns [] when
        no earlier occurrence of any trailing n-gram exists."""
        if k <= 0:
            return []
        h = np.asarray(history, np.int64)
        n_hist = int(h.shape[0])
        for n in range(min(self.max_ngram, n_hist - 1),
                       self.min_ngram - 1, -1):
            tail = h[n_hist - n:]
            # candidate starts whose n-gram ends strictly before the
            # history's end (the tail itself is excluded)
            starts = np.flatnonzero(h[: n_hist - n] == tail[0])
            match = None
            for i in starts[::-1]:          # latest match first
                if np.array_equal(h[i: i + n], tail):
                    match = int(i)
                    break
            if match is None:
                continue
            out = h[match + n: match + n + k]
            if out.size:
                return [int(x) for x in out]
        return []


def slo_spec_len(tpot_slo: float, model, cur_lens: Sequence[int],
                 cfg: SpecConfig) -> int:
    """Speculation depth for one lane from its Eq. 5 / TPOT slack.

    ``model`` is the shared (Fitted)LatencyModel: ``E_d`` comes from
    Eq. 2 over the current batch lengths and ``b`` (the prefill
    per-token coefficient) prices each extra verify lane.  Worst-case
    guarantee: a dispatch at the returned depth emits >= 1 token in at
    most ``tpot_slo`` seconds even when every proposal is rejected.
    """
    if cfg.max_spec_len <= 0:
        return 0
    if not getattr(model, "fitted", True):
        return min(cfg.unfitted_default, cfg.max_spec_len)
    e_d = model.decode_step_time(list(cur_lens))
    slack = tpot_slo - e_d
    if slack <= 0.0:
        return 0
    b = max(float(model.b), 1e-12)
    return int(min(slack / b, cfg.max_spec_len))


def expected_emitted(k: int, accept_rate: float) -> float:
    """Expected tokens emitted by one depth-``k`` propose-verify
    dispatch under i.i.d. per-token acceptance probability
    ``accept_rate`` (geometric longest-prefix): 1 + sum_{i=1..k} a^i.

    The sim plane scales its decode ticks by this so the Dispatcher /
    Scaler see the same acceptance-rate-scaled throughput model the
    engine plane measures.
    """
    k = max(0, int(k))
    a = min(max(float(accept_rate), 0.0), 1.0)
    if k == 0:
        return 1.0
    if a >= 1.0:
        return 1.0 + k
    return 1.0 + a * (1.0 - a ** k) / (1.0 - a)
