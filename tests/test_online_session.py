"""Online serving session (PR 5): submit/stream/drain over both planes,
submit-time SLO admission, the run-loop horizon fix, and Cluster.run as
a thin adapter over ServingSession."""

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.request import Request, RequestState
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.session import EventKind, ServingSession, StreamEvent
from repro.serving.workload import poisson_workload

MODEL = get_config("qwen7b")
SMOKE = get_smoke_config("qwen7b")

TOKEN_KINDS = (EventKind.FIRST_TOKEN, EventKind.TOKEN)


def _engine_cfg(**kw):
    from repro.serving.engine import EngineConfig

    kw.setdefault("engine", EngineConfig(n_slots=4, max_len=48,
                                         prefill_batch=2, page_size=8,
                                         chunk_size=16))
    return ClusterConfig(model=SMOKE, backend="engine", n_workers=1,
                         policy="hyperflexis", seed=0, **kw)


def _sim_cfg(**kw):
    kw.setdefault("n_workers", 1)
    return ClusterConfig(model=MODEL, policy="hyperflexis", seed=0, **kw)


def _workload(n=8, seed=3):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.05))
        reqs.append(Request(rid=i, task="gsm8k", arrival=t,
                            l_in=int(rng.integers(4, 14)),
                            l_out=int(rng.integers(2, 6)),
                            ttft_slo=5.0, tpot_slo=1.0))
    return reqs


def _streamed_tokens(handle):
    return [ev.token for ev in handle.log if ev.kind in TOKEN_KINDS]


# ---------------------------------------------------------------------------
# Tentpole: token identity between online streaming and the batch run
# ---------------------------------------------------------------------------

def test_online_stream_token_identical_to_batch_engine():
    """Acceptance: online submit()-streamed token ids are bit-identical
    to the batch Cluster.run() output on the engine plane."""
    batch_reqs = _workload()
    Cluster(_engine_cfg()).run(batch_reqs)

    session = ServingSession(Cluster(_engine_cfg()), admission="none")
    handles = [session.submit_request(r) for r in _workload()]
    session.drain()
    session.close()

    for h, br in zip(handles, batch_reqs):
        assert h.done and not h.rejected
        streamed = _streamed_tokens(h)
        assert streamed == h.request.generated      # stream == record
        assert streamed == br.generated             # online == batch
        assert len(streamed) == h.request.tokens_done


def test_online_stream_matches_batch_sim():
    """Sim plane: no real ids (token=None), but the stream must carry
    exactly tokens_done ticks per request with stamps matching the
    recorded first-token/finish times of a batch run."""
    batch_reqs = _workload()
    Cluster(_sim_cfg()).run(batch_reqs)

    session = ServingSession(Cluster(_sim_cfg()), admission="none")
    handles = [session.submit_request(r) for r in _workload()]
    session.drain()
    session.close()

    for h, br in zip(handles, batch_reqs):
        toks = [ev for ev in h.log if ev.kind in TOKEN_KINDS]
        assert all(ev.token is None for ev in toks)
        assert len(toks) == h.request.tokens_done == br.tokens_done
        assert toks[0].time == pytest.approx(h.request.first_token_time)
        assert toks[-1].time == pytest.approx(h.request.finish_time)


def test_cluster_run_is_thin_adapter_over_session():
    """Acceptance: the batch path goes through ServingSession (one
    event loop).  After run(), the cluster carries the session's
    streaming sinks' results — and a second session cannot attach
    while one is live."""
    cl = Cluster(_sim_cfg())
    session = ServingSession(cl)
    with pytest.raises(RuntimeError, match="already"):
        ServingSession(cl)
    session.close()
    # a Cluster's clock/cost accounting span one session: re-attaching
    # (or re-running) a used cluster fails loudly instead of silently
    # clamping arrivals past the previous makespan
    with pytest.raises(RuntimeError, match="fresh Cluster"):
        ServingSession(cl)
    reqs = _workload(2)
    cl2 = Cluster(_sim_cfg())
    cl2.run(reqs)
    with pytest.raises(RuntimeError, match="fresh Cluster"):
        cl2.run(reqs)


# ---------------------------------------------------------------------------
# Event stream shape
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_cfg", [_sim_cfg, _engine_cfg],
                         ids=["sim", "engine"])
def test_event_ordering_and_timestamp_monotonicity(make_cfg):
    session = ServingSession(Cluster(make_cfg()), admission="none")
    handles = [session.submit_request(r) for r in _workload(6)]
    session.drain()
    session.close()
    for h in handles:
        kinds = [ev.kind for ev in h.log]
        assert kinds[0] == EventKind.ADMITTED
        assert kinds[1] == EventKind.FIRST_TOKEN
        assert kinds[-1] == EventKind.FINISHED
        assert all(k == EventKind.TOKEN for k in kinds[2:-1])
        times = [ev.time for ev in h.log]
        assert all(b >= a - 1e-12 for a, b in zip(times, times[1:]))
        assert h.log[1].time == pytest.approx(
            h.request.first_token_time)
        fin = h.log[-1]
        assert fin.data["n_tokens"] == h.request.tokens_done


def test_submit_after_start_mid_run():
    """A request submitted while the loop is already streaming another
    one is admitted, served, and token-identical to its batch twin."""
    session = ServingSession(Cluster(_engine_cfg()), admission="none")
    h1 = session.submit(prompt=np.arange(1, 9, dtype=np.int32),
                        l_out=5, ttft_slo=5.0, tpot_slo=1.0)
    it = h1.events()
    while next(it).kind != EventKind.FIRST_TOKEN:
        pass  # h1 is mid-stream now
    h2 = session.submit(prompt=np.arange(3, 9, dtype=np.int32),
                        l_out=3, ttft_slo=5.0, tpot_slo=1.0)
    assert h2.request.arrival >= h1.request.first_token_time
    session.drain()
    session.close()
    assert h1.done and h2.done
    assert _streamed_tokens(h1) == h1.request.generated
    assert _streamed_tokens(h2) == h2.request.generated
    assert len(h2.request.generated) == 3


def test_closed_loop_client_via_events_generator():
    """handle.events() drives the loop: a client that only iterates its
    own stream still makes the whole cluster progress."""
    session = ServingSession(Cluster(_sim_cfg()))
    h = session.submit(l_in=32, l_out=8, ttft_slo=10.0, tpot_slo=1.0)
    kinds = [ev.kind for ev in h.events()]
    assert kinds[0] == EventKind.ADMITTED
    assert kinds[-1] == EventKind.FINISHED
    # closed loop: the next request is stamped at the previous finish
    h2 = session.submit(l_in=16, l_out=4, ttft_slo=10.0, tpot_slo=1.0)
    assert h2.request.arrival == pytest.approx(h.request.finish_time)
    h2.result()
    assert h2.request.state == RequestState.FINISHED
    session.drain()
    session.close()


# ---------------------------------------------------------------------------
# Submit-time admission control
# ---------------------------------------------------------------------------

def test_rejection_verdict_under_saturated_budget():
    """A request whose TTFT SLO cannot clear even an idle worker's
    prefill estimate is refused at submit time — REJECTED event with a
    reason, state REJECTED, never queued."""
    session = ServingSession(Cluster(_sim_cfg()), admission="reject")
    ok = session.submit(l_in=64, l_out=4, ttft_slo=10.0, tpot_slo=1.0)
    doomed = session.submit(l_in=2048, l_out=4, ttft_slo=1e-4,
                            tpot_slo=1.0)
    assert doomed.rejected and doomed.done
    ev = doomed.log[-1]
    assert ev.kind == EventKind.REJECTED
    assert "theta" in ev.data["reason"]
    session.drain()
    res = session.close()
    assert ok.request.state == RequestState.FINISHED
    assert doomed.request.state == RequestState.REJECTED
    assert doomed.request.finish_time is None
    assert res.metrics.n_rejected == 1
    assert res.metrics.n_total == 2 and res.metrics.n_finished == 1
    assert session.streaming.n_rejected == 1


def test_degrade_mode_renegotiates_slo_and_serves():
    """admission='degrade': the same doomed request is admitted with
    its TTFT SLO stretched to the achievable estimate."""
    session = ServingSession(Cluster(_sim_cfg()), admission="degrade")
    h = session.submit(l_in=2048, l_out=4, ttft_slo=1e-4, tpot_slo=1.0)
    assert not h.rejected
    adm = h.log[0]
    assert adm.kind == EventKind.ADMITTED
    assert adm.data.get("degraded") is True
    assert h.request.ttft_slo > 1e-4
    session.drain()
    session.close()
    assert h.request.state == RequestState.FINISHED


def test_degrade_mode_still_rejects_unplaceable_requests():
    """degrade relaxes SLOs, but a prompt no worker could EVER hold
    (verdict.wid is None) is refused — renegotiation can't fix
    capacity, and queueing it would spin until drain_timeout."""
    session = ServingSession(Cluster(_sim_cfg()), admission="degrade")
    h = session.submit(l_in=10**9, l_out=4, ttft_slo=10.0, tpot_slo=1.0)
    assert h.rejected
    assert "hold the prompt" in h.log[-1].data["reason"]
    session.drain()
    session.close()


def test_engine_impossible_request_rejected_not_raised():
    """Online mode turns the engine's validation error into a REJECTED
    verdict instead of an exception (batch mode still raises)."""
    session = ServingSession(Cluster(_engine_cfg()), admission="reject")
    h = session.submit(l_in=4096, l_out=4, ttft_slo=10.0, tpot_slo=1.0)
    assert h.rejected
    assert "never fit" in h.log[-1].data["reason"]
    session.drain()
    session.close()


def test_rejected_requests_count_in_partial_metrics():
    session = ServingSession(Cluster(_sim_cfg()), admission="reject")
    session.submit(l_in=2048, l_out=4, ttft_slo=1e-4, tpot_slo=1.0)
    h = session.submit(l_in=16, l_out=4, ttft_slo=10.0, tpot_slo=1.0)
    h.result()
    m = session.partial()
    assert m.n_total == 2 and m.n_rejected == 1 and m.n_finished == 1
    # rolling attainment is over finished-so-far
    assert m.attainment == 1.0
    session.drain()
    session.close()


# ---------------------------------------------------------------------------
# Satellite: run-loop horizon fix
# ---------------------------------------------------------------------------

def test_horizon_extends_while_inflight_decode_tail():
    """Regression: the loop used to exit at max(arrival)+drain_timeout
    even while an admitted request was mid-decode, silently counting a
    long l_out tail as an SLO miss.  The horizon must extend while
    in-flight work progresses."""
    reqs = [Request(rid=0, task="tail", arrival=0.0, l_in=32,
                    l_out=2000, ttft_slo=10.0, tpot_slo=1.0)]
    res = Cluster(_sim_cfg(drain_timeout=0.05)).run(reqs)
    assert res.metrics.n_finished == 1
    assert reqs[0].state == RequestState.FINISHED
    assert reqs[0].tokens_done == 2000
    # the decode tail really did outlive the naive horizon
    assert reqs[0].finish_time > 0.05


def test_horizon_still_times_out_unplaceable_work():
    """The extension is progress-gated: queued work that can never be
    dispatched still times out drain_timeout after the last progress,
    instead of spinning forever."""
    # theta-impossible request with admission disabled: it queues and
    # is never admitted by the dispatch pass
    reqs = [Request(rid=0, task="stuck", arrival=0.0, l_in=4096,
                    l_out=4, ttft_slo=1e-6, tpot_slo=1e-6)]
    res = Cluster(_sim_cfg(drain_timeout=0.5)).run(reqs)
    assert res.metrics.n_finished == 0
    assert reqs[0].finish_time is None


# ---------------------------------------------------------------------------
# Wall-clock driver
# ---------------------------------------------------------------------------

def test_wall_clock_driver_completes_and_paces():
    import time as _time

    session = ServingSession(Cluster(_sim_cfg()), clock="wall")
    t0 = _time.monotonic()
    h = session.submit(l_in=16, l_out=4, ttft_slo=10.0, tpot_slo=1.0)
    h.result()
    session.drain()
    session.close()
    elapsed = _time.monotonic() - t0
    assert h.request.state == RequestState.FINISHED
    # wall pacing: the virtual finish time was waited out in real time
    # (allow generous slack for sleep granularity / scheduler jitter)
    assert elapsed >= 0.5 * h.request.finish_time
    times = [ev.time for ev in h.log]
    assert all(b >= a - 1e-12 for a, b in zip(times, times[1:]))


# ---------------------------------------------------------------------------
# Session hygiene
# ---------------------------------------------------------------------------

def test_submit_after_close_raises_and_duplicate_rid_rejected():
    session = ServingSession(Cluster(_sim_cfg()))
    h = session.submit(rid=7, l_in=8, l_out=2, ttft_slo=10.0,
                       tpot_slo=1.0)
    with pytest.raises(ValueError, match="duplicate"):
        session.submit(rid=7, l_in=8, l_out=2, ttft_slo=10.0,
                       tpot_slo=1.0)
    h.result()
    # rids are unique for the session's lifetime: a finished request's
    # rid can neither be resubmitted nor handed out by auto-assignment
    with pytest.raises(ValueError, match="duplicate"):
        session.submit(rid=7, l_in=8, l_out=2, ttft_slo=10.0,
                       tpot_slo=1.0)
    h2 = session.submit(l_in=8, l_out=2, ttft_slo=10.0, tpot_slo=1.0)
    assert h2.rid not in (7, h.rid)
    session.drain()
    session.close()
    with pytest.raises(RuntimeError, match="closed"):
        session.submit(l_in=8, l_out=2, ttft_slo=10.0, tpot_slo=1.0)


def test_stream_event_json_schema():
    ev = StreamEvent(EventKind.TOKEN, rid=3, time=1.25, token=42)
    assert ev.to_json() == {"event": "token", "rid": 3, "t": 1.25,
                            "token": 42}
    ev = StreamEvent(EventKind.REJECTED, rid=1, time=0.0,
                     data={"reason": "x"})
    assert ev.to_json() == {"event": "rejected", "rid": 1, "t": 0.0,
                            "reason": "x"}


def test_batch_runs_unaffected_by_rejection_field():
    """Closed-world runs admit everything: n_rejected stays 0 and the
    RunMetrics row schema carries the field on both planes."""
    reqs = poisson_workload(["gsm8k"], qps=16, n_per_task=5, seed=0)
    res = Cluster(_sim_cfg()).run(reqs)
    assert res.metrics.n_rejected == 0
    assert "n_rejected" in res.metrics.row()
