"""Paged KV plane: allocator invariants, page-table gather, and the
paged decode-attention kernel vs its oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import paged_decode_attention
from repro.serving.kv_manager import PageAllocator, PagedKVManager


# ---------------------------------------------------------------------------
# Allocator
# ---------------------------------------------------------------------------


def test_alloc_no_double_allocation():
    a = PageAllocator(n_pages=16, page_size=8)
    seen = set()
    for owner in range(4):
        pages = a.alloc(4, owner=owner)
        assert pages is not None and len(pages) == 4
        assert not (set(pages) & seen)
        seen |= set(pages)
    assert a.n_free == 0
    assert a.alloc(1) is None          # exhausted, existing intact
    assert seen == set(range(16))


def test_alloc_atomic_on_failure():
    a = PageAllocator(n_pages=4, page_size=8)
    got = a.alloc(3, owner="x")
    assert a.alloc(2) is None          # only 1 left: nothing allocated
    assert a.n_free == 1
    a.free(got)
    assert a.n_free == 4


def test_full_reclamation_cycles():
    a = PageAllocator(n_pages=8, page_size=4)
    for _ in range(10):
        p1 = a.alloc(5, owner=1)
        p2 = a.alloc(3, owner=2)
        assert p1 is not None and p2 is not None
        a.free(p1)
        a.free(p2)
    assert a.n_free == 8
    assert a.n_used == 0


def test_double_free_asserts():
    a = PageAllocator(n_pages=2, page_size=4)
    p = a.alloc(1)
    a.free(p)
    with pytest.raises(AssertionError):
        a.free(p)


def test_kv_manager_ensure_grow_and_release():
    kv = PagedKVManager(n_slots=2, max_len=32, page_size=8)
    assert kv.max_pages == 4 and kv.n_pages == 8
    assert kv.ensure(0, 1)             # 1 token -> 1 page
    assert len(kv.pages_of(0)) == 1
    assert kv.ensure(0, 8)             # exact page boundary: still 1
    assert len(kv.pages_of(0)) == 1
    assert kv.ensure(0, 9)             # crosses into page 2
    assert len(kv.pages_of(0)) == 2
    assert kv.ensure(0, 32) and len(kv.pages_of(0)) == 4
    assert not kv.ensure(0, 33)        # beyond max_len
    assert kv.ensure(1, 32)
    assert kv.n_free_pages == 0
    kv.release(0)
    assert kv.n_free_pages == 4
    assert (kv.table[0] == -1).all()
    kv.release(1)
    assert kv.n_free_pages == kv.n_pages


def test_kv_manager_tables_disjoint():
    kv = PagedKVManager(n_slots=4, max_len=16, page_size=4)
    for s in range(4):
        assert kv.ensure(s, 16)
    used = [p for s in range(4) for p in kv.pages_of(s)]
    assert len(used) == len(set(used)) == 16


# ---------------------------------------------------------------------------
# Gather / kernel vs contiguous reference
# ---------------------------------------------------------------------------


def _paged_fixture(seed, b, h, s, d, ps):
    """Build a contiguous cache and its paged twin via a PagedKVManager."""
    rng = np.random.default_rng(seed)
    kv = PagedKVManager(n_slots=b, max_len=s, page_size=ps)
    kv_len = rng.integers(1, s + 1, size=b).astype(np.int32)
    k_cont = rng.standard_normal((b, h, s, d)).astype(np.float32)
    v_cont = rng.standard_normal((b, h, s, d)).astype(np.float32)
    k_pages = np.zeros((kv.n_pages, h, ps, d), np.float32)
    v_pages = np.zeros((kv.n_pages, h, ps, d), np.float32)
    for i in range(b):
        assert kv.ensure(i, int(kv_len[i]))
        for t in range(int(kv_len[i])):
            pg = kv.table[i, t // ps]
            k_pages[pg, :, t % ps] = k_cont[i, :, t]
            v_pages[pg, :, t % ps] = v_cont[i, :, t]
        k_cont[i, :, kv_len[i]:] = 0  # masked region: match zeros
        v_cont[i, :, kv_len[i]:] = 0
    return kv, map(jnp.asarray, (k_cont, v_cont, k_pages, v_pages, kv_len))


def test_page_table_gather_matches_contiguous():
    b, h, s, d, ps = 3, 2, 32, 16, 8
    kv, (k_cont, _, k_pages, _, kv_len) = _paged_fixture(0, b, h, s, d, ps)
    got = ref.paged_gather(k_pages, jnp.asarray(kv.table))
    for i in range(b):
        n = int(kv_len[i])
        np.testing.assert_array_equal(
            np.asarray(got[i, :, :n]), np.asarray(k_cont[i, :, :n])
        )


@pytest.mark.parametrize("ps", [4, 8, 16])
def test_paged_decode_attention_matches_contiguous_ref(ps):
    b, h, s, d = 3, 2, 32, 16
    kv, (k_cont, v_cont, k_pages, v_pages, kv_len) = _paged_fixture(
        ps, b, h, s, d, ps
    )
    q = jax.random.normal(jax.random.key(7), (b, h, d))
    want = ref.decode_attention_ref(q, k_cont, v_cont, kv_len)
    pt = jnp.asarray(kv.table)
    got_ref = ref.paged_decode_attention_ref(q, k_pages, v_pages, pt, kv_len)
    got_pl = paged_decode_attention(q, k_pages, v_pages, pt, kv_len,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_decode_attention_gqa_heads():
    """Hq > Hkv: the kernel maps query head hi to kv head hi // g via
    the index map — must match the broadcast contiguous reference."""
    b, hq, hkv, s, d, ps = 2, 6, 2, 16, 16, 4
    kv, (k_cont, v_cont, k_pages, v_pages, kv_len) = _paged_fixture(
        11, b, hkv, s, d, ps
    )
    q = jax.random.normal(jax.random.key(5), (b, hq, d))
    g = hq // hkv
    want = ref.decode_attention_ref(
        q, jnp.repeat(k_cont, g, axis=1), jnp.repeat(v_cont, g, axis=1),
        kv_len,
    )
    pt = jnp.asarray(kv.table)
    got_ref = ref.paged_decode_attention_ref(q, k_pages, v_pages, pt, kv_len)
    got_pl = paged_decode_attention(q, k_pages, v_pages, pt, kv_len,
                                    interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(got_pl), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_paged_kernel_ignores_stale_pages():
    """Reclaimed-page garbage beyond kv_len must not leak into outputs."""
    b, h, s, d, ps = 2, 2, 16, 8, 4
    kv, (k_cont, v_cont, k_pages, v_pages, kv_len) = _paged_fixture(
        3, b, h, s, d, ps
    )
    # poison every allocated-but-unused offset and all free pages
    poison = 1e3 * jnp.ones_like(k_pages)
    mask = np.zeros((kv.n_pages, 1, ps, 1), bool)
    for i in range(b):
        for t in range(int(kv_len[i])):
            mask[kv.table[i, t // ps], 0, t % ps, 0] = True
    k_pois = jnp.where(jnp.asarray(mask), k_pages, poison)
    v_pois = jnp.where(jnp.asarray(mask), v_pages, poison)
    q = jax.random.normal(jax.random.key(9), (b, h, d))
    want = ref.decode_attention_ref(q, k_cont, v_cont, kv_len)
    got = paged_decode_attention(q, k_pois, v_pois, jnp.asarray(kv.table),
                                 kv_len, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# Property test (hypothesis optional)
# ---------------------------------------------------------------------------


def test_allocator_migration_traffic_property():
    """P/D migration traffic: interleaved grow (prefill), export
    (release on src + ensure on dst), import-fail rollback, and evict
    across TWO pools.  Invariants on both: no double-free (PageAllocator
    asserts), no leaked pages, free + used == total (conservation),
    tables disjoint."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    N_SLOTS, MAX_LEN, PS = 3, 24, 4

    @hyp.settings(max_examples=50, deadline=None)
    @hyp.given(ops=st.lists(
        st.tuples(st.sampled_from(["grow", "migrate", "evict"]),
                  st.integers(0, 1),           # which pool is src
                  st.integers(0, N_SLOTS - 1), # slot
                  st.integers(1, 9)),          # tokens to grow
        max_size=80))
    def inner(ops):
        pools = [PagedKVManager(N_SLOTS, MAX_LEN, PS),
                 PagedKVManager(N_SLOTS, MAX_LEN, PS)]
        lens = [[0] * N_SLOTS, [0] * N_SLOTS]
        for kind, pi, slot, n in ops:
            src, dst = pools[pi], pools[1 - pi]
            if kind == "grow":
                want = min(lens[pi][slot] + n, MAX_LEN)
                if src.ensure(slot, want):
                    lens[pi][slot] = want
            elif kind == "migrate" and lens[pi][slot] > 0:
                # export: install the same token count on some dst
                # slot, then release the source (transfer landed)
                t = lens[pi][slot]
                free = [s for s in range(N_SLOTS)
                        if lens[1 - pi][s] == 0]
                if free and dst.ensure(free[0], t):
                    lens[1 - pi][free[0]] = t
                    src.release(slot)
                    lens[pi][slot] = 0
                # else: import failed — ensure() rolled back, src keeps
                # its pages (nothing moved, nothing leaked)
            elif kind == "evict":
                src.release(slot)
                lens[pi][slot] = 0
            for j, kv in enumerate(pools):
                used = [p for s in range(N_SLOTS) for p in kv.pages_of(s)]
                assert len(used) == len(set(used))          # disjoint
                assert kv.n_free_pages + len(used) == kv.n_pages
                for s in range(N_SLOTS):                    # no leaks
                    assert len(kv.pages_of(s)) == -(-lens[j][s] // PS)
        for kv in pools:
            for s in range(N_SLOTS):
                kv.release(s)
            assert kv.n_free_pages == kv.n_pages   # full reclamation

    inner()


def test_allocator_random_workload_property():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    @hyp.settings(max_examples=50, deadline=None)
    @hyp.given(ops=st.lists(st.tuples(st.integers(0, 3),
                                      st.integers(0, 6)),
                            max_size=60))
    def inner(ops):
        kv = PagedKVManager(n_slots=4, max_len=24, page_size=4)
        lens = [0, 0, 0, 0]
        for slot, n in ops:
            if n == 0:
                kv.release(slot)
                lens[slot] = 0
            else:
                want = min(lens[slot] + n, 24)
                if kv.ensure(slot, want):
                    lens[slot] = want
            # invariants: tables disjoint, free + used == total
            used = [p for s in range(4) for p in kv.pages_of(s)]
            assert len(used) == len(set(used))
            assert kv.n_free_pages + len(used) == kv.n_pages
            for s in range(4):
                assert len(kv.pages_of(s)) == -(-lens[s] // 4) or lens[s] == 0
        for s in range(4):
            kv.release(s)
        assert kv.n_free_pages == kv.n_pages

    inner()
