"""Prefix cache subsystem: content keys, refcounted page sharing, LRU
eviction, engine/sim/scheduler integration, and the token-identity
guarantee (cached KV must change WHAT runs, never WHAT is generated)."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.request import Request, RequestState
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.kv_manager import PagedKVManager, SlotManager
from repro.serving.prefix_cache import PrefixCache, SimPrefixIndex, page_keys
from repro.serving.workload import (
    materialize_prompts,
    shared_prefix_workload,
)

SMOKE = get_smoke_config("qwen7b")


# ---------------------------------------------------------------------------
# SlotManager (satellites: double-free guard, heap-ordered free list)
# ---------------------------------------------------------------------------

def test_slot_manager_double_free_asserts():
    sm = SlotManager(4)
    s = sm.alloc(owner="r")
    sm.free(s)
    with pytest.raises(AssertionError, match="double free"):
        sm.free(s)


def test_slot_manager_lowest_id_first_after_out_of_order_frees():
    sm = SlotManager(4)
    assert [sm.alloc() for _ in range(4)] == [0, 1, 2, 3]
    assert sm.alloc() is None
    for s in (2, 0, 3):            # free out of order
        sm.free(s)
    assert sm.n_free == 3
    # heap keeps deterministic lowest-id-first order
    assert [sm.alloc(), sm.alloc(), sm.alloc()] == [0, 2, 3]
    assert sm.active_slots() == [0, 1, 2, 3]


# ---------------------------------------------------------------------------
# Content keys
# ---------------------------------------------------------------------------

def test_page_keys_prefix_dependent_chaining():
    ps = 4
    a = np.arange(16, dtype=np.int32)
    b = a.copy()
    b[1] = 99                       # diverge inside page 0
    ka, kb = page_keys(a, ps, 4), page_keys(b, ps, 4)
    assert len(ka) == 4
    # chaining: a page-0 divergence changes EVERY downstream key, even
    # though pages 1..3 hold identical tokens
    assert all(x != y for x, y in zip(ka, kb))
    # identical prefixes agree up to the divergence point
    c = a.copy()
    c[9] = 7                        # diverge inside page 2
    kc = page_keys(c, ps, 4)
    assert kc[0] == ka[0] and kc[1] == ka[1]
    assert kc[2] != ka[2] and kc[3] != ka[3]
    assert page_keys(a, ps, 0) == []


def test_page_keys_match_incremental_prefixes():
    """A longer prompt's keys extend a shorter prompt's keys — the
    property that lets agent sessions grow their history."""
    ps = 4
    long = np.arange(32, dtype=np.int32)
    assert page_keys(long[:16], ps, 4) == page_keys(long, ps, 8)[:4]


# ---------------------------------------------------------------------------
# PrefixCache unit
# ---------------------------------------------------------------------------

def _kv_with_cache(n_slots=2, max_len=32, ps=4, n_pages=None,
                   max_pages=None):
    kv = PagedKVManager(n_slots, max_len, ps, n_pages)
    pc = PrefixCache(kv.alloc, ps, max_pages=max_pages)
    kv.attach_prefix_cache(pc)
    return kv, pc


def test_lookup_caps_hit_at_full_pages_strictly_inside_prompt():
    kv, pc = _kv_with_cache(ps=4)
    toks = np.arange(16, dtype=np.int32)
    assert kv.ensure(0, 16)
    assert kv.publish_prefix(0, toks) == 4
    # exact-multiple prompt: at least one token must re-prefill, so the
    # hit is capped one page short
    assert pc.peek(toks) == 12
    # one token past the span: all 4 published pages hit
    assert pc.peek(np.arange(17, dtype=np.int32)) == 16
    # divergence inside page 1 kills pages >= 1
    div = toks.copy()
    div[5] = 99
    assert pc.peek(np.concatenate([div, [0]])) == 4


def test_lookup_pins_and_release_moves_to_reclaimable():
    kv, pc = _kv_with_cache(n_slots=3, ps=4)
    toks = np.arange(17, dtype=np.int32)
    assert kv.ensure(0, 17)
    kv.publish_prefix(0, toks)      # 4 pages, refs=1 (held by slot 0)
    hit = kv.lookup_prefix(1, toks)
    assert hit == 16
    shared = kv.pages_of(1)
    assert shared == kv.pages_of(0)[:4]
    assert all(pc.refs(p) == 2 for p in shared)
    assert pc.n_reclaimable == 0    # everything pinned
    assert pc.evict(4) == 0         # pinned pages never evict
    kv.release(0)
    assert all(pc.refs(p) == 1 for p in shared)
    kv.release(1)
    assert all(pc.refs(p) == 0 for p in shared)
    assert pc.n_reclaimable == 4    # resident but reclaimable
    # a new lookup revives them (no recompute needed)
    assert kv.lookup_prefix(2, toks) == 16
    assert pc.n_reclaimable == 0


def test_publish_skips_cache_owned_and_duplicate_content():
    kv, pc = _kv_with_cache(n_slots=3, ps=4)
    toks = np.arange(17, dtype=np.int32)
    assert kv.ensure(0, 17)
    assert kv.publish_prefix(0, toks) == 4
    # slot 1 hits the span, then "re-publishes" at prefill complete:
    # its hit pages are already cache-owned -> nothing new
    kv.lookup_prefix(1, toks)
    assert kv.ensure(1, 17)
    assert kv.publish_prefix(1, toks) == 0
    # slot 2 computed the same content privately (no lookup): publish
    # finds the keys taken and keeps the pages private
    assert kv.ensure(2, 17)
    assert kv.publish_prefix(2, toks) == 0
    assert not any(pc.is_cached(p) for p in kv.pages_of(2))
    kv.release(2)                   # private pages free straight back
    assert kv.alloc.n_free >= 5


def test_max_pages_budget_enforced_with_lru_eviction():
    kv, pc = _kv_with_cache(n_slots=2, max_len=32, ps=4, n_pages=16,
                            max_pages=2)
    a = np.arange(8, dtype=np.int32)
    b = np.arange(100, 108, dtype=np.int32)
    assert kv.ensure(0, 8)
    assert kv.publish_prefix(0, a) == 2
    kv.release(0)                   # both cached pages reclaimable
    # publishing b's 2 pages must evict a's LRU pages to stay <= 2
    assert kv.ensure(0, 8)
    assert kv.publish_prefix(0, b) == 2
    assert pc.n_cached == 2
    assert pc.peek(np.arange(9, dtype=np.int32)) == 0      # a evicted
    assert pc.peek(np.arange(100, 109, dtype=np.int32)) == 8
    # pinned pages can't be evicted: a third publish adds nothing
    c = np.arange(200, 208, dtype=np.int32)
    assert kv.ensure(1, 8)
    assert kv.publish_prefix(1, c) == 0    # budget full of pinned pages
    assert pc.n_cached == 2


def test_ensure_auto_evicts_reclaimable_prefix_pages():
    kv, pc = _kv_with_cache(n_slots=2, max_len=32, ps=4, n_pages=8)
    toks = np.arange(16, dtype=np.int32)
    assert kv.ensure(0, 16)
    kv.publish_prefix(0, toks)
    kv.release(0)
    assert kv.alloc.n_free == 4
    assert kv.n_available_pages == 8       # 4 free + 4 reclaimable
    # a full-pool allocation succeeds by evicting the cached pages
    assert kv.ensure(1, 32)
    assert pc.n_cached == 0
    assert kv.alloc.n_free == 0
    kv.release(1)
    assert kv.alloc.n_free == 8            # nothing leaked


# ---------------------------------------------------------------------------
# SimPrefixIndex (sim-plane mirror)
# ---------------------------------------------------------------------------

def _req(rid, g, plen, l_in):
    return Request(rid=rid, l_in=l_in, prefix_group=g, prefix_len=plen)


def test_sim_index_alignment_and_pin_lifecycle():
    ix = SimPrefixIndex(page_size=8)
    r0 = _req(0, 5, 20, 26)
    assert ix.peek(r0) == 0
    assert ix.acquire(r0) == 0
    ix.publish(r0)                  # cached span = aligned(20) = 16
    r1 = _req(1, 5, 20, 26)
    assert ix.peek(r1) == 16
    # exact-span prompt: >= 1 token still prefills
    assert ix.peek(_req(2, 5, 16, 16)) == 8
    assert ix.peek(_req(3, 6, 20, 26)) == 0    # other group: miss
    ix.release(0)
    ix.release(999)                 # unknown rid: no-op


def test_sim_index_capacity_eviction_respects_pins():
    ix = SimPrefixIndex(page_size=8, capacity_pages=4)
    a, b = _req(0, 1, 32, 40), _req(1, 2, 32, 40)
    ix.acquire(a)
    ix.publish(a)                   # group 1: 4 pages, still pinned
    ix.acquire(b)
    ix.publish(b)                   # group 2: over capacity
    # group 1 is pinned (a in flight) so group 2 evicts instead? No:
    # group 2 is also pinned -> both stay (capacity is best-effort
    # against pins), then releasing a lets the next publish evict it
    ix.release(0)
    ix.release(1)
    c = _req(2, 3, 32, 40)
    ix.acquire(c)
    ix.publish(c)
    assert ix.peek(_req(3, 3, 32, 40)) == 32
    total = sum(ix._cached.values()) // ix.page_size
    assert total <= 4


def test_sim_index_grows_monotonically_for_agent_sessions():
    ix = SimPrefixIndex(page_size=8)
    ix.publish(_req(0, 9, 16, 20))
    ix.publish(_req(1, 9, 32, 36))
    ix.publish(_req(2, 9, 8, 12))   # shorter turn must not shrink it
    assert ix.peek(_req(3, 9, 32, 40)) == 32


# ---------------------------------------------------------------------------
# Workload generator
# ---------------------------------------------------------------------------

def test_shared_prefix_workload_chat_shape():
    reqs = shared_prefix_workload(task="gsm8k", n=200, qps=32, seed=0,
                                  n_groups=4, shape="chat",
                                  prefix_len=64, suffix_len=16)
    assert len(reqs) == 200
    assert all(r.prefix_len == 64 for r in reqs)
    assert all(64 < r.l_in <= 80 for r in reqs)
    counts = np.bincount([r.prefix_group for r in reqs], minlength=4)
    assert counts[0] == max(counts)     # Zipf: group 0 hottest
    assert counts.sum() == 200
    # deterministic under seed
    again = shared_prefix_workload(task="gsm8k", n=200, qps=32, seed=0,
                                   n_groups=4, shape="chat",
                                   prefix_len=64, suffix_len=16)
    assert [(r.prefix_group, r.l_in, r.arrival) for r in reqs] == \
           [(r.prefix_group, r.l_in, r.arrival) for r in again]


def test_shared_prefix_workload_agent_shape_grows_history():
    reqs = shared_prefix_workload(task="gsm8k", n=60, qps=8, seed=1,
                                  n_groups=2, shape="agent",
                                  prefix_len=16, turn_growth=8,
                                  max_turns=4, suffix_len=4)
    cap = 16 + 3 * 8
    assert all(16 <= r.prefix_len <= cap for r in reqs)
    assert any(r.prefix_len == cap for r in reqs)   # sessions saturate


def test_materialize_group_mates_share_prefix_bytes():
    reqs = shared_prefix_workload(task="gsm8k", n=24, qps=8, seed=2,
                                  n_groups=2, shape="chat",
                                  prefix_len=300, suffix_len=8)
    materialize_prompts(reqs, vocab_size=1000, seed=7)
    by_group = {}
    for r in reqs:
        by_group.setdefault(r.prefix_group, []).append(r)
    for g, rs in by_group.items():
        base = rs[0].prompt[:300]
        for r in rs[1:]:
            # 300 > one 256-token stream chunk: crosses the chunk seam
            np.testing.assert_array_equal(r.prompt[:300], base)
    gs = sorted(by_group)
    if len(gs) == 2:
        assert not np.array_equal(by_group[gs[0]][0].prompt[:300],
                                  by_group[gs[1]][0].prompt[:300])
    # materialization is order-independent for the shared span: a
    # singleton re-materialization (the online-submit path) matches
    solo = Request(rid=500, l_in=308, prefix_group=reqs[0].prefix_group,
                   prefix_len=300)
    materialize_prompts([solo], vocab_size=1000, seed=7)
    np.testing.assert_array_equal(
        solo.prompt[:300], by_group[reqs[0].prefix_group][0].prompt[:300]
    )


# ---------------------------------------------------------------------------
# Engine integration: token identity + telemetry
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def smoke_model():
    import jax

    from repro.models import build_model

    model = build_model(SMOKE)
    return model, model.init(jax.random.key(0))


def _shared_prompts(n, prefix_len, seed=42):
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, SMOKE.vocab_size,
                          size=prefix_len).astype(np.int32)
    return [np.concatenate([
        prefix, rng.integers(0, SMOKE.vocab_size, size=2 + k)
        .astype(np.int32)]) for k in range(n)]


@pytest.mark.parametrize("ps,cs", [(8, 16), (4, 8), (8, 8), (4, 16)])
def test_engine_token_identity_cache_on_off(smoke_model, ps, cs):
    """Acceptance: identical generations with the cache on and off,
    across 2 page sizes x 2 chunk sizes — and the cached run must
    actually skip prefill work."""
    from repro.serving.engine import EngineConfig, InferenceEngine

    model, params = smoke_model
    prompts = _shared_prompts(4, prefix_len=17)
    out, prefill_tok, hits = {}, {}, 0
    for on in (False, True):
        eng = InferenceEngine(model, params, EngineConfig(
            n_slots=4, max_len=48, prefill_batch=2, page_size=ps,
            chunk_size=cs, prefix_cache=on))
        for i, p in enumerate(prompts):
            # sequential: each prefill publishes before the next looks up
            eng.submit(Request.from_prompt(i, p.copy(), max_new=4))
            eng.run_until_done()
        out[on] = sorted((r.rid, tuple(r.generated))
                         for r in eng.finished)
        prefill_tok[on] = eng.n_prefill_tokens
        if on:
            hits = eng.prefix.stats()["n_hit_tokens"]
    assert out[True] == out[False]
    assert hits > 0
    assert prefill_tok[True] < prefill_tok[False]
    assert prefill_tok[False] - prefill_tok[True] == hits


def test_engine_preempted_request_rehits_own_pages(smoke_model):
    """A request that published, got preempted, and re-admits may hit
    its own published pages — folded prompts share the prefix keys."""
    from repro.serving.engine import EngineConfig, InferenceEngine

    model, params = smoke_model
    eng = InferenceEngine(model, params, EngineConfig(
        n_slots=2, max_len=32, prefill_batch=1, page_size=4,
        chunk_size=8, prefix_cache=True))
    p = np.arange(1, 18, dtype=np.int32)
    r = Request.from_prompt(0, p, max_new=3)
    eng.submit(r)
    eng.run_until_done()
    # a second identical prompt: full-page span of the first is cached
    r2 = Request.from_prompt(1, p.copy(), max_new=3)
    eng.submit(r2)
    eng.run_until_done()
    assert r2.prefix_hit_tokens == 16
    assert r2.generated == r.generated


def test_engine_prefix_cache_rejects_slot_plane_and_mamba(smoke_model):
    from repro.serving.engine import EngineConfig, InferenceEngine

    model, params = smoke_model
    with pytest.raises(ValueError, match="paged"):
        InferenceEngine(model, params, EngineConfig(
            n_slots=2, max_len=32, paged=False, prefix_cache=True))
    mcfg = get_smoke_config("mamba2-2.7b")
    from repro.models import build_model

    mmodel = build_model(mcfg)
    assert not mmodel.supports_prefix_cache
    import jax

    mparams = mmodel.init(jax.random.key(0))
    with pytest.raises(ValueError, match="prefix"):
        InferenceEngine(mmodel, mparams, EngineConfig(
            n_slots=2, max_len=32, prefix_cache=True))


# ---------------------------------------------------------------------------
# Cluster integration (both planes) incl. P/D migration of a hit request
# ---------------------------------------------------------------------------

def _pd_workload():
    # low qps: arrivals are spaced far beyond service time, so every
    # later group-mate sees the published prefix (deterministic hits)
    return shared_prefix_workload(task="gsm8k", n=6, qps=2.0, seed=3,
                                  n_groups=2, shape="chat",
                                  prefix_len=16, suffix_len=4, l_out=3)


def test_engine_pd_cluster_prefix_cache_token_identity():
    """Acceptance: P/D migration of cache-hit requests (mixed
    shared+private page tables exported/imported) preserves tokens."""
    from repro.serving.engine import EngineConfig

    def cfg(on):
        return ClusterConfig(model=SMOKE, backend="engine",
                             policy="hyperflexis", mode="pd",
                             n_prefill=1, n_decode=1, seed=0,
                             engine=EngineConfig.smoke(),
                             prefix_cache=on)

    reqs_on, reqs_off = _pd_workload(), _pd_workload()
    res_on = Cluster(cfg(True)).run(reqs_on)
    Cluster(cfg(False)).run(reqs_off)
    assert [r.generated for r in reqs_on] == \
           [r.generated for r in reqs_off]
    assert all(r.state == RequestState.FINISHED for r in reqs_on)
    assert res_on.metrics.prefix_hit_tokens > 0
    # at least one migrated request rode on cached pages
    assert any(r.prefix_hit_tokens > 0
               and r.decode_worker is not None
               and r.decode_worker != r.prefill_worker
               for r in reqs_on)
    assert res_on.n_prefill_tokens > 0
    assert res_on.prefix_stats.get("n_hit_tokens", 0) > 0


def test_sim_pd_cluster_prefix_cache_hits_across_migration():
    cfg = ClusterConfig(model=get_config("qwen7b"), policy="hyperflexis",
                        mode="pd", n_prefill=1, n_decode=1, seed=0,
                        prefix_cache=True)
    reqs = shared_prefix_workload(task="gsm8k", n=24, qps=8.0, seed=5,
                                  n_groups=3, shape="chat",
                                  prefix_len=256, suffix_len=32)
    res = Cluster(cfg).run(reqs)
    m = res.metrics
    assert m.n_finished == m.n_total == len(reqs)
    assert m.prefix_hit_tokens > 0
    # pins released on whichever worker finished (post-migration)
    assert Cluster(cfg).prefix_index is not None
    assert res.prefix_stats["n_hit_tokens"] == m.prefix_hit_tokens


def test_sim_cluster_prefix_cache_improves_ttft_under_shared_load():
    def run(on):
        reqs = shared_prefix_workload(task="gsm8k", n=48, qps=48.0,
                                      seed=5, n_groups=4, shape="chat",
                                      prefix_len=512, suffix_len=64)
        cfg = ClusterConfig(model=get_config("qwen7b"), n_workers=1,
                            policy="hyperflexis", seed=0,
                            chunk_tokens=256, prefix_cache=on)
        return Cluster(cfg).run(reqs).metrics

    off, on = run(False), run(True)
    assert on.prefix_hit_rate > 0.3 and off.prefix_hit_rate == 0.0
    assert on.mean_ttft < off.mean_ttft
    assert on.attainment >= off.attainment


def test_metrics_schema_has_prefix_fields_on_both_planes():
    from repro.serving.engine import EngineConfig

    sim = Cluster(ClusterConfig(
        model=get_config("qwen7b"), n_workers=1, policy="hyperflexis",
        seed=0, prefix_cache=True)).run(
            shared_prefix_workload(task="gsm8k", n=6, qps=8, seed=1,
                                   n_groups=2, prefix_len=64,
                                   suffix_len=8))
    eng = Cluster(ClusterConfig(
        model=SMOKE, backend="engine", n_workers=1, policy="hyperflexis",
        seed=0, engine=EngineConfig.smoke(), prefix_cache=True)).run(
            _pd_workload())
    a, b = sim.metrics.row(), eng.metrics.row()
    assert set(a) == set(b)
    assert "prefix_hit_tokens" in a and "prefix_hit_rate" in a
    assert dataclasses.asdict(sim.metrics).keys() == \
           dataclasses.asdict(eng.metrics).keys()
    assert eng.metrics.prefix_hit_tokens > 0


# ---------------------------------------------------------------------------
# Admission regression: a warm cache admits what a cold one rejects
# ---------------------------------------------------------------------------

def test_sim_admission_full_hit_admitted_where_cold_rejects():
    P = 4096
    cfg = ClusterConfig(model=get_config("qwen7b"), n_workers=1,
                        policy="hyperflexis", seed=0, prefix_cache=True)
    cluster = Cluster(cfg)
    warmup = Request(rid=0, task="gsm8k", arrival=0.0, l_in=P + 8,
                     l_out=1, ttft_slo=100.0, tpot_slo=10.0,
                     prefix_group=0, prefix_len=P)
    cluster.run([warmup])
    now = cluster.now
    e_cold = cluster.fitted.prefill_time([P + 8])
    e_warm = cluster.fitted.prefill_time([8])   # suffix after 4096 hit
    assert e_warm / 0.9 < e_cold                # separation precondition
    slo = 0.5 * (e_warm / 0.9 + e_cold)
    warm = Request(rid=1, task="gsm8k", arrival=now, l_in=P + 8,
                   l_out=1, ttft_slo=slo, tpot_slo=10.0,
                   prefix_group=0, prefix_len=P)
    cold = Request(rid=2, task="gsm8k", arrival=now, l_in=P + 8,
                   l_out=1, ttft_slo=slo, tpot_slo=10.0)
    v_warm = cluster.policy.admission_verdict(warm, now)
    v_cold = cluster.policy.admission_verdict(cold, now)
    assert not v_cold.admit and "theta" in v_cold.reason
    assert v_warm.admit and v_warm.p > v_cold.p


def test_engine_admission_full_hit_admitted_where_cold_rejects():
    from repro.serving.engine import EngineConfig

    cfg = ClusterConfig(model=SMOKE, backend="engine", n_workers=1,
                        policy="hyperflexis", seed=0,
                        engine=EngineConfig.smoke(), prefix_cache=True)
    cluster = Cluster(cfg)
    wl = shared_prefix_workload(task="gsm8k", n=5, qps=2.0, seed=2,
                                n_groups=1, shape="chat",
                                prefix_len=32, suffix_len=4, l_out=2)
    cluster.run(wl)
    assert cluster.fitted.fit(min_samples=4)
    now = cluster.now
    warm = Request(rid=900, task="gsm8k", arrival=now, l_in=33, l_out=2,
                   prefix_group=0, prefix_len=32)
    materialize_prompts([warm], SMOKE.vocab_size, seed=cfg.seed)
    cold = Request(rid=901, task="gsm8k", arrival=now, l_in=33, l_out=2)
    materialize_prompts([cold], SMOKE.vocab_size, seed=123)
    # the warm request's full 32-token prefix is resident
    assert cluster.workers[0].prefix_peek(warm) == 32
    assert cluster.workers[0].prefix_peek(cold) == 0
    e_cold = cluster.fitted.prefill_time([33])
    e_warm = cluster.fitted.prefill_time([1])
    assert e_warm / 0.9 < e_cold
    slo = 0.5 * (e_warm / 0.9 + e_cold)
    warm.ttft_slo = cold.ttft_slo = slo
    v_warm = cluster.policy.admission_verdict(warm, now)
    v_cold = cluster.policy.admission_verdict(cold, now)
    assert not v_cold.admit
    assert v_warm.admit and v_warm.p > v_cold.p


# ---------------------------------------------------------------------------
# Property test: refcounted sharing never leaks or frees pinned pages
# ---------------------------------------------------------------------------

def test_refcounted_prefix_sharing_property():
    """Random interleavings of lookup/ensure (start), publish, retire,
    and evict, with the invariants in ``tests/_prefix_ops`` asserted
    after every op."""
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    from _prefix_ops import MAX_LEN, N_SLOTS, run_prefix_ops

    @hyp.settings(max_examples=60, deadline=None)
    @hyp.given(ops=st.lists(
        st.tuples(st.sampled_from(["start", "publish", "retire",
                                   "evict"]),
                  st.integers(0, N_SLOTS - 1),   # slot
                  st.integers(0, 2),             # prefix group
                  st.integers(1, MAX_LEN)),      # prompt length
        max_size=80))
    def inner(ops):
        run_prefix_ops(ops)

    inner()


def test_refcounted_prefix_sharing_seeded_fuzz():
    """Same invariants without the hypothesis dependency: 40 seeded
    random op sequences (deterministic, so failures reproduce)."""
    from _prefix_ops import MAX_LEN, N_SLOTS, run_prefix_ops

    kinds = ["start", "publish", "retire", "evict"]
    for trial in range(40):
        rng = np.random.default_rng(trial)
        ops = [(kinds[rng.integers(len(kinds))],
                int(rng.integers(N_SLOTS)),
                int(rng.integers(3)),
                int(rng.integers(1, MAX_LEN + 1)))
               for _ in range(int(rng.integers(0, 81)))]
        run_prefix_ops(ops)
