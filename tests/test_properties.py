"""Hypothesis property tests on system invariants."""

import numpy as np
import pytest

pytest.importorskip("hypothesis")

import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.latency_model import LatencyCoeffs, LatencyModel
from repro.core.queues import RequestPriorityQueue
from repro.core.request import Request
from repro.core.slo_mapper import PriorityBand, PrioritySLOMapper
from repro.core.token_budget import maturity_interval, ntoken_limit
from repro.distributed.compression import (
    compress_residual,
    dequantize,
    quantize,
)

MODEL = LatencyModel(LatencyCoeffs(0.003, 1.5e-4, 1e-9, 0.02, 8e-7, 1e-4))


@given(
    ttft=st.floats(0.05, 50.0),
    tpot=st.floats(0.05, 5.0),
    e_d=st.floats(0.0, 5.0),
)
@settings(max_examples=200, deadline=None)
def test_ntoken_nonnegative_and_monotone(ttft, tpot, e_d):
    n = ntoken_limit(ttft, tpot, e_d, MODEL)
    assert n >= 0
    # loosening TTFT can never shrink the budget
    n2 = ntoken_limit(ttft * 2, tpot, e_d, MODEL)
    assert n2 >= n


@given(
    e_p=st.floats(0.0, 10.0),
    e_d=st.floats(1e-4, 1.0),
    slack=st.floats(1e-3, 5.0),
)
@settings(max_examples=200, deadline=None)
def test_maturity_interval_at_least_prefill(e_p, e_d, slack):
    out = maturity_interval(e_p, e_d, e_d + slack)
    assert out >= e_p - 1e-12


@given(st.lists(st.tuples(st.floats(0.01, 10.0), st.floats(0.0, 100.0)),
                min_size=1, max_size=60))
@settings(max_examples=100, deadline=None)
def test_queue_scan_sorted(items):
    q = RequestPriorityQueue()
    for i, (tpot, arr) in enumerate(items):
        q.add(Request(rid=i, task="t", arrival=arr, l_in=1, l_out=1,
                      ttft_slo=1.0, tpot_slo=tpot))
    seen = [(r.tpot_slo, r.arrival) for r in q.scan()]
    assert seen == sorted(seen)


@given(
    obs=st.lists(
        st.tuples(st.integers(0, 3), st.floats(0.01, 10.0),
                  st.floats(0.001, 2.0), st.floats(0.0, 3.0)),
        min_size=0, max_size=120,
    ),
    p=st.integers(0, 3),
    contended=st.booleans(),
)
@settings(max_examples=100, deadline=None)
def test_slo_mapper_always_within_band(obs, p, contended):
    bands = [PriorityBand(0.1 * (i + 1), 1.0 * (i + 1),
                          0.05 * (i + 1), 0.5 * (i + 1))
             for i in range(4)]
    m = PrioritySLOMapper(bands, window=50)
    for (pi, ttft, tpot, qt) in obs:
        m.observe(pi, ttft, tpot, qt)
    ttft, tpot = m.assign(p, higher_priority_pending=contended)
    b = bands[p]
    assert b.min_ttft - 1e-9 <= ttft <= b.max_ttft + 1e-9
    assert b.min_tpot - 1e-9 <= tpot <= b.max_tpot + 1e-9


@given(st.lists(st.integers(1, 4000), min_size=0, max_size=50))
@settings(max_examples=100, deadline=None)
def test_latency_model_additive_monotone(lens):
    t = MODEL.prefill_time(lens)
    assert t >= 0
    t2 = MODEL.prefill_time(lens + [100])
    assert t2 > t or not lens and t == 0 and t2 > 0


@given(st.lists(st.floats(-100.0, 100.0), min_size=1, max_size=256))
@settings(max_examples=150, deadline=None)
def test_quantize_roundtrip_error_bound(vals):
    import jax.numpy as jnp
    g = jnp.asarray(np.array(vals, np.float32))
    q, s = quantize(g)
    err = np.abs(np.asarray(dequantize(q, s) - g))
    assert err.max() <= float(s) * 0.5 + 1e-6  # half-step rounding


@given(st.lists(st.floats(-10.0, 10.0), min_size=1, max_size=128))
@settings(max_examples=100, deadline=None)
def test_error_feedback_residual_identity(vals):
    import jax.numpy as jnp
    g = jnp.asarray(np.array(vals, np.float32))
    q, s, r = compress_residual(g)
    recon = dequantize(q, s) + r
    np.testing.assert_allclose(np.asarray(recon), np.asarray(g),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Speculative-decode rollback (truncate) invariants on the paged KV
# manager — arbitrary accept/reject sequences leave the page table,
# free list, and prefix-cache refcounts consistent
# ---------------------------------------------------------------------------

_KV_OPS = st.lists(
    st.tuples(st.integers(0, 2),                      # slot
              st.sampled_from(["grow", "trunc", "release"]),
              st.integers(0, 64)),                    # token count
    min_size=1, max_size=50,
)


@given(ops=_KV_OPS)
@settings(max_examples=80, deadline=None)
def test_truncate_property_no_leak_no_double_free(ops):
    from repro.serving.kv_manager import PagedKVManager

    # pool too small for all slots at max_len: ensure-failures and the
    # untouched-on-failure contract get exercised too
    kv = PagedKVManager(n_slots=3, max_len=64, page_size=4, n_pages=24)
    pos = [0, 0, 0]
    for slot, op, n in ops:
        if op == "grow":
            if kv.ensure(slot, n):
                pos[slot] = max(pos[slot], n)
        elif op == "trunc":
            m = min(n, pos[slot])       # engine never truncates upward
            kv.truncate(slot, m)
            pos[slot] = m
        else:
            kv.release(slot)
            pos[slot] = 0
        held_total = 0
        for s in range(3):
            held = kv.n_pages_held(s)
            assert held == -(-pos[s] // 4)
            assert all(int(p) >= 0 for p in kv.table[s][:held])
            assert all(int(p) == -1 for p in kv.table[s][held:])
            held_total += held
        # conservation: every page is free xor held by exactly one slot
        assert kv.alloc.n_used == held_total
        assert kv.n_free_pages == kv.n_pages - held_total
        live = [int(p) for s in range(3)
                for p in kv.table[s][: kv.n_pages_held(s)]]
        assert len(live) == len(set(live))
    for s in range(3):
        kv.release(s)
    assert kv.n_free_pages == kv.n_pages


@given(steps=st.lists(st.tuples(st.integers(1, 6), st.integers(0, 6)),
                      min_size=1, max_size=25))
@settings(max_examples=60, deadline=None)
def test_truncate_property_prefix_refcounts(steps):
    """A slot speculating on top of a shared cached prefix: rollback
    must deref shared pages through the cache (never hand a pinned
    page to the allocator) and keep every refcount exact."""
    from repro.serving.kv_manager import PagedKVManager
    from repro.serving.prefix_cache import PrefixCache

    kv = PagedKVManager(n_slots=2, max_len=256, page_size=4)
    pc = PrefixCache(kv.alloc, 4)
    kv.attach_prefix_cache(pc)

    toks = list(range(13))
    assert kv.ensure(0, len(toks))
    assert kv.publish_prefix(0, toks) == 3     # 3 full pages cached

    hit = kv.lookup_prefix(1, toks + [50, 51, 52])
    assert hit == 12
    shared = kv.pages_of(1)
    assert len(shared) == 3
    pos = hit + 1                               # first private token
    assert kv.ensure(1, pos)

    for k, acc in steps:
        acc = min(acc, k)
        if pos + k + 1 > 256:
            break
        # speculate: grow to cover the proposal, then roll back to the
        # accepted prefix — an arbitrary accept/reject outcome
        assert kv.ensure(1, pos + k + 1)
        pos += acc + 1
        kv.truncate(1, pos)
        assert kv.n_pages_held(1) == -(-pos // 4)
        # shared span never truncated (engine floor: resident pos)
        assert kv.pages_of(1)[:3] == shared
        for p in shared:
            assert pc.refs(p) == 2              # publisher + this slot
        # conservation incl. the shared pages counted once
        held = kv.n_pages_held(0) + kv.n_pages_held(1) - len(shared)
        assert kv.alloc.n_used == held
        assert pc.n_reclaimable == 0            # everything pinned

    kv.release(1)
    for p in shared:
        assert pc.refs(p) == 1                  # publisher still holds
    kv.release(0)
    assert pc.n_reclaimable == 3                # unpinned, resident
    assert pc.evict(3) == 3
    assert kv.n_free_pages == kv.n_pages
