"""Per-arch smoke tests (required by the assignment): a reduced config
of the same family runs one forward + one train step on CPU with
correct output shapes and no NaNs."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, PAPER_MODELS, get_smoke_config
from repro.models import build_model
from repro.training.optimizer import AdamWConfig, adamw_init, adamw_update

ALL = sorted(ASSIGNED_ARCHS) + sorted(PAPER_MODELS)


def _batch(cfg, key, b=2, s=24):
    if cfg.frontend == "frames":
        return {
            "frames": jax.random.normal(key, (b, s, cfg.d_model)),
            "labels": jax.random.randint(key, (b, s), 0, cfg.vocab_size),
            "mask": jnp.ones((b, s), jnp.float32),
        }
    toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    return {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}


@pytest.mark.parametrize("name", ALL)
def test_forward_shapes_no_nans(name):
    cfg = get_smoke_config(name)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits = model.forward(params, batch)
    b, s = (batch.get("tokens", batch.get("frames"))).shape[:2]
    assert logits.shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", ALL)
def test_one_train_step(name):
    cfg = get_smoke_config(name)
    model = build_model(cfg, remat=True)
    params = model.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert bool(jnp.isfinite(loss))
    opt = adamw_init(params)
    new_params, opt, stats = adamw_update(AdamWConfig(), grads, opt, params)
    assert bool(jnp.isfinite(stats["grad_norm"]))
    # params actually moved
    delta = sum(
        float(jnp.sum(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(new_params),
                        jax.tree.leaves(params))
    )
    assert delta > 0


@pytest.mark.parametrize("name", ["qwen2.5-14b", "mamba2-2.7b",
                                  "zamba2-7b", "gemma3-4b",
                                  "olmoe-1b-7b"])
def test_unroll_matches_scan(name):
    cfg = get_smoke_config(name)
    m_scan = build_model(cfg)
    m_unroll = build_model(cfg, unroll=True)
    params = m_scan.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    a = m_scan.forward(params, batch)
    b = m_unroll.forward(params, batch)
    assert float(jnp.max(jnp.abs(a - b))) < 1e-5


def test_vocab_pad_does_not_change_loss_labels():
    cfg = get_smoke_config("qwen7b")
    m0 = build_model(cfg)
    m1 = build_model(cfg, vocab_pad=16)
    p1 = m1.init(jax.random.key(0))
    batch = _batch(cfg, jax.random.key(1))
    logits = m1.forward(p1, batch)
    assert logits.shape[-1] == cfg.vocab_size + 16
    assert bool(jnp.isfinite(m1.loss(p1, batch)))
