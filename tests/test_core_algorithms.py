"""Unit tests for the paper's core algorithms (Eq. 1-6, Alg. 1-3)."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.latency_model import (
    AnalyticLatencyModel,
    FittedLatencyModel,
    LatencyCoeffs,
    LatencyModel,
)
from repro.core.monitor import Monitor
from repro.core.request import Request, TASKS
from repro.core.slo_mapper import (
    PriorityBand,
    PrioritySLOMapper,
    bands_from_tasks,
)
from repro.core.tlmanager import TLManager, kv_bytes
from repro.core.token_budget import maturity_interval, ntoken_limit


# -- latency model (Eq. 1 / Eq. 2, Appendix A) ------------------------------

def test_fit_recovers_coefficients(rng):
    truth = LatencyModel(LatencyCoeffs(
        a=0.004, b=1.5e-4, c=2e-9, a_d=0.02, b_d=8e-7, c_d=1e-4,
    ))
    fitted = FittedLatencyModel.from_profile(truth, rng, noise=0.01)
    assert fitted.fitted
    for lens in ([64], [512] * 8, [2020] * 32, [100, 900, 40]):
        t_true = truth.prefill_time(lens)
        t_fit = fitted.prefill_time(lens)
        assert abs(t_fit - t_true) / t_true < 0.15, (lens, t_true, t_fit)
        d_true = truth.decode_step_time(lens)
        d_fit = fitted.decode_step_time(lens)
        assert abs(d_fit - d_true) / d_true < 0.15


def test_analytic_model_magnitudes():
    m = AnalyticLatencyModel(get_config("qwen7b"))
    # 1k-token prefill on one chip: O(100ms); decode step: O(10ms)
    assert 0.03 < m.prefill_time([1024]) < 1.0
    assert 0.005 < m.decode_step_time([128] * 8) < 0.1


def test_ssm_has_no_kv_growth():
    m = AnalyticLatencyModel(get_config("mamba2-2.7b"))
    t1 = m.decode_step_time([100] * 4)
    t2 = m.decode_step_time([10_000] * 4)
    assert abs(t1 - t2) < 1e-3  # b' ~ 0 for attention-free archs


# -- token budget (Eq. 5) -----------------------------------------------------

def test_ntoken_basic():
    model = LatencyModel(LatencyCoeffs(0.003, 1.5e-4, 0, 0.02, 0, 0))
    n = ntoken_limit(0.7, 0.5, 0.05, model)
    # (0.7*0.5 - 0.7*0.05 - 0.003*0.5) / (1.5e-4*0.5) = 4180
    assert 4000 < n < 4400


def test_ntoken_zero_when_no_decode_slack():
    model = LatencyModel(LatencyCoeffs(0.003, 1.5e-4, 0, 0.02, 0, 0))
    assert ntoken_limit(0.7, 0.05, 0.06, model) == 0


def test_ntoken_monotone_in_ttft():
    model = LatencyModel(LatencyCoeffs(0.003, 1.5e-4, 0, 0.02, 0, 0))
    ns = [ntoken_limit(t, 0.5, 0.05, model) for t in (0.3, 0.7, 2.0, 20.0)]
    assert ns == sorted(ns)


def test_maturity_interval_amortization():
    # relax = 0.5 - 0.1 = 0.4; interval = 1 + (1/0.4)*0.1 = 1.25
    assert abs(maturity_interval(1.0, 0.1, 0.5) - 1.25) < 1e-9


# -- priority SLO mapping (Alg. 2 / Eq. 6) -------------------------------------

def _mapper(n=4, w=100):
    bands = [PriorityBand(0.1 * (i + 1), 1.0 * (i + 1),
                          0.05 * (i + 1), 0.5 * (i + 1))
             for i in range(n)]
    return PrioritySLOMapper(bands, window=w)


def test_mapper_defaults_before_history():
    m = _mapper()
    ttft, tpot = m.assign(0)
    b = m.bands[0]
    assert b.min_ttft <= ttft <= b.max_ttft
    assert b.min_tpot <= tpot <= b.max_tpot


def test_mapper_priority_ordering(rng):
    m = _mapper()
    for _ in range(200):
        p = int(rng.integers(0, 4))
        ttft = float(rng.uniform(0.05, 4.0))
        m.observe(p, ttft, ttft / 3, queue_time=0.0)
    slos = [m.assign(p)[0] for p in range(4)]
    # higher priority (lower p) must land on a lower-or-equal quantile,
    # after clamping bands this is monotone
    assert slos == sorted(slos)


def test_mapper_contention_rule():
    m = _mapper()
    ttft, tpot = m.assign(3, higher_priority_pending=True)
    assert ttft == m.bands[3].max_ttft
    assert tpot == m.bands[3].max_tpot


def test_mapper_queue_correction_and_clamp(rng):
    m = _mapper()
    for _ in range(50):
        m.observe(1, 0.5, 0.2, queue_time=0.0)
    base_ttft, _ = m.assign(1)
    # a big queue-time spike on the reference entry lowers derived ttft,
    # but never below the band floor
    m.observe(1, 0.5, 0.2, queue_time=5.0)
    ttft, _ = m.assign(1)
    assert ttft >= m.bands[1].min_ttft


def test_bands_from_tasks():
    bands = bands_from_tasks([TASKS[t] for t in
                              ("medical_qa", "tldr_content_gen")])
    assert bands[0].min_ttft == pytest.approx(0.7 * 0.75)
    assert bands[0].max_ttft == pytest.approx(0.7 * 1.25)


# -- TLManager -------------------------------------------------------------------

def test_kv_transfer_time_scales_with_tokens():
    tl = TLManager()
    cfg = get_config("qwen7b")
    t1 = tl.kv_transfer_time(cfg, 100, 0, 1)
    t2 = tl.kv_transfer_time(cfg, 1000, 0, 1)
    assert t2 > t1 * 5


def test_weight_strategies_ordering():
    tl = TLManager()
    cfg = get_config("qwen32b")
    d2d = tl.weight_load_time(cfg, "d2d", tp=2)
    cpu = tl.weight_load_time(cfg, "cpu", tp=2)
    disk = tl.weight_load_time(cfg, "disk", tp=2)
    assert d2d < cpu < disk  # Table 2 ordering
    assert disk / d2d > 5    # order-of-magnitude Fast Scaling win


def test_lazy_link_pays_setup_once():
    tl = TLManager(proactive_links=False)
    cfg = get_config("qwen7b")
    t1 = tl.kv_transfer_time(cfg, 500, 3, 4)
    t2 = tl.kv_transfer_time(cfg, 500, 3, 4)
    assert t1 > t2  # first transfer paid link setup


def test_ssm_kv_bytes_constant_in_tokens():
    cfg = get_config("mamba2-2.7b")
    assert kv_bytes(cfg, 100) == kv_bytes(cfg, 100_000)
