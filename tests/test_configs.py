"""Architecture configs: published sizes, shape suites, smoke reduction."""

import pytest

from repro.configs import (
    ASSIGNED_ARCHS,
    REGISTRY,
    SHAPES,
    get_config,
    get_smoke_config,
    mfu_flops,
)

# parameter-count targets from the published configs (±12% tolerance:
# we simplify zamba2's LoRA'd shared blocks, swiglu-ify hubert's FFN)
PUBLISHED = {
    "mamba2-2.7b": 2.7e9,
    "olmoe-1b-7b": 6.9e9,
    "phi3.5-moe-42b-a6.6b": 41.9e9,
    "chameleon-34b": 34e9,
    "gemma3-4b": 3.9e9,
    "command-r-plus-104b": 104e9,
    "qwen2.5-14b": 14.8e9,
    "internlm2-20b": 19.9e9,
    "hubert-xlarge": 1.0e9,
    "zamba2-7b": 7.4e9,
    "qwen7b": 7.7e9,
    "qwen32b": 32.5e9,
    "llama70b": 70.6e9,
}


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_param_count_matches_published(name):
    n = get_config(name).param_count()
    target = PUBLISHED[name]
    tol = 0.30 if name in ("zamba2-7b", "hubert-xlarge") else 0.12
    assert abs(n - target) / target < tol, (name, n, target)


def test_ten_assigned_archs():
    assert len(ASSIGNED_ARCHS) == 10


def test_shape_cells_total_40():
    # 4 shapes x 10 archs = 40 assigned cells; runnable + documented skips
    total = 0
    for cfg in ASSIGNED_ARCHS.values():
        total += len(cfg.shapes()) + len(cfg.skipped_shapes())
    assert total == 40


@pytest.mark.parametrize("name", sorted(ASSIGNED_ARCHS))
def test_shape_skips_are_principled(name):
    cfg = get_config(name)
    names = {s.name for s in cfg.shapes()}
    skips = dict(cfg.skipped_shapes())
    if cfg.is_encoder_only:
        assert "decode_32k" in skips and "long_500k" in skips
    elif not cfg.sub_quadratic:
        assert "long_500k" in skips
    else:
        assert "long_500k" in names
    assert "train_4k" in names and "prefill_32k" in names


@pytest.mark.parametrize("name", sorted(REGISTRY))
def test_smoke_config_small(name):
    sm = get_smoke_config(name)
    assert sm.param_count() < 5e7
    assert sm.family == get_config(name).family
    # same layer-pattern *structure*
    kinds = [k for k, _ in sm.layer_pattern()]
    full_kinds = [k for k, _ in get_config(name).layer_pattern()]
    assert set(kinds) == set(full_kinds)


def test_mfu_flops_positive():
    for cfg in ASSIGNED_ARCHS.values():
        for shape in cfg.shapes():
            assert mfu_flops(cfg, shape) > 0


def test_moe_active_params():
    cfg = get_config("olmoe-1b-7b")
    assert cfg.active_param_count() < 0.3 * cfg.param_count()
