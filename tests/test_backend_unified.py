"""Unified control plane (PR 2): one Request lifecycle, a Backend
protocol over both planes, and an engine-backed Cluster driven by the
same Dispatcher/Scaler/PrioritySLOMapper as the simulator."""

import dataclasses

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.dispatcher import Dispatcher
from repro.core.latency_model import AnalyticLatencyModel
from repro.core.request import (
    FOUR_TASK_SET,
    TASKS,
    TWO_TASK_SET,
    Request,
    RequestState,
)
from repro.core.scaler import Scaler, ScalerConfig
from repro.core.slo_mapper import PrioritySLOMapper, bands_from_tasks
from repro.serving.backend import Backend, EngineWorker, WorkerBase
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.worker import SimWorker
from repro.serving.workload import (
    materialize_prompts,
    poisson_workload,
    ramp_workload,
)

SMOKE = get_smoke_config("qwen7b")


def _engine_cluster_cfg(**kw):
    from repro.serving.engine import EngineConfig

    kw.setdefault("engine", EngineConfig(n_slots=4, max_len=48,
                                         prefill_batch=2))
    return ClusterConfig(model=SMOKE, backend="engine", n_workers=1,
                         policy="hyperflexis", seed=0, **kw)


def _small_multi_slo_workload(n=12, seed=0):
    """Two task classes with distinct SLOs and priorities, sized for a
    reduced engine (prompts of 4-13 tokens, 2-5 output tokens)."""
    rng = np.random.default_rng(seed)
    classes = [("chat", 0.8, 0.25, 0), ("doc", 4.0, 0.6, 1)]
    reqs, t = [], 0.0
    for i in range(n):
        name, ttft, tpot, prio = classes[i % 2]
        t += float(rng.exponential(0.05))
        reqs.append(Request(rid=i, task=name, arrival=t,
                            l_in=int(rng.integers(4, 14)),
                            l_out=int(rng.integers(2, 6)),
                            ttft_slo=ttft, tpot_slo=tpot, priority=prio))
    return reqs


# ---------------------------------------------------------------------------
# Tentpole: engine-backed cluster, same control plane
# ---------------------------------------------------------------------------

def test_engine_backed_cluster_end_to_end_multi_slo():
    """Acceptance: Cluster(backend="engine") completes a multi-SLO
    workload on CPU with the SAME Dispatcher (Alg. 1), Scaler (Alg. 3)
    and PrioritySLOMapper (Alg. 2) objects the simulator uses, and the
    engines' measured step times feed the dispatcher's fitted model."""
    mapper = PrioritySLOMapper(
        bands_from_tasks([TASKS[t] for t in TWO_TASK_SET])
    )
    reqs = _small_multi_slo_workload(12)
    cluster = Cluster(_engine_cluster_cfg(
        slo_mapper=mapper, scaling=True,
        scaler=ScalerConfig(max_workers=1, min_workers=1),
    ))
    # the unmodified control-plane classes drive the engine plane
    assert isinstance(cluster.policy.dispatcher, Dispatcher)
    assert isinstance(cluster.scaler, Scaler)
    assert all(isinstance(w, EngineWorker) for w in cluster.workers)

    res = cluster.run(reqs)
    m = res.metrics
    assert m.n_finished == m.n_total == len(reqs)
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert r.finish_time is not None and r.first_token_time is not None
        assert len(r.generated) == r.l_out
        assert r.tokens_done == r.l_out
        assert r.ttft is not None and r.ttft >= 0.0
        # Algorithm 2 mapped the SLOs into the priority band
        band = mapper.bands[r.priority]
        assert band.min_ttft - 1e-9 <= r.ttft_slo <= band.max_ttft + 1e-9
    # real measured step times reached the shared profiler (Appendix A
    # path), so Eq. 5 budgets were grounded in engine latencies
    assert cluster.fitted.n_samples() > 0
    assert cluster.workers[0].engine.profiler is cluster.fitted
    # per-task multi-SLO breakdown present
    assert set(m.per_task) == {"chat", "doc"}
    for v in m.per_task.values():
        assert {"ttft_attainment", "tpot_attainment"} <= set(v)


def test_sim_and_engine_runmetrics_schema_identical():
    """Acceptance: both planes emit the same RunMetrics schema through
    the shared compute_metrics."""
    sim = Cluster(ClusterConfig(model=get_config("qwen7b"), n_workers=1,
                                policy="hyperflexis", seed=0)).run(
        poisson_workload(["gsm8k"], qps=16, n_per_task=5, seed=0))
    eng = Cluster(_engine_cluster_cfg()).run(_small_multi_slo_workload(6))
    a = dataclasses.asdict(sim.metrics)
    b = dataclasses.asdict(eng.metrics)
    assert a.keys() == b.keys()
    assert set(sim.metrics.row()) == set(eng.metrics.row())
    inner_a = {k for v in sim.metrics.per_task.values() for k in v}
    inner_b = {k for v in eng.metrics.per_task.values() for k in v}
    assert inner_a == inner_b


def test_engine_request_shim_removed():
    """The PR-2 ``EngineRequest`` deprecation shim is gone (nothing
    imported it); ``Request.from_prompt`` is the one construction
    path for engine-plane requests."""
    from repro.serving import engine as engine_mod

    assert not hasattr(engine_mod, "EngineRequest")


def test_request_equality_safe_with_ndarray_fields():
    """ndarray fields are excluded from __eq__, so membership tests in
    worker pools never hit elementwise-array ambiguity."""
    a = Request.from_prompt(0, [1, 2, 3], 4)
    b = Request.from_prompt(0, [1, 2, 3], 4)
    assert a == b          # would raise ValueError if prompt compared
    assert a in [b]
    b.l_out = 5
    assert a != b


def test_backend_protocol_satisfied_by_both_planes():
    truth = AnalyticLatencyModel(get_config("qwen7b"))
    sim = SimWorker(0, "collocated", truth, 10_000,
                    np.random.default_rng(0))
    assert isinstance(sim, Backend)
    assert isinstance(sim, WorkerBase)

    cluster = Cluster(_engine_cluster_cfg())
    ew = cluster.workers[0]
    assert isinstance(ew, Backend)
    assert isinstance(ew, WorkerBase)
    # snapshot comes from the worker itself (Monitor delegates)
    snap = ew.snapshot(0.0, 0.5)
    assert snap.wid == ew.wid and snap.utilization == 0.5


def test_engine_worker_lifecycle_states():
    """The unified lifecycle is visible on engine-plane requests."""
    cluster = Cluster(_engine_cluster_cfg())
    reqs = _small_multi_slo_workload(4)
    assert all(r.state == RequestState.ARRIVED for r in reqs)
    cluster.run(reqs)
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_engine_backed_cluster_slot_plane_fallback():
    """The monolithic slot plane (ring-cache/encoder fallback) also
    serves cluster-backed, with the shape lattice pre-warmed."""
    from repro.serving.engine import EngineConfig

    reqs = _small_multi_slo_workload(6)
    res = Cluster(_engine_cluster_cfg(engine=EngineConfig(
        n_slots=4, max_len=32, prefill_batch=2, paged=False))).run(reqs)
    assert res.metrics.n_finished == res.metrics.n_total == 6
    assert all(len(r.generated) == r.l_out for r in reqs)


def test_engine_backed_run_is_deterministic_in_tokens():
    """Greedy decoding + deterministic prompts: two engine-backed runs
    generate identical token streams (timings may differ)."""
    out = []
    for _ in range(2):
        reqs = _small_multi_slo_workload(6)
        Cluster(_engine_cluster_cfg()).run(reqs)
        out.append([r.generated for r in reqs])
    assert out[0] == out[1]


# ---------------------------------------------------------------------------
# Satellites: workload hygiene
# ---------------------------------------------------------------------------

def test_poisson_rids_assigned_once_after_sort():
    reqs = poisson_workload(FOUR_TASK_SET, qps=32, n_per_task=10, seed=3)
    assert [r.rid for r in reqs] == list(range(len(reqs)))
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr)
    # determinism: identical ids AND payloads across calls
    again = poisson_workload(FOUR_TASK_SET, qps=32, n_per_task=10, seed=3)
    assert [(r.rid, r.task, r.arrival, r.l_in, r.l_out) for r in reqs] == \
           [(r.rid, r.task, r.arrival, r.l_in, r.l_out) for r in again]


def test_materialize_prompts_deterministic_and_validating():
    reqs = poisson_workload(["gsm8k"], qps=8, n_per_task=4, seed=1)
    materialize_prompts(reqs, vocab_size=100, seed=7)
    a = [r.prompt.tolist() for r in reqs]
    reqs2 = poisson_workload(["gsm8k"], qps=8, n_per_task=4, seed=1)
    materialize_prompts(reqs2, vocab_size=100, seed=7)
    assert a == [r.prompt.tolist() for r in reqs2]
    with pytest.raises(ValueError):
        materialize_prompts(
            poisson_workload(["wikisql"], qps=8, n_per_task=2, seed=1),
            vocab_size=100, seed=7, max_len=32)


def test_engine_cluster_rejects_impossible_workload_before_run():
    """The full engine admission constraints (incl. the paged
    fit-alone page bound) are checked up front — an impossible request
    fails before the run, not mid-dispatch."""
    from repro.serving.engine import EngineConfig

    # 2 pages of 8 tokens: a request needing 3 pages can never fit
    cluster = Cluster(_engine_cluster_cfg(engine=EngineConfig(
        n_slots=2, max_len=32, page_size=8, n_pages=2)))
    bad = [Request(rid=0, task="t", arrival=0.0, l_in=14, l_out=6,
                   ttft_slo=1.0, tpot_slo=1.0)]
    with pytest.raises(ValueError, match="pages"):
        cluster.run(bad)


def test_ramp_workload_class_join_boundaries():
    """Fig. 6: class k (lowest priority first) never arrives before
    k * join_every, and all arrivals stay inside the duration."""
    join_every, duration = 20.0, 100.0
    reqs = ramp_workload(FOUR_TASK_SET, qps_per_class=10.0,
                         join_every=join_every, duration=duration, seed=5)
    specs = sorted((TASKS[n] for n in FOUR_TASK_SET),
                   key=lambda s: -s.priority)
    for k, spec in enumerate(specs):
        arrivals = [r.arrival for r in reqs if r.task == spec.name]
        assert arrivals, spec.name  # every class joined
        assert min(arrivals) >= k * join_every
        assert max(arrivals) < duration


def test_ramp_workload_deterministic_under_seed():
    kw = dict(qps_per_class=12.0, join_every=15.0, duration=60.0, seed=9)
    a = ramp_workload(FOUR_TASK_SET, **kw)
    b = ramp_workload(FOUR_TASK_SET, **kw)
    assert [(r.rid, r.task, r.arrival, r.l_in, r.l_out, r.priority)
            for r in a] == \
           [(r.rid, r.task, r.arrival, r.l_in, r.l_out, r.priority)
            for r in b]
    assert [r.rid for r in a] == list(range(len(a)))


def test_ramp_workload_priority_ordering_of_joins():
    """Classes join in decreasing priority value (lowest priority
    first); the first arrival overall belongs to the lowest class."""
    reqs = ramp_workload(FOUR_TASK_SET, qps_per_class=10.0,
                         join_every=20.0, duration=100.0, seed=2)
    assert reqs[0].priority == max(r.priority for r in reqs)
    first_seen = {}
    for r in reqs:
        first_seen.setdefault(r.priority, r.arrival)
    joins = sorted(first_seen.items(), key=lambda kv: kv[1])
    assert [p for p, _ in joins] == sorted(
        first_seen, reverse=True)  # descending priority value
    # n_per_class caps each class
    capped = ramp_workload(FOUR_TASK_SET, qps_per_class=10.0,
                           join_every=20.0, duration=100.0,
                           n_per_class=3, seed=2)
    for name in FOUR_TASK_SET:
        assert sum(1 for r in capped if r.task == name) <= 3
