import numpy as np
import pytest

# NOTE: no XLA_FLAGS here — tests must see the real single CPU device;
# only launch/dryrun.py requests 512 placeholder devices.


@pytest.fixture
def rng():
    return np.random.default_rng(0)
