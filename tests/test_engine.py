"""Real JAX engine: continuous batching equals reference generation."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model
from repro.core.request import Request
from repro.serving.engine import EngineConfig, InferenceEngine


def _reference_generate(model, params, prompt, n_new):
    toks = jnp.asarray(prompt)[None, :]
    lens = jnp.array([len(prompt)], jnp.int32)
    logits, caches = model.prefill(params, toks, lens,
                                   cache_len=len(prompt) + n_new)
    out = [int(jnp.argmax(logits[0]))]
    pos = lens
    for _ in range(n_new - 1):
        lg, caches = model.decode_step(
            params, caches, jnp.array([out[-1]], jnp.int32), pos
        )
        out.append(int(jnp.argmax(lg[0])))
        pos = pos + 1
    return out


@pytest.mark.parametrize("arch", ["qwen7b", "mamba2-2.7b"])
def test_engine_generation_content(arch):
    cfg = get_smoke_config(arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 13, 7)]
    reqs = [Request.from_prompt(i, p, max_new=5)
            for i, p in enumerate(prompts)]
    eng = InferenceEngine(model, params,
                          EngineConfig(n_slots=2, max_len=32,
                                       prefill_batch=2))
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    for r in reqs:
        assert r.finish_time is not None
        assert len(r.generated) == 5
        ref = _reference_generate(model, params, r.prompt, 5)
        assert r.generated == ref, (r.rid, r.generated, ref)


def test_engine_slot_reuse():
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = InferenceEngine(model, params,
                          EngineConfig(n_slots=1, max_len=24,
                                       prefill_batch=1))
    rng = np.random.default_rng(2)
    reqs = [Request.from_prompt(
        i, rng.integers(0, cfg.vocab_size, size=4).astype(np.int32),
        max_new=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.finish_time is not None for r in reqs)
    assert eng.slots.n_free == 1


def test_engine_profiler_feeds_latency_model():
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = InferenceEngine(model, params,
                          EngineConfig(n_slots=4, max_len=32,
                                       prefill_batch=1))
    rng = np.random.default_rng(3)
    for i in range(6):
        eng.submit(Request.from_prompt(
            i, rng.integers(0, cfg.vocab_size, size=6).astype(np.int32),
            max_new=6))
    eng.run_until_done()
    assert eng.fit_profiler()
    t = eng.profiler.prefill_time([8])
    assert t > 0


def test_paged_preemption_under_page_pressure():
    """An oversubscribed page pool recompute-preempts instead of
    deadlocking or crashing, and preserves token-exact outputs."""
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
               for _ in range(2)]

    def run(**kw):
        reqs = [Request.from_prompt(i, p.copy(), max_new=6)
                for i, p in enumerate(prompts)]
        eng = InferenceEngine(model, params, EngineConfig(
            n_slots=2, max_len=16, prefill_batch=2, paged=True,
            chunk_size=8, page_size=4, **kw))
        for r in reqs:
            eng.submit(r)
        fin = eng.run_until_done(max_steps=500)
        assert len(fin) == 2
        assert eng.kv.n_free_pages == eng.kv.n_pages
        return [r.generated for r in reqs]

    base = run()                 # roomy default pool
    # 4 pages: one request fills the whole pool -> prefill preemption
    # 5 pages: both fit until decode grows -> decode-time preemption
    for n_pages in (4, 5):
        assert run(n_pages=n_pages) == base, n_pages


def test_engine_rejects_impossible_requests():
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    eng = InferenceEngine(model, params, EngineConfig(n_slots=2, max_len=16))
    with pytest.raises(ValueError):
        eng.submit(Request.from_prompt(0, np.zeros(0, np.int32),
                                       max_new=2))
    with pytest.raises(ValueError):
        eng.submit(Request.from_prompt(1, np.zeros(16, np.int32),
                                       max_new=2))
    eng2 = InferenceEngine(model, params, EngineConfig(
        n_slots=2, max_len=24, paged=True, page_size=4, n_pages=2))
    with pytest.raises(ValueError):  # could never fit the pool alone
        eng2.submit(Request.from_prompt(2, np.zeros(10, np.int32),
                                        max_new=4))
