"""Fault injection + recovery (PR 9).

Covers the FaultInjector spec grammar and determinism, replica-crash
recovery on both planes (engine token identity included), KV-transfer
retry with alternate destinations, crash races with migrations in
flight, last-weight-owner death (disk scale-from-zero), SLO-ordered
mass re-admission, the weight-provisioning fallback chain, donor
selection guards, the checkpoint staging-dir sweep, terminal
FAILED/RETRIED stream semantics, and the hardened online JSONL loop.
"""

import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.faults import FaultInjector
from repro.core.request import Request, RequestState
from repro.core.scaler import ScalerConfig
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.metrics import StreamingStats, compute_metrics
from repro.serving.session import EventKind, ServingSession

MODEL = get_config("qwen7b")
SMOKE = get_smoke_config("qwen7b")


def _req(rid, arrival=0.0, l_in=200, l_out=30, ttft=10.0, tpot=0.5,
         task="t"):
    return Request(rid=rid, task=task, arrival=arrival, l_in=l_in,
                   l_out=l_out, ttft_slo=ttft, tpot_slo=tpot)


def _burst(n, seed=3, qps=30.0, **kw):
    rng = np.random.default_rng(seed)
    t, reqs = 0.0, []
    for i in range(n):
        t += float(rng.exponential(1.0 / qps))
        reqs.append(_req(i, arrival=t, l_in=int(rng.integers(150, 350)),
                         l_out=int(rng.integers(20, 40)), **kw))
    return reqs


def _run(reqs, *, spec=None, recovery=True, seed=3, **cfg_kw):
    faults = FaultInjector.from_spec(spec, seed=seed) if spec else None
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", seed=seed,
                        faults=faults, recovery=recovery, **cfg_kw)
    return Cluster(cfg).run(reqs)


# ---------------------------------------------------------------------------
# FaultInjector: spec grammar + determinism
# ---------------------------------------------------------------------------

def test_fault_spec_parsing_round_trip():
    fi = FaultInjector.from_spec(
        "crash:wid=1,t=2.0; kv_drop:p=0.5,max=3;"
        "weight_fail:strategy=d2d,p=1.0;"
        "straggler:wid=0,slowdown=4.0,t=1.0,until=6.0", seed=9,
    )
    assert [(c.wid, c.t) for c in fi.crashes] == [(1, 2.0)]
    assert fi.kv_drop_p == 0.5 and fi.kv_drop_max == 3
    assert fi.weight_fail_p == {"d2d": 1.0}
    s = fi.stragglers[0]
    assert (s.wid, s.slowdown, s.t, s.until) == (0, 4.0, 1.0, 6.0)


def test_fault_spec_errors_are_loud():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultInjector.from_spec("explode:wid=1")
    with pytest.raises(ValueError, match="missing field"):
        FaultInjector.from_spec("crash:wid=1")  # no t
    with pytest.raises(ValueError, match="key=value"):
        FaultInjector.from_spec("crash:wid")
    with pytest.raises(ValueError, match="not in"):
        FaultInjector(kv_drop_p=1.5)


def test_injector_streams_deterministic_and_independent():
    def draws(fi):
        return [fi.drop_kv_transfer(0.0, i, 0, 1) for i in range(40)]

    a = FaultInjector(kv_drop_p=0.4, seed=7)
    b = FaultInjector(kv_drop_p=0.4, seed=7)
    ref = draws(a)
    assert ref == draws(b)
    # adding a crash + weight-fail schedule must not reshuffle which
    # transfers drop (independent per-class streams)
    c = FaultInjector(kv_drop_p=0.4, seed=7,
                      crashes=[(0, 1.0)], weight_fail_p={"*": 0.5})
    for _ in range(10):
        c.fail_weight_load(0.0, "d2d")
    assert ref == draws(c)


def test_kv_drop_cap_bounds_injections():
    fi = FaultInjector(kv_drop_p=1.0, kv_drop_max=2, seed=0)
    hits = sum(fi.drop_kv_transfer(0.0, i, 0, 1) for i in range(10))
    assert hits == 2
    assert fi.n_injected == 2


def test_straggler_windows_compound_and_note_once():
    fi = FaultInjector(stragglers=[(0, 3.0, 1.0, 5.0),
                                   (0, 2.0, 2.0, 4.0)])
    assert fi.slowdown(0, 0.5) == 1.0       # before the window
    assert fi.slowdown(0, 1.5) == 3.0
    assert fi.slowdown(0, 3.0) == 6.0       # overlap compounds
    assert fi.slowdown(0, 5.0) == 1.0       # window is half-open
    assert fi.slowdown(1, 3.0) == 1.0       # other worker untouched
    assert fi.n_injected == 2               # one record per entry


# ---------------------------------------------------------------------------
# Sim-plane crash recovery
# ---------------------------------------------------------------------------

def test_sim_crash_recovery_requeues_everything():
    res = _run(_burst(40), spec="crash:wid=1,t=0.3", n_workers=2)
    m = res.metrics
    assert m.n_finished + m.n_failed == 40
    assert m.n_failed == 0 and res.n_lost == 0
    assert res.n_recovered > 0
    assert res.n_faults == 1
    assert any(ev == "crash" for _, wid, ev in res.timeline if wid == 1)


def test_sim_crash_recovery_off_sheds_residents():
    on = _run(_burst(40), spec="crash:wid=1,t=0.3", n_workers=2)
    off = _run(_burst(40), spec="crash:wid=1,t=0.3", n_workers=2,
               recovery=False)
    assert off.metrics.n_finished + off.metrics.n_failed == 40
    assert off.n_lost > 0 and off.metrics.n_failed == off.n_lost
    assert on.metrics.n_finished > off.metrics.n_finished


def test_sim_crash_during_monolithic_prefill_not_stranded():
    # regression: a monolithic prefill batch lives inside the in-flight
    # StepOutcome, not in any worker pool — a crash mid-step must still
    # re-home it (drop_all returns the in-flight batch)
    reqs = _burst(60, qps=60.0)
    res = _run(reqs, spec="crash:wid=1,t=0.1", n_workers=2)
    assert res.metrics.n_finished + res.metrics.n_failed == 60
    assert all(r.state in (RequestState.FINISHED, RequestState.FAILED)
               for r in reqs)


def test_chunked_plane_crash_recovery():
    res = _run(_burst(40), spec="crash:wid=1,t=0.3", n_workers=2,
               chunk_tokens=256)
    assert res.metrics.n_finished + res.metrics.n_failed == 40
    assert res.n_recovered > 0


def test_crash_of_only_worker_without_scaler_sheds():
    # nothing can ever serve the residents again: SLO-aware re-admission
    # must shed them as FAILED, not park them forever
    res = _run(_burst(10), spec="crash:wid=0,t=0.05", n_workers=1)
    m = res.metrics
    assert m.n_finished + m.n_failed == 10
    assert m.n_failed > 0 and res.n_lost == m.n_failed


def test_straggler_degrades_attainment_deterministically():
    base = _run(_burst(40), n_workers=2)
    a = _run(_burst(40), spec="straggler:wid=0,slowdown=6.0", n_workers=2)
    b = _run(_burst(40), spec="straggler:wid=0,slowdown=6.0", n_workers=2)
    assert a.metrics.attainment <= base.metrics.attainment
    assert a.metrics.mean_e2e == b.metrics.mean_e2e  # replayable
    assert a.n_faults == 1


# ---------------------------------------------------------------------------
# Stream semantics: no hung consumer, terminal FAILED, RETRIED events
# ---------------------------------------------------------------------------

def test_no_hung_events_consumer_after_crash():
    faults = FaultInjector.from_spec("crash:wid=1,t=0.2", seed=3)
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", n_workers=2,
                        seed=3, faults=faults)
    s = ServingSession(Cluster(cfg), admission="none")
    handles = [s.submit_request(r) for r in _burst(30)]
    s.drain()
    for h in handles:
        assert h.done, f"rid {h.rid} never reached a terminal event"
        kinds = [ev.kind for ev in h.events(wait=False)]
        assert kinds[-1] in (EventKind.FINISHED, EventKind.FAILED,
                             EventKind.REJECTED)
    s.close()


def test_failed_event_is_terminal_with_reason():
    faults = FaultInjector.from_spec("crash:wid=0,t=0.05", seed=3)
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", n_workers=1,
                        seed=3, faults=faults)
    s = ServingSession(Cluster(cfg), admission="none")
    handles = [s.submit_request(r) for r in _burst(8)]
    s.drain()
    failed = [h for h in handles if h.failed]
    assert failed, "expected at least one shed request"
    for h in failed:
        last = h.log[-1]
        assert last.kind == EventKind.FAILED
        assert "reason" in last.data
    res = s.close()
    assert s.streaming.n_failed == len(failed)
    assert res.metrics.n_failed == len(failed)


def test_retried_event_emitted_on_requeue():
    faults = FaultInjector.from_spec("crash:wid=1,t=0.2", seed=3)
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", n_workers=2,
                        seed=3, faults=faults)
    s = ServingSession(Cluster(cfg), admission="none")
    handles = [s.submit_request(r) for r in _burst(30)]
    s.drain()
    retried = [h for h in handles
               if any(ev.kind == EventKind.RETRIED for ev in h.log)]
    assert retried, "expected RETRIED events for re-queued residents"
    for h in retried:
        assert h.request.state == RequestState.FINISHED
        ev = next(ev for ev in h.log if ev.kind == EventKind.RETRIED)
        assert ev.data["reason"] == "crash"
    assert s.streaming.n_retried >= len(retried)
    s.close()


def test_streaming_stats_failed_and_retried_counters():
    st = StreamingStats()
    st.observe("first_token", 1, 0.1, arrival=0.0)
    st.observe("retried", 1, 0.2)
    # the recovery gap must not pollute inter-token latency samples
    st.observe("first_token", 1, 0.9, arrival=0.0)
    st.observe("failed", 2, 0.3)
    row = st.row()
    assert row["n_retried"] == 1 and row["n_failed"] == 1


def test_compute_metrics_counts_failed_against_attainment():
    a, b = _req(0), _req(1)
    a.first_token_time, a.finish_time = 0.1, 1.0
    a.tokens_done, a.state = a.l_out, RequestState.FINISHED
    b.state = RequestState.FAILED
    m = compute_metrics([a, b], 0.0, 1.0)
    assert m.n_failed == 1 and m.n_total == 2
    assert m.attainment <= 0.5


# ---------------------------------------------------------------------------
# KV-transfer drops: retry, alternate destination, fallback
# ---------------------------------------------------------------------------

def test_kv_drop_retries_on_alternate_destination():
    res = _run(_burst(30), spec="kv_drop:p=1.0,max=2", mode="pd",
               n_prefill=1, n_decode=2)
    assert res.metrics.n_finished + res.metrics.n_failed == 30
    assert res.n_lost == 0
    assert res.n_transfer_retries >= 2
    # each retry re-places the transfer, avoiding the destination of
    # the drop that immediately preceded it for that request
    last_drop: dict = {}
    checked = 0
    for _, _, ev in res.timeline:
        if ev.startswith("kv_drop:"):
            rid, dst = ev.split(":")[1].split("->")
            last_drop[rid] = dst
        elif ev.startswith("kv_retry_to:"):
            rid, dst = ev.split(":")[1].split("->")
            assert dst != last_drop[rid]
            checked += 1
    assert checked >= 2


def test_kv_drop_exhausted_retries_fall_back():
    from repro.serving.recovery import RecoveryConfig

    faults = FaultInjector.from_spec("kv_drop:p=1.0,max=4", seed=3)
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", mode="pd",
                        n_prefill=1, n_decode=2, seed=3, faults=faults,
                        recovery_cfg=RecoveryConfig(
                            max_transfer_retries=0))
    res = Cluster(cfg).run(_burst(20))
    assert res.metrics.n_finished + res.metrics.n_failed == 20
    assert res.n_transfer_retries == 0
    assert any(ev.startswith("kv_giveup:") for _, _, ev in res.timeline)


def test_crash_of_decode_worker_with_transfers_in_flight():
    # hand-offs racing toward the corpse: their ledger charges are
    # dropped and the stale kv_ready events no-op; sources re-home
    res = _run(_burst(30, qps=60.0), spec="crash:wid=1,t=0.15",
               mode="pd", n_prefill=1, n_decode=2)
    assert res.metrics.n_finished + res.metrics.n_failed == 30
    assert res.n_lost == 0


def test_crash_of_prefill_source_with_transfers_in_flight():
    # the source dies mid-flight: the crashed-src guard stops the
    # export and crash recovery re-prefills the residents elsewhere
    res = _run(_burst(30, qps=60.0), spec="crash:wid=0,t=0.15",
               mode="pd", n_prefill=2, n_decode=1)
    assert res.metrics.n_finished + res.metrics.n_failed == 30


def test_live_migration_survives_crash_and_drops():
    res = _run(_burst(40, qps=80.0),
               spec="crash:wid=1,t=0.3;kv_drop:p=0.5,max=3",
               n_workers=3, live_migration=True)
    assert res.metrics.n_finished + res.metrics.n_failed == 40
    assert res.n_faults >= 1


# ---------------------------------------------------------------------------
# Mass re-admission ordering
# ---------------------------------------------------------------------------

def test_readmission_orders_by_tpot_then_arrival(monkeypatch):
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", n_workers=2,
                        seed=0)
    cl = Cluster(cfg)
    w = cl.workers[1]
    residents = [
        _req(0, arrival=0.3, tpot=0.5),
        _req(1, arrival=0.1, tpot=0.1),
        _req(2, arrival=0.2, tpot=0.1),
        _req(3, arrival=0.0, tpot=0.9),
    ]
    for r in residents:
        r.state = RequestState.DECODING
        r.prefill_worker = r.decode_worker = w.wid
        r.first_token_time, r.tokens_done = 0.05, 3
        w.running.append(r)
    order = []
    orig = cl.policy.on_request_arrive
    monkeypatch.setattr(
        cl.policy, "on_request_arrive",
        lambda r: (order.append(r.rid), orig(r))[1],
    )
    w.crashed = True
    w.deactivate(1.0)
    cl.recovery.note_crash(w.wid, 1.0)
    cl.recovery.watchdog(1.0)
    assert order == [1, 2, 0, 3]  # (tpot_slo, arrival) lexicographic
    assert cl.recovery.n_recovered == 4


def test_requeue_keeps_original_arrival_and_first_token():
    res = _run(_burst(40), spec="crash:wid=1,t=0.3", n_workers=2)
    reqs = res.requests
    # arrival stamps survive the re-queue: attainment is judged against
    # the true submit time, not the recovery time
    assert all(r.arrival is not None and r.arrival < 2.0 for r in reqs)
    assert all(r.state == RequestState.FINISHED for r in reqs)


# ---------------------------------------------------------------------------
# Weight-provisioning faults + donor guards (engine plane)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def engine_cluster():
    from repro.serving.engine import EngineConfig

    cfg = ClusterConfig(
        model=SMOKE, backend="engine", n_workers=2,
        policy="hyperflexis", seed=0,
        engine=EngineConfig(n_slots=4, max_len=48, prefill_batch=2,
                            page_size=8, chunk_size=16),
        faults=FaultInjector(weight_fail_p={"d2d": 1.0}, seed=0),
    )
    return Cluster(cfg)


def test_weight_fail_falls_back_down_the_chain(engine_cluster):
    cl = engine_cluster
    w = cl._make_worker(90, "collocated", active=False,
                        strategy="d2d", donor=0)
    # injected d2d failure -> the cpu offload serves the copy
    assert cl._provision_strategy == "cpu"
    assert cl.weights.owns(90)
    assert any("weight_fail:d2d" in str(ev)
               for _, wid, ev in cl.timeline if wid == 90)
    cl.weights.release(90)
    w.engine.release_weights()


def test_dead_donor_mid_pull_falls_back(engine_cluster):
    cl = engine_cluster
    # donor wid no longer owns a tree: the d2d pull itself raises and
    # the chain falls through (cpu is also scripted dead here? no —
    # only d2d has p=1.0, but the injected skip already covers d2d;
    # exercise the *exception* path with a fault-free injector)
    saved = cl.faults
    cl.faults = None
    try:
        cl._make_worker(91, "collocated", active=False,
                        strategy="d2d", donor=777)  # bogus donor
        assert cl._provision_strategy in ("cpu", "disk")
        assert cl.weights.owns(91)
    finally:
        cl.faults = saved
        cl.weights.release(91)


def test_pick_donor_skips_evacuating_and_crashed(engine_cluster):
    cl = engine_cluster
    w0, w1 = cl.workers[0], cl.workers[1]
    assert cl._pick_donor() in (w0.wid, w1.wid)
    w0.evacuating = True
    assert cl._pick_donor() == w1.wid
    w1.crashed = True
    assert cl._pick_donor() is None
    w0.evacuating = w1.crashed = False


# ---------------------------------------------------------------------------
# Engine plane end to end: crash recovery is token-exact
# ---------------------------------------------------------------------------

def _engine_run(spec, recovery=True, n=14, seed=5):
    from repro.serving.workload import engine_smoke_workload

    reqs = engine_smoke_workload(n=n, qps=2000.0, seed=seed, clip_out=20)
    faults = FaultInjector.from_spec(spec, seed=seed) if spec else None
    cfg = ClusterConfig(model=SMOKE, backend="engine", n_workers=2,
                        policy="hyperflexis", seed=seed, faults=faults,
                        recovery=recovery, monitor_interval=0.005)
    res = Cluster(cfg).run(reqs)
    return res, {r.rid: list(r.generated) for r in reqs}


def test_engine_crash_recovery_token_identical():
    base, base_toks = _engine_run(None)
    assert base.metrics.n_finished == 14
    res, toks = _engine_run("crash:wid=1,t=0.01")
    assert res.metrics.n_finished + res.metrics.n_failed == 14
    assert res.n_recovered > 0 and res.n_lost == 0
    # greedy decode + prompt folding: recovered streams re-emit the
    # exact tokens of the fault-free run
    assert toks == base_toks


def test_engine_crash_recovery_off_sheds():
    res, _ = _engine_run("crash:wid=1,t=0.01", recovery=False)
    assert res.metrics.n_failed > 0
    assert res.metrics.n_finished + res.metrics.n_failed == 14


def test_engine_last_weight_owner_crash_scales_from_disk():
    from repro.serving.engine import EngineConfig
    from repro.serving.workload import engine_smoke_workload

    reqs = engine_smoke_workload(n=8, qps=2000.0, seed=4, clip_out=8)
    faults = FaultInjector.from_spec("crash:wid=0,t=0.01", seed=4)
    cfg = ClusterConfig(
        model=SMOKE, backend="engine", n_workers=1,
        policy="hyperflexis", seed=4, faults=faults,
        monitor_interval=0.005, scaling=True,
        scaler=ScalerConfig(tau=0.02, max_workers=2,
                            weight_strategy="d2d"),
        engine=EngineConfig(n_slots=4, max_len=48, prefill_batch=2,
                            page_size=8, chunk_size=16),
    )
    res = Cluster(cfg).run(reqs)
    # the only weight owner died: the first scale-out must come from
    # disk (later ones may d2d off the freshly provisioned replica)
    outs = [ev for _, _, ev in res.timeline
            if ev.startswith("scale_out:")]
    assert outs and "disk" in outs[0]
    assert res.metrics.n_finished + res.metrics.n_failed == 8
    assert res.metrics.n_finished > 0


def test_engine_crash_mid_step_completion_not_stranded():
    """The engine executes steps eagerly: a request can complete (and
    leave every engine pool) while its step is still in flight in
    cluster time.  A crash landing in that window must re-home it —
    not strand its handle until the drain horizon.  The straggler
    stretches w1's step durations so the crash deterministically
    precedes the first step_done; l_out=1 makes the request complete
    inside its own prefill step."""
    from repro.serving.workload import engine_smoke_workload

    reqs = engine_smoke_workload(n=8, qps=2000.0, seed=6, clip_out=1)
    faults = FaultInjector.from_spec(
        "straggler:wid=1,slowdown=1e6;crash:wid=1,t=0.05", seed=6
    )
    cfg = ClusterConfig(model=SMOKE, backend="engine", n_workers=2,
                        policy="hyperflexis", seed=6, faults=faults,
                        monitor_interval=0.005, drain_timeout=5.0)
    res = Cluster(cfg).run(reqs)
    assert res.metrics.n_finished + res.metrics.n_failed == 8
    assert res.n_recovered > 0
    # no orphaned handle rode the drain horizon
    assert res.metrics.makespan < 5.0


# ---------------------------------------------------------------------------
# Checkpoint staging-dir sweep
# ---------------------------------------------------------------------------

def test_load_latest_sweeps_stale_tmp_dirs(tmp_path):
    import jax.numpy as jnp

    from repro.distributed.checkpoint import (
        load_latest,
        save_checkpoint,
    )

    tree = {"w": jnp.ones((2, 2))}
    save_checkpoint(str(tmp_path), 3, tree)
    stale = tmp_path / ".tmp_dead_writer"
    stale.mkdir()
    (stale / "arrays.npz").write_bytes(b"partial")
    out = load_latest(str(tmp_path), tree)
    assert out is not None and out[0] == 3
    assert not stale.exists()


def test_load_latest_sweep_on_empty_dir(tmp_path):
    from repro.distributed.checkpoint import load_latest

    stale = tmp_path / ".tmp_x"
    stale.mkdir()
    assert load_latest(str(tmp_path), {"w": np.ones(2)}) is None
    assert not stale.exists()


# ---------------------------------------------------------------------------
# Online JSONL hardening + fault flags (CLI)
# ---------------------------------------------------------------------------

def test_online_malformed_jsonl_survives():
    env = dict(os.environ, PYTHONPATH="src")
    lines = "\n".join([
        "this is not json",
        '{"task":"gsm8k","l_in":12,"l_out":3}',
        '[1,2,3]',
        '{"task":"gsm8k","l_in":"not-a-length","l_out":3}',
        '{"task":"gsm8k","l_in":10,"l_out":2}',
    ]) + "\n"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--online",
         "--model", "qwen7b", "--workers", "1", "--admission", "none"],
        input=lines, capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    evs = [json.loads(ln) for ln in out.stdout.splitlines() if ln]
    errors = [e for e in evs if e["event"] == "error"]
    summary = [e for e in evs if e["event"] == "summary"]
    assert len(errors) == 3
    assert all("reason" in e and "line" in e for e in errors)
    assert summary and summary[0]["n_finished"] == 2


def test_serve_fault_schedule_cli_sim():
    env = dict(os.environ, PYTHONPATH="src")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--model", "qwen7b",
         "--workers", "2", "--qps", "40", "--n-per-task", "8",
         "--tasks", "2task", "--fault-schedule", "crash:wid=1,t=0.3",
         "--json"],
        capture_output=True, text=True, env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    row = json.loads(out.stdout.strip().splitlines()[-1])
    assert row["n_faults"] == 1
    assert row["n_finished"] + row["n_failed"] + row["n_rejected"] \
        == row["n_total"]
