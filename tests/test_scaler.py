"""Algorithm 3: thresholds, sustained scale-in, role transitions."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.latency_model import AnalyticLatencyModel
from repro.core.monitor import Monitor, WorkerSnapshot
from repro.core.request import Request
from repro.core.scaler import Scaler, ScalerConfig
from repro.core.tlmanager import TLManager
from repro.serving.worker import SimWorker


def _setup(max_workers=4):
    cfg = get_config("qwen7b")
    mon = Monitor(0.05)
    tl = TLManager()
    sc = Scaler(ScalerConfig(tau=1.0, max_workers=max_workers), mon, tl,
                cfg)
    truth = AnalyticLatencyModel(cfg)
    ws = [SimWorker(i, "collocated", truth, 10_000,
                    np.random.default_rng(i)) for i in range(2)]
    return sc, mon, ws


def _snap(mon, w, util, t=0.0):
    mon.snapshots[w.wid] = WorkerSnapshot(
        wid=w.wid, role=w.role, time=t, busy=util > 0,
        n_waiting=0, n_running=0, kv_tokens=0, cur_lens=(),
        waiting_tokens=0, utilization=util,
    )


def _req(rid, arrival, ttft=0.7):
    return Request(rid=rid, task="t", arrival=arrival, l_in=10, l_out=5,
                   ttft_slo=ttft, tpot_slo=0.5)


def test_scale_out_on_high_load():
    sc, mon, ws = _setup()
    for w in ws:
        _snap(mon, w, 0.99)
    acts = sc.tick(10.0, ws, [])
    assert acts and acts[0].kind == "out"
    assert acts[0].delay > 0  # provisioning is not free


def test_scale_out_on_queue_wait():
    sc, mon, ws = _setup()
    for w in ws:
        _snap(mon, w, 0.1)
    # a request far past its TTFT drives the wait term
    acts = sc.tick(10.0, ws, [_req(0, arrival=0.0, ttft=0.5)])
    assert acts and acts[0].kind == "out"


def test_scale_in_requires_sustained_low_load():
    sc, mon, ws = _setup()
    for w in ws:
        _snap(mon, w, 0.01)
    t = 10.0
    acts = sc.tick(t, ws, [])
    assert not acts  # 1st low tick
    acts = sc.tick(t + 1.1, ws, [])
    assert not acts  # 2nd
    acts = sc.tick(t + 2.2, ws, [])
    assert acts and acts[0].kind == "in"


def test_max_workers_cap():
    sc, mon, ws = _setup(max_workers=2)
    for w in ws:
        _snap(mon, w, 0.99)
    assert sc.tick(10.0, ws, []) == []


def test_pd_role_transition_preferred():
    sc, mon, ws = _setup(max_workers=8)
    ws[0].role = "prefill"
    ws[1].role = "decode"
    extra = SimWorker(2, "decode", ws[0].truth, 10_000,
                      np.random.default_rng(9))
    ws.append(extra)
    _snap(mon, ws[0], 0.99)
    _snap(mon, ws[1], 0.01)
    _snap(mon, ws[2], 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, 0.0, ttft=0.2)], [])
    assert acts and acts[0].kind == "role"
    assert acts[0].role == "prefill"


def _pd_setup(max_workers=8, n_prefill=2, n_decode=2):
    sc, mon, ws = _setup(max_workers=max_workers)
    truth = ws[0].truth
    ws = [SimWorker(i, "prefill", truth, 10_000,
                    np.random.default_rng(i)) for i in range(n_prefill)]
    ws += [SimWorker(n_prefill + i, "decode", truth, 10_000,
                     np.random.default_rng(100 + i))
           for i in range(n_decode)]
    return sc, mon, ws


def test_pd_flip_decode_to_prefill_on_queue_imbalance():
    """Prefill pool hot (queue wait past TTFT), decode pool idle: an
    idle decode worker flips instead of provisioning a new instance."""
    sc, mon, ws = _pd_setup()
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert len(acts) == 1 and acts[0].kind == "role"
    assert acts[0].role == "prefill"
    assert acts[0].worker_id in {w.wid for w in ws if w.role == "decode"}
    assert acts[0].delay == sc.cfg.role_transition_time
    assert sc.n_role_flips == 1


def test_pd_flip_prefill_to_decode_on_decode_pressure():
    """The symmetric direction: decode hot, prefill idle."""
    sc, mon, ws = _pd_setup()
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "decode" else 0.01)
    acts = sc.tick_pd(10.0, ws, [], [_req(0, arrival=0.0, ttft=0.2)])
    assert len(acts) == 1 and acts[0].kind == "role"
    assert acts[0].role == "decode"
    assert acts[0].worker_id in {w.wid for w in ws if w.role == "prefill"}


def test_pd_flip_only_drained_workers():
    """Drain-and-flip: a worker still holding queued/running work is
    never flipped — the scaler scales out instead."""
    sc, mon, ws = _pd_setup()
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    for w in ws:
        if w.role == "decode":
            w.running.append(_req(50 + w.wid, arrival=0.0))
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert all(a.kind != "role" for a in acts)
    assert any(a.kind == "out" and a.role == "prefill" for a in acts)


def test_pd_flip_blocked_by_parked_kv():
    """A prefill worker whose requests await migration (parked KV
    resident) has not drained: flipping it would strand the pages."""
    sc, mon, ws = _pd_setup(n_prefill=2, n_decode=2)
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "decode" else 0.01)
    for w in ws:
        if w.role == "prefill":
            w.parked.append(_req(50 + w.wid, arrival=0.0))
    acts = sc.tick_pd(10.0, ws, [], [_req(0, arrival=0.0, ttft=0.2)])
    assert all(a.kind != "role" for a in acts)


def test_pd_flip_respects_min_pool_size():
    """A pool never flips below min_workers even when idle."""
    sc, mon, ws = _pd_setup(n_prefill=2, n_decode=1)
    sc.cfg.min_workers = 1
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert all(a.kind != "role" for a in acts)


def test_fast_scaling_delay_smaller_than_disk():
    sc, mon, ws = _setup()
    d2d, warm = sc.provision_delay(0.0, "d2d")
    assert warm
    # 10s later the warm pool has replenished: same-footing compare
    disk, warm = sc.provision_delay(10.0, "disk")
    assert warm
    assert d2d < disk


# -- pool-accounting regressions -----------------------------------------------


class _BareWorker(SimWorker):
    """A Backend whose ``is_drained`` reports only queue emptiness.
    The protocol does not promise the active check — the Scaler must
    filter inactive workers itself."""

    def is_drained(self):
        return not (self.waiting or self.running or self.parked)


def test_scale_in_never_picks_inactive_drained_worker():
    """An already-deactivated drained worker must not be 'scaled in'
    again (double-counts n_scale_in, leaves the loaded worker up)."""
    sc, mon, ws = _setup()
    truth = ws[0].truth
    ws = [_BareWorker(i, "collocated", truth, 10_000,
                      np.random.default_rng(i)) for i in range(3)]
    ws[0].deactivate(0.0)  # scaled in earlier; drained AND inactive
    for w in ws:
        _snap(mon, w, 0.01)
    acts = []
    for i in range(4):
        acts = sc.tick(10.0 + 1.1 * i, ws, [])
        if acts:
            break
    assert acts and acts[0].kind == "in"
    assert acts[0].worker_id != ws[0].wid


def test_pd_scale_in_never_picks_inactive_drained_worker():
    sc, mon, ws = _pd_setup()
    truth = ws[0].truth
    ws = [_BareWorker(i, "prefill", truth, 10_000,
                      np.random.default_rng(i)) for i in range(3)]
    ws += [_BareWorker(3, "decode", truth, 10_000,
                       np.random.default_rng(3))]
    ws[0].deactivate(0.0)
    for w in ws:
        _snap(mon, w, 0.01)
    acts = []
    for i in range(4):
        acts = sc.tick_pd(10.0 + 1.1 * i, ws, [], [])
        if any(a.kind == "in" for a in acts):
            break
    ins = [a for a in acts if a.kind == "in"]
    assert ins and all(a.worker_id != ws[0].wid for a in ins)


def test_pd_flip_guard_counts_active_workers_only():
    """A deactivated replica keeps its role; it must not inflate the
    pool-size guard and let the LAST active worker of a role flip."""
    sc, mon, ws = _pd_setup(n_prefill=2, n_decode=2)
    sc.cfg.min_workers = 1
    dead = [w for w in ws if w.role == "decode"][0]
    dead.deactivate(0.0)
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert all(a.kind != "role" for a in acts)


# -- warm pool + strategy selection --------------------------------------------


def test_warm_pool_depletes_and_replenishes():
    sc, mon, ws = _setup()
    d1, warm1 = sc.provision_delay(0.0, "d2d")
    assert warm1
    # pool (size 1) consumed: the next concurrent scale-out is cold
    d2, warm2 = sc.provision_delay(0.01, "d2d")
    assert not warm2
    assert d2 == pytest.approx(d1 + sc.tl.costs.runtime_warmup)
    # the replacement runtime matured: warm again
    d3, warm3 = sc.provision_delay(
        0.01 + sc.tl.costs.runtime_warmup + 1e-6, "d2d")
    assert warm3 and d3 == pytest.approx(d1)


def test_tick_scale_outs_consume_warm_pool():
    sc, mon, ws = _setup(max_workers=8)
    sc.cfg.tau = 0.1  # two scale-outs inside one runtime_warmup window
    for w in ws:
        _snap(mon, w, 0.99)
    a1 = sc.tick(10.0, ws, [])[0]
    a2 = sc.tick(10.2, ws, [])[0]
    assert a1.kind == a2.kind == "out"
    assert a1.warm and not a2.warm
    assert a2.delay > a1.delay


def test_choose_strategy_scale_from_zero_falls_back_to_disk():
    sc, mon, ws = _setup()
    assert sc.choose_strategy(has_donor=True) == "d2d"
    assert sc.choose_strategy(has_donor=False) == "disk"


def test_tick_scale_from_zero_uses_disk():
    """No active replica -> no live donor -> the scale-out action
    carries the disk transport."""
    sc, mon, ws = _setup()
    for w in ws:
        w.deactivate(0.0)
    acts = sc.tick(10.0, ws, [])
    assert acts and acts[0].kind == "out"
    assert acts[0].strategy == "disk"


def test_auto_strategy_tracks_measured_costs():
    sc, mon, ws = _setup()
    sc.cfg = ScalerConfig(weight_strategy="auto")
    assert sc.choose_strategy(has_donor=True) == "d2d"  # analytic prior
    assert sc.choose_strategy(has_donor=False) in ("cpu", "disk")
    # observed transfers invert the ordering: cpu measured far faster
    nbytes = sc.model_cfg.param_count() * 2
    sc.tl.observe_weight_load("cpu", nbytes, 1e-3)
    sc.tl.observe_weight_load("d2d", nbytes, 10.0)
    assert sc.choose_strategy(has_donor=True) == "cpu"
