"""Algorithm 3: thresholds, sustained scale-in, role transitions."""

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.latency_model import AnalyticLatencyModel
from repro.core.monitor import Monitor, WorkerSnapshot
from repro.core.request import Request
from repro.core.scaler import Scaler, ScalerConfig
from repro.core.tlmanager import TLManager
from repro.serving.worker import SimWorker


def _setup(max_workers=4):
    cfg = get_config("qwen7b")
    mon = Monitor(0.05)
    tl = TLManager()
    sc = Scaler(ScalerConfig(tau=1.0, max_workers=max_workers), mon, tl,
                cfg)
    truth = AnalyticLatencyModel(cfg)
    ws = [SimWorker(i, "collocated", truth, 10_000,
                    np.random.default_rng(i)) for i in range(2)]
    return sc, mon, ws


def _snap(mon, w, util, t=0.0):
    mon.snapshots[w.wid] = WorkerSnapshot(
        wid=w.wid, role=w.role, time=t, busy=util > 0,
        n_waiting=0, n_running=0, kv_tokens=0, cur_lens=(),
        waiting_tokens=0, utilization=util,
    )


def _req(rid, arrival, ttft=0.7):
    return Request(rid=rid, task="t", arrival=arrival, l_in=10, l_out=5,
                   ttft_slo=ttft, tpot_slo=0.5)


def test_scale_out_on_high_load():
    sc, mon, ws = _setup()
    for w in ws:
        _snap(mon, w, 0.99)
    acts = sc.tick(10.0, ws, [])
    assert acts and acts[0].kind == "out"
    assert acts[0].delay > 0  # provisioning is not free


def test_scale_out_on_queue_wait():
    sc, mon, ws = _setup()
    for w in ws:
        _snap(mon, w, 0.1)
    # a request far past its TTFT drives the wait term
    acts = sc.tick(10.0, ws, [_req(0, arrival=0.0, ttft=0.5)])
    assert acts and acts[0].kind == "out"


def test_scale_in_requires_sustained_low_load():
    sc, mon, ws = _setup()
    for w in ws:
        _snap(mon, w, 0.01)
    t = 10.0
    acts = sc.tick(t, ws, [])
    assert not acts  # 1st low tick
    acts = sc.tick(t + 1.1, ws, [])
    assert not acts  # 2nd
    acts = sc.tick(t + 2.2, ws, [])
    assert acts and acts[0].kind == "in"


def test_max_workers_cap():
    sc, mon, ws = _setup(max_workers=2)
    for w in ws:
        _snap(mon, w, 0.99)
    assert sc.tick(10.0, ws, []) == []


def test_pd_role_transition_preferred():
    sc, mon, ws = _setup(max_workers=8)
    ws[0].role = "prefill"
    ws[1].role = "decode"
    extra = SimWorker(2, "decode", ws[0].truth, 10_000,
                      np.random.default_rng(9))
    ws.append(extra)
    _snap(mon, ws[0], 0.99)
    _snap(mon, ws[1], 0.01)
    _snap(mon, ws[2], 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, 0.0, ttft=0.2)], [])
    assert acts and acts[0].kind == "role"
    assert acts[0].role == "prefill"


def _pd_setup(max_workers=8, n_prefill=2, n_decode=2):
    sc, mon, ws = _setup(max_workers=max_workers)
    truth = ws[0].truth
    ws = [SimWorker(i, "prefill", truth, 10_000,
                    np.random.default_rng(i)) for i in range(n_prefill)]
    ws += [SimWorker(n_prefill + i, "decode", truth, 10_000,
                     np.random.default_rng(100 + i))
           for i in range(n_decode)]
    return sc, mon, ws


def test_pd_flip_decode_to_prefill_on_queue_imbalance():
    """Prefill pool hot (queue wait past TTFT), decode pool idle: an
    idle decode worker flips instead of provisioning a new instance."""
    sc, mon, ws = _pd_setup()
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert len(acts) == 1 and acts[0].kind == "role"
    assert acts[0].role == "prefill"
    assert acts[0].worker_id in {w.wid for w in ws if w.role == "decode"}
    assert acts[0].delay == sc.cfg.role_transition_time
    assert sc.n_role_flips == 1


def test_pd_flip_prefill_to_decode_on_decode_pressure():
    """The symmetric direction: decode hot, prefill idle."""
    sc, mon, ws = _pd_setup()
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "decode" else 0.01)
    acts = sc.tick_pd(10.0, ws, [], [_req(0, arrival=0.0, ttft=0.2)])
    assert len(acts) == 1 and acts[0].kind == "role"
    assert acts[0].role == "decode"
    assert acts[0].worker_id in {w.wid for w in ws if w.role == "prefill"}


def test_pd_flip_only_drained_workers():
    """Drain-and-flip: a worker still holding queued/running work is
    never flipped — the scaler scales out instead."""
    sc, mon, ws = _pd_setup()
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    for w in ws:
        if w.role == "decode":
            w.running.append(_req(50 + w.wid, arrival=0.0))
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert all(a.kind != "role" for a in acts)
    assert any(a.kind == "out" and a.role == "prefill" for a in acts)


def test_pd_flip_blocked_by_parked_kv():
    """A prefill worker whose requests await migration (parked KV
    resident) has not drained: flipping it would strand the pages."""
    sc, mon, ws = _pd_setup(n_prefill=2, n_decode=2)
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "decode" else 0.01)
    for w in ws:
        if w.role == "prefill":
            w.parked.append(_req(50 + w.wid, arrival=0.0))
    acts = sc.tick_pd(10.0, ws, [], [_req(0, arrival=0.0, ttft=0.2)])
    assert all(a.kind != "role" for a in acts)


def test_pd_flip_respects_min_pool_size():
    """A pool never flips below min_workers even when idle."""
    sc, mon, ws = _pd_setup(n_prefill=2, n_decode=1)
    sc.cfg.min_workers = 1
    for w in ws:
        _snap(mon, w, 0.99 if w.role == "prefill" else 0.01)
    acts = sc.tick_pd(10.0, ws, [_req(0, arrival=0.0, ttft=0.2)], [])
    assert all(a.kind != "role" for a in acts)


def test_fast_scaling_delay_smaller_than_disk():
    sc, mon, ws = _setup()
    d2d = sc.provision_delay(True)
    sc.cfg = ScalerConfig(weight_strategy="disk")
    disk = sc.provision_delay(True)
    assert d2d < disk
