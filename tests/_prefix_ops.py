"""Shared op-interpreter for the refcounted prefix-sharing property
test — used by the hypothesis test and the seeded-fuzz fallback in
``test_prefix_cache.py``."""

import numpy as np

from repro.serving.kv_manager import PagedKVManager
from repro.serving.prefix_cache import PrefixCache

N_SLOTS, MAX_LEN, PS = 4, 24, 4


def run_prefix_ops(ops):
    """Apply (kind, slot, group, length) ops to a scarce-pool paged KV
    manager with an attached prefix cache, asserting the sharing
    invariants after every op:

    - the allocator's free and owned sets partition the pool,
    - every cached page is allocator-owned,
    - a cached page's refcount equals the number of live slot tables
      holding it,
    - only refs-0 pages sit on the reclaimable (LRU) list,
    - retiring everything and evicting reclaims the whole pool.
    """
    kv = PagedKVManager(N_SLOTS, MAX_LEN, PS,
                        n_pages=N_SLOTS * 4)   # scarce: forces evict
    pc = PrefixCache(kv.alloc, PS)
    kv.attach_prefix_cache(pc)
    live = {}   # slot -> prompt
    for kind, slot, g, n in ops:
        if kind == "start" and slot not in live:
            prompt = (1000 * g + np.arange(n)).astype(np.int32)
            kv.lookup_prefix(slot, prompt)
            if kv.ensure(slot, n):
                live[slot] = prompt
            else:
                kv.release(slot)     # derefs the hit span
        elif kind == "publish" and slot in live:
            kv.publish_prefix(slot, live[slot])
        elif kind == "retire" and slot in live:
            kv.release(slot)
            del live[slot]
        elif kind == "evict":
            pc.evict(n)
        # invariants
        owned = set(kv.alloc._owner)
        free = set(kv.alloc._free)
        assert not (owned & free)
        assert owned | free == set(range(kv.n_pages))
        tables = {s: set(kv.pages_of(s)) for s in live}
        for p, (_, refs) in pc._entries.items():
            assert p in owned
            assert refs == sum(p in t for t in tables.values())
        assert all(pc.refs(p) == 0 for p in pc._lru)
    for s in list(live):
        kv.release(s)
    assert all(refs == 0 for _, refs in pc._entries.values())
    pc.evict(kv.n_pages)
    assert pc.n_cached == 0
    assert kv.alloc.n_free == kv.n_pages
