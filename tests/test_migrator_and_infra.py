"""Migrator, slot manager, sharding plans, roofline parser, workload."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, get_smoke_config
from repro.core.latency_model import AnalyticLatencyModel
from repro.core.migrator import Migrator
from repro.core.monitor import Monitor
from repro.core.request import FOUR_TASK_SET, Request
from repro.core.tlmanager import TLManager
from repro.launch.roofline import (
    is_baseline,
    nondefault_options,
)
from repro.models import build_model
from repro.serving.kv_manager import SlotManager, clear_rows, insert_rows
from repro.serving.worker import SimWorker
from repro.serving.workload import poisson_workload


# -- Migrator ---------------------------------------------------------------

def _decode_worker(wid, truth, kv=100_000):
    return SimWorker(wid, "decode", truth, kv, np.random.default_rng(0),
                     noise=0.0)


def _prefilled(rid, l_in=100, tpot=0.5):
    r = Request(rid=rid, task="t", arrival=0.0, l_in=l_in, l_out=20,
                ttft_slo=1.0, tpot_slo=tpot)
    r.prefill_worker = 0
    r.first_token_time = 0.1
    r.tokens_done = 1
    return r


def _migrator(cfg_name="qwen7b"):
    cfg = get_config(cfg_name)
    truth = AnalyticLatencyModel(cfg)
    return Migrator(truth, Monitor(0.05), TLManager(), cfg), truth


def test_migrator_assigns_to_least_pressured_worker():
    mig, truth = _migrator()
    w1 = _decode_worker(1, truth)
    w2 = _decode_worker(2, truth)
    # preload w1 with a heavy decode batch
    for i in range(40):
        q = _prefilled(100 + i, l_in=400)
        q.decode_worker = 1
        w1.running.append(q)
    r = _prefilled(0)
    mig.on_prefill_complete(r)
    moves = mig.migrate_pass(1.0, [w1, w2])
    assert len(moves) == 1
    assert moves[0][1].wid == 2  # most slack
    assert moves[0][2] > 0       # KV transfer takes time
    assert r.decode_worker == 2


def test_migrator_defers_when_tpot_would_break():
    mig, truth = _migrator()
    w = _decode_worker(1, truth)
    # batch so large that E_d exceeds the tightest TPOT
    for i in range(400):
        q = _prefilled(100 + i, l_in=2000)
        w.running.append(q)
    r = _prefilled(0, tpot=0.05)
    mig.on_prefill_complete(r)
    moves = mig.migrate_pass(1.0, [w])
    assert moves == []
    assert mig.pending() == 1  # stays queued for a later pass


def test_migrator_respects_kv_capacity():
    mig, truth = _migrator()
    w = _decode_worker(1, truth, kv=50)
    r = _prefilled(0, l_in=100)
    mig.on_prefill_complete(r)
    assert mig.migrate_pass(1.0, [w]) == []


def test_migrator_transfer_time_scales_with_prompt():
    mig, truth = _migrator()
    w = _decode_worker(1, truth)
    a, b = _prefilled(0, l_in=50), _prefilled(1, l_in=5000)
    mig.on_prefill_complete(a)
    mig.on_prefill_complete(b)
    moves = dict()
    for r, _, t in mig.migrate_pass(1.0, [w]):
        moves[r.rid] = t
    assert moves[1] > moves[0] * 10


def test_migrator_charges_inflight_reservations_to_destination():
    """Destination-overcommit regression: requests whose transfer is
    scheduled but not landed are invisible in running/waiting, so
    without the ReservationLedger successive selections pile every
    simultaneous prefill onto one destination past its KV capacity."""
    mig, truth = _migrator()
    w1 = _decode_worker(1, truth, kv=2000)
    w2 = _decode_worker(2, truth, kv=2000)
    # four simultaneously-prefilled prompts, each ~half a worker's KV;
    # TPOT loose enough that only capacity can discriminate
    reqs = [_prefilled(i, l_in=900, tpot=10.0) for i in range(4)]
    for r in reqs:
        mig.on_prefill_complete(r)
    moves = mig.migrate_pass(1.0, [w1, w2])
    # all four must be placed (2000*2 of capacity for 3600 of KV)...
    assert len(moves) == 4
    placed: dict[int, int] = {}
    for r, w, _ in moves:
        placed[w.wid] = placed.get(w.wid, 0) + r.cur_len
    # ...and no destination may be promised more KV than it has —
    # pre-fix every pick reads kv_tokens()==0 and all 3600 land on one
    for wid, tok in placed.items():
        assert tok <= 2000, f"worker {wid} overcommitted: {tok} tokens"
    assert len(placed) == 2  # genuinely spread, not shoehorned


def test_migrator_reservation_released_on_landing():
    mig, truth = _migrator()
    w1 = _decode_worker(1, truth, kv=2000)
    r = _prefilled(0, l_in=900, tpot=10.0)
    mig.on_prefill_complete(r)
    (rr, w, _), = mig.migrate_pass(1.0, [w1])
    assert mig.ledger.tokens(w1.wid) == r.cur_len
    # the cluster releases at kv_ready; after that the charge is gone
    # and the same rid can be re-reserved without double-counting
    assert mig.ledger.release(rr.rid) == w1.wid
    assert mig.ledger.tokens(w1.wid) == 0
    assert mig.ledger.release(rr.rid) is None  # idempotent


def test_migrator_config_not_shared_across_instances():
    """cfg=MigratorConfig() evaluated in the signature would be ONE
    object shared by every instance — mutating one migrator's knobs
    must never leak into another's."""
    a, _ = _migrator()
    b, _ = _migrator()
    assert a.cfg is not b.cfg
    a.cfg.headroom = 0.123
    assert b.cfg.headroom != 0.123


def test_dispatcher_config_not_shared_across_instances():
    from repro.core.dispatcher import Dispatcher
    from repro.core.latency_model import FittedLatencyModel

    def mk():
        return Dispatcher(FittedLatencyModel(), Monitor(0.05))

    a, b = mk(), mk()
    assert a.cfg is not b.cfg
    a.cfg.default_ttft = 99.0
    assert b.cfg.default_ttft != 99.0


def test_measured_kv_bytes_resolves_deactivated_and_explicit_source():
    """_measured_kv_bytes must resolve through the _by_wid index (a
    deactivated source's KV stays resident until the transfer lands)
    and honor an explicit live-migration source wid."""
    from repro.serving.cluster import Cluster, ClusterConfig

    c = Cluster(ClusterConfig(model=get_config("qwen7b"), n_workers=2,
                              policy="rr"))
    r = _prefilled(0)
    r.prefill_worker = 0
    c._by_wid[0].kv_payload_bytes = lambda q: 111.0
    c._by_wid[1].kv_payload_bytes = lambda q: 222.0
    assert c._measured_kv_bytes(r) == 111.0
    assert c._measured_kv_bytes(r, src=1) == 222.0
    # deactivation must not make the measurement silently fall back
    c._by_wid[0].deactivate(0.0)
    assert c._measured_kv_bytes(r) == 111.0


# -- SlotManager / cache row surgery ----------------------------------------

def test_slot_manager_alloc_free_cycle():
    sm = SlotManager(2)
    a, b = sm.alloc("ra"), sm.alloc("rb")
    assert {a, b} == {0, 1} and sm.alloc() is None
    sm.free(a)
    assert sm.n_free == 1 and sm.alloc("rc") == a


def test_insert_and_clear_rows_roundtrip():
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    full = model.init_cache(4, 16)
    axes = model.cache_axes()
    part = model.init_cache(2, 16)
    part = jax.tree.map(lambda a: jnp.ones_like(a), part)
    out = insert_rows(full, part, axes, slots=[1, 3])
    k = out[0]["k"]
    assert float(jnp.sum(jnp.abs(k[:, 0]))) == 0.0
    assert float(jnp.min(k[:, 1])) == 1.0
    assert float(jnp.min(k[:, 3])) == 1.0
    wiped = clear_rows(out, axes, [1])
    assert float(jnp.sum(jnp.abs(wiped[0]["k"][:, 1]))) == 0.0
    assert float(jnp.min(wiped[0]["k"][:, 3])) == 1.0
    # pos rows clear to -1 (int sentinel)
    assert int(jnp.max(wiped[0]["pos"][:, 1])) == -1


# -- sharding plans -----------------------------------------------------------

def test_plan_arch_decisions():
    import os
    import subprocess
    import sys
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.configs import get_config
from repro.distributed.sharding import plan_arch
from repro.launch.mesh import make_production_mesh
mesh = make_production_mesh()
checks = {
    "command-r-plus-104b": dict(heads_sharded=True, kv_repeat=2,
                                kv_sharded=True, vocab_pad=0),
    "gemma3-4b": dict(heads_sharded=False, kv_repeat=1,
                      kv_sharded=False, vocab_pad=0),
    "qwen2.5-14b": dict(heads_sharded=False, kv_repeat=1),
    "olmoe-1b-7b": dict(heads_sharded=True, kv_repeat=1,
                        kv_sharded=True),
    "mamba2-2.7b": dict(vocab_pad=(-50280) % 16),
}
for arch, want in checks.items():
    plan = plan_arch(get_config(arch), mesh)
    for k, v in want.items():
        assert plan[k] == v, (arch, k, plan)
print("OK")
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-1500:]


# -- roofline reader -----------------------------------------------------------

def test_roofline_baseline_detection():
    assert is_baseline({"options": {"fsdp": True, "compress": False}})
    assert not is_baseline({"options": {"fsdp": False}})
    assert nondefault_options({"q_chunk": 512, "pad_heads": 8}) == {
        "pad_heads": 8
    }


def test_collective_parser():
    from repro.launch.dryrun import parse_collectives
    hlo = """
  %ar = bf16[8,128]{1,0} all-reduce(bf16[8,128]{1,0} %x), replica_groups={}
  %ag.1 = f32[16,64]{1,0} all-gather(f32[8,64]{1,0} %y), dimensions={0}
  %nop = f32[4]{0} add(f32[4]{0} %a, f32[4]{0} %b)
"""
    c = parse_collectives(hlo)
    assert c["all-reduce"]["count"] == 1
    assert c["all-reduce"]["bytes"] == 8 * 128 * 2
    assert c["all-gather"]["bytes"] == 16 * 64 * 4
    # ring all-reduce counts 2x on the link
    assert c["link_bytes"] == 2 * 8 * 128 * 2 + 16 * 64 * 4


# -- workload statistics --------------------------------------------------------

def test_poisson_rate_and_determinism():
    reqs = poisson_workload(FOUR_TASK_SET, qps=40.0, n_per_task=200,
                            seed=5)
    span = max(r.arrival for r in reqs)
    rate = len(reqs) / span
    assert 32 < rate < 48  # within ~20% of nominal
    again = poisson_workload(FOUR_TASK_SET, qps=40.0, n_per_task=200,
                             seed=5)
    assert [r.arrival for r in reqs] == [r.arrival for r in again]
    assert all(r.l_in >= 1 and r.l_out >= 1 for r in reqs)


def test_every_assigned_arch_has_analytic_model():
    for name in ASSIGNED_ARCHS:
        m = AnalyticLatencyModel(get_config(name))
        assert m.prefill_time([128]) > 0
        assert m.decode_step_time([128]) > 0
