"""Live decode-to-decode migration (PR 8).

Unit coverage for the unified instance-load signal (InstanceLoadCalculator
+ ReservationLedger), the MigrationCoordinator's victim/destination
pairing, the Scaler's evacuation-aware target choice, the Cluster's
kv_ready race handling (destination vanished mid-transfer, source
scaled in, request finished in flight), sim-plane migrate-then-scale-in
end to end, and engine-plane token identity for a request migrated
twice and for a cluster-level evacuation.
"""

import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.instance_load import (
    InstanceLoadCalculator,
    ReservationLedger,
)
from repro.core.latency_model import AnalyticLatencyModel
from repro.core.migrator import MigrationConfig, MigrationCoordinator
from repro.core.monitor import Monitor
from repro.core.request import Request, RequestState
from repro.core.scaler import ScaleAction, Scaler, ScalerConfig
from repro.core.tlmanager import TLManager
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.session import ServingSession
from repro.serving.worker import SimWorker

QWEN = get_config("qwen7b")
TRUTH = AnalyticLatencyModel(QWEN)


def _decode_worker(wid, kv=1_000_000):
    return SimWorker(wid, "decode", TRUTH, kv, np.random.default_rng(0),
                     noise=0.0)


def _decoding(rid, l_in=200, l_out=40, tokens_done=5, tpot=0.5, wid=1):
    r = Request(rid=rid, task="t", arrival=0.0, l_in=l_in, l_out=l_out,
                ttft_slo=2.0, tpot_slo=tpot)
    r.prefill_worker = wid
    r.decode_worker = wid
    r.first_token_time = 0.1
    r.tokens_done = tokens_done
    r.state = RequestState.DECODING
    return r


# ---------------------------------------------------------------------------
# ReservationLedger
# ---------------------------------------------------------------------------

def test_ledger_reserve_release_and_move():
    led = ReservationLedger()
    r = _decoding(0, l_in=100, tokens_done=10)
    led.reserve(3, r)
    assert led.tokens(3) == r.cur_len
    assert led.lens(3) == [r.cur_len]
    assert led.tpots(3) == [r.tpot_slo]
    assert led.dst_of(0) == 3 and led.n_inflight(3) == 1
    # re-reserving moves the charge, never double-counts
    led.reserve(5, r)
    assert led.tokens(3) == 0 and led.tokens(5) == r.cur_len
    assert led.release(0) == 5
    assert led.tokens(5) == 0
    assert led.release(0) is None  # idempotent


def test_request_queue_readd_after_remove():
    """Regression: remove() tombstones the rid; a later add() of the
    same request (migration destination vanished -> requeue) must make
    it visible again, exactly once."""
    from repro.core.queues import RequestPriorityQueue

    q = RequestPriorityQueue()
    r = _decoding(0)
    q.add(r)
    q.remove(r)
    assert len(q) == 0
    q.add(r)
    assert len(q) == 1
    assert list(q.scan()) == [r]


# ---------------------------------------------------------------------------
# InstanceLoadCalculator
# ---------------------------------------------------------------------------

def test_load_zero_when_idle_and_monotone_in_batch():
    lc = InstanceLoadCalculator(TRUTH)
    w = _decode_worker(1)
    assert lc.load(w) == 0.0
    loads = []
    for i in range(3):
        w.running.append(_decoding(i, l_in=2000))
        loads.append(lc.load(w))
    assert loads == sorted(loads)
    assert loads[0] > 0.0


def test_pressure_exceeds_one_on_predicted_tpot_miss():
    lc = InstanceLoadCalculator(TRUTH)
    w = _decode_worker(1)
    w.running.append(_decoding(0, l_in=2000, tpot=0.05))
    for i in range(1, 500):
        if lc.pressure(w) > 1.0:
            break
        w.running.append(_decoding(i, l_in=2000, tpot=10.0))
    assert lc.pressure(w) > 1.0
    # the miss is localized to the tight request: risk stays partial
    assert 0.0 < lc.slo_risk(w) < 1.0


def test_reservations_raise_load_before_landing():
    led = ReservationLedger()
    lc = InstanceLoadCalculator(TRUTH, ledger=led)
    w = _decode_worker(1)
    w.running.append(_decoding(0, l_in=500))
    before = lc.load(w)
    led.reserve(w.wid, _decoding(9, l_in=2000))
    assert lc.load(w) > before
    led.release(9)
    assert lc.load(w) == before


# ---------------------------------------------------------------------------
# Scaler target choice
# ---------------------------------------------------------------------------

class _StubWorker:
    def __init__(self, wid, drained, load=0.0, active=True,
                 evacuating=False):
        self.wid = wid
        self.active = active
        self.evacuating = evacuating
        self._drained = drained
        self.load = load

    def is_drained(self):
        return self._drained


class _StubLoad:
    def load(self, w):
        return w.load


def _scaler(evacuate):
    return Scaler(ScalerConfig(), Monitor(0.05), TLManager(), QWEN,
                  load_calc=_StubLoad(), evacuate=evacuate)


def test_scale_target_prefers_drained_workers():
    s = _scaler(evacuate=True)
    ws = [_StubWorker(0, drained=False, load=0.1),
          _StubWorker(1, drained=True, load=5.0)]
    assert s._scale_target(ws).wid == 1  # drained wins despite load


def test_scale_target_evacuates_least_loaded_when_none_drained():
    ws = [_StubWorker(0, drained=False, load=2.0),
          _StubWorker(1, drained=False, load=0.5)]
    assert _scaler(evacuate=True)._scale_target(ws).wid == 1
    # without live migration a loaded worker is never targeted
    assert _scaler(evacuate=False)._scale_target(ws) is None


def test_scale_target_skips_evacuating_and_inactive():
    s = _scaler(evacuate=True)
    ws = [_StubWorker(0, drained=False, load=0.1, evacuating=True),
          _StubWorker(1, drained=False, load=9.0),
          _StubWorker(2, drained=True, active=False)]
    assert s._scale_target(ws).wid == 1
    assert len(s._committed(ws)) == 1


# ---------------------------------------------------------------------------
# MigrationCoordinator planning
# ---------------------------------------------------------------------------

def _coordinator(**kw):
    lc = InstanceLoadCalculator(TRUTH)
    return MigrationCoordinator(lc, TRUTH, TLManager(), QWEN,
                                cfg=MigrationConfig(**kw)), lc


def test_rescue_sheds_loosest_tpot_victims_to_cold_worker():
    coord, lc = _coordinator(max_moves=8)
    hot, cold = _decode_worker(1), _decode_worker(2)
    # tight-but-feasible alone: pressure must come from the batch, so
    # shedding the LOOSE requests is what restores the budget
    hot.running.append(_decoding(0, l_in=2000, tpot=0.1, wid=1))
    assert lc.pressure(hot) <= 1.0
    i = 1
    while lc.pressure(hot) <= 1.0:
        hot.running.append(_decoding(i, l_in=2000, tpot=10.0, wid=1))
        i += 1
    moves = coord.plan(1.0, [hot, cold])
    assert moves and all(reason == "rescue" for *_, reason in moves)
    for r, src, dst, t_x, _ in moves:
        assert src.wid == 1 and dst.wid == 2 and t_x > 0
        assert r.tpot_slo == 10.0  # never the tight request itself
        assert r.migrating
    assert coord.n_rescues == len(moves)
    # every planned move is charged to the destination up front
    assert coord.ledger.n_inflight(2) == len(moves)


def test_evacuation_moves_only_movable_residents():
    coord, _ = _coordinator()
    src, dst = _decode_worker(1), _decode_worker(2)
    ok = _decoding(0, wid=1)
    nearly_done = _decoding(1, l_out=10, tokens_done=8, wid=1)
    cooling = _decoding(2, wid=1)
    cooling.last_migrated = 0.95  # landed just before the pass
    src.running += [ok, nearly_done, cooling]
    src.evacuating = True
    moves = coord.plan(1.0, [src, dst])
    assert [m[0].rid for m in moves] == [0]
    assert moves[0][4] == "evac"
    assert coord.n_evacuations == 1
    assert not nearly_done.migrating and not cooling.migrating


def test_no_destination_no_move():
    coord, _ = _coordinator()
    src = _decode_worker(1)
    src.running.append(_decoding(0, wid=1))
    src.evacuating = True
    evac_dst = _decode_worker(2)
    evac_dst.evacuating = True   # both emptying: nowhere to go
    assert coord.plan(1.0, [src, evac_dst]) == []


# ---------------------------------------------------------------------------
# Cluster kv_ready races (sim plane)
# ---------------------------------------------------------------------------

def _pd_cluster(n_decode=2):
    return Cluster(ClusterConfig(model=QWEN, policy="hyperflexis",
                                 mode="pd", n_prefill=1,
                                 n_decode=n_decode, seed=0))


def _drive(c, reqs, on_event=None, max_events=200_000):
    s = ServingSession(c, admission="none")
    for r in reqs:
        s.submit_request(r)
    for _ in range(max_events):
        kind = c.process_next()
        if kind is None:
            break
        if on_event is not None:
            on_event(kind)
        if (all(r.state == RequestState.FINISHED for r in reqs)
                and not c._evac):
            break
    return s.close(requests=list(reqs))


def test_kv_ready_requeues_when_destination_vanished():
    """Destination scaled in mid-transfer: the request must be
    requeued with its stale decode_worker cleared, then land on the
    surviving decode worker and finish."""
    c = _pd_cluster(n_decode=2)
    r = Request(rid=0, task="t", arrival=0.0, l_in=200, l_out=16,
                ttft_slo=5.0, tpot_slo=1.0)
    killed = []

    def on_event(kind):
        if (not killed and r.migrate_ready is not None
                and r.decode_worker is not None
                and r.tokens_done <= 1):
            # transfer scheduled, not landed: kill the destination now
            c._by_wid[r.decode_worker].deactivate(c.now)
            killed.append(r.decode_worker)

    res = _drive(c, [r], on_event)
    assert killed, "migration never got scheduled"
    assert r.state == RequestState.FINISHED
    assert len(res.requests) == 1
    assert r.decode_worker is not None and r.decode_worker != killed[0]
    assert r.n_migrations == 1  # only the landed move counts


def test_kv_ready_survives_source_and_destination_scale_in():
    """Source AND first destination both scaled in mid-transfer: the
    parked KV stays with the (deactivated) source until a transfer
    lands, and the request still finishes on the survivor."""
    c = _pd_cluster(n_decode=2)
    r = Request(rid=0, task="t", arrival=0.0, l_in=200, l_out=16,
                ttft_slo=5.0, tpot_slo=1.0)
    killed = []

    def on_event(kind):
        if (not killed and r.migrate_ready is not None
                and r.decode_worker is not None
                and r.tokens_done <= 1):
            c._by_wid[r.decode_worker].deactivate(c.now)
            c._by_wid[r.prefill_worker].deactivate(c.now)
            killed.append(r.decode_worker)

    _drive(c, [r], on_event)
    assert killed
    assert r.state == RequestState.FINISHED
    assert r.decode_worker not in (killed[0], r.prefill_worker)


def test_kv_ready_noops_when_request_finished_in_flight():
    """A live-migration source keeps decoding during the transfer; if
    the stream finishes first, the landing must release the
    reservation and move nothing."""
    c = Cluster(ClusterConfig(model=QWEN, policy="rr", n_workers=2,
                              live_migration=True, seed=0))
    r = _decoding(0, wid=0)
    r.state = RequestState.FINISHED
    r.migrating = True
    dst = c._by_wid[1]
    c._mig_ledger.reserve(1, r)
    c._handle("kv_ready", (r, 1, 0), 1.0)
    assert not r.migrating
    assert c._mig_ledger.dst_of(0) is None
    assert r not in dst.running
    assert c.n_live_migrations == 0


# ---------------------------------------------------------------------------
# Sim plane: migrate-then-scale-in end to end
# ---------------------------------------------------------------------------

def test_sim_evacuation_scale_in_commits_after_migrating_residents():
    c = Cluster(ClusterConfig(model=QWEN, policy="rr", n_workers=2,
                              live_migration=True, seed=0))
    reqs = [Request(rid=i, task="t", arrival=0.0, l_in=400, l_out=96,
                    ttft_slo=4.0, tpot_slo=0.2) for i in range(8)]
    kicked = []

    def on_event(kind):
        if not kicked and c.now > 0.3:
            c._begin_evacuation(
                c._by_wid[0],
                ScaleAction("in", "collocated", 0.0, worker_id=0),
                c.now,
            )
            kicked.append(True)

    res = _drive(c, reqs, on_event)
    assert res.metrics.n_finished == len(reqs)
    assert res.n_live_migrations > 0
    assert res.n_evacuations > 0
    assert res.metrics.n_migrated > 0
    w0 = c._by_wid[0]
    assert not w0.active and not w0.evacuating and not c._evac
    events = [ev for _, wid, ev in c.timeline if wid == 0]
    assert any(ev.startswith("evacuate:in") for ev in events)
    assert "scale_in" in events
    # the scale-in committed only after the evacuation began
    assert events.index("scale_in") > 0


def test_sim_evacuation_begin_is_idempotent():
    c = Cluster(ClusterConfig(model=QWEN, policy="rr", n_workers=2,
                              live_migration=True, seed=0))
    w0 = c._by_wid[0]
    w0.running.append(_decoding(0, wid=0))
    a = ScaleAction("in", "collocated", 0.0, worker_id=0)
    c._begin_evacuation(w0, a, 1.0)
    c._begin_evacuation(w0, a, 1.0)
    assert list(c._evac) == [0]
    n_events = sum(1 for _, wid, ev in c.timeline
                   if wid == 0 and ev.startswith("evacuate:"))
    assert n_events == 1


# ---------------------------------------------------------------------------
# Engine plane: token identity across repeated live migration
# ---------------------------------------------------------------------------

from repro.models import build_model                       # noqa: E402
from repro.serving.engine import EngineConfig, InferenceEngine  # noqa: E402

SMOKE = get_smoke_config("qwen7b")
_MODEL = None
_PARAMS = None
_FN_CACHE: dict = {}


def _engine(page_size=8, chunk_size=16, n_slots=4, max_len=64):
    global _MODEL, _PARAMS
    if _MODEL is None:
        import jax

        _MODEL = build_model(SMOKE)
        _PARAMS = _MODEL.init(jax.random.key(0))
    return InferenceEngine(
        _MODEL, _PARAMS,
        EngineConfig(n_slots=n_slots, max_len=max_len, prefill_batch=2,
                     page_size=page_size, chunk_size=chunk_size,
                     decode_block=1),   # per-token steps: precise
        fn_cache=_FN_CACHE,             # mid-stream checkpoints
    )


def _req(rid=0, l_in=20, max_new=8):
    prompt = (np.arange(l_in, dtype=np.int32) * 7 + rid) % SMOKE.vocab_size
    return Request.from_prompt(rid, prompt.astype(np.int32), max_new)


@pytest.mark.parametrize("page_size", [4, 8])
def test_double_migration_token_identity(page_size):
    """A -> B -> C mid-decode: a request checkpointed and moved TWICE
    still bit-matches the unmigrated stream."""
    base = _engine(page_size=page_size)
    want_r = _req()
    base.submit(want_r)
    base.run_until_done()
    want = want_r.generated
    assert len(want) == 8

    a = _engine(page_size=page_size)
    r = _req()
    a.submit(r)
    while len(r.generated) < 2:
        a.step()
    p1 = a.export_kv(r.rid)
    a.evict(r.slot)
    b = _engine(page_size=page_size)
    assert b.import_kv(p1, r)
    while len(r.generated) < 5:
        b.step()
    p2 = b.export_kv(r.rid)
    assert p2.n_tokens > p1.n_tokens  # newest tokens travel too
    b.evict(r.slot)
    c = _engine(page_size=page_size)
    assert c.import_kv(p2, r)
    c.run_until_done()
    assert r.generated == want
    assert r.state == RequestState.FINISHED


def test_engine_cluster_evacuation_token_identity():
    """Cluster-level migrate-then-scale-in on the REAL engine plane:
    evacuating a collocated engine mid-run moves live paged KV and the
    evacuated streams stay bit-identical to an undisturbed run."""
    ecfg = EngineConfig(n_slots=4, max_len=64, prefill_batch=2,
                        page_size=8, chunk_size=16, decode_block=2)

    def cfg(**kw):
        return ClusterConfig(model=SMOKE, backend="engine",
                             policy="rr", n_workers=2, seed=0,
                             engine=ecfg, **kw)

    def wl():
        rng = np.random.default_rng(0)
        reqs, t = [], 0.0
        for i in range(6):
            t += float(rng.exponential(0.02))
            reqs.append(Request(rid=i, task="chat", arrival=t,
                                l_in=int(rng.integers(8, 16)), l_out=16,
                                ttft_slo=5.0, tpot_slo=2.0))
        return reqs

    base = wl()
    Cluster(cfg()).run(base)
    want = [r.generated for r in base]
    assert all(len(g) == 16 for g in want)

    reqs = wl()
    c = Cluster(cfg(live_migration=True))
    c._materialize_prompts(reqs)
    kicked = []

    def on_event(kind):
        w0 = c._by_wid[0]
        if not kicked and any(r.tokens_done >= 1 for r in w0.running):
            c._begin_evacuation(
                w0, ScaleAction("in", "collocated", 0.0, worker_id=0),
                c.now,
            )
            kicked.append(True)

    res = _drive(c, reqs, on_event)
    assert kicked, "worker 0 never had a decoding resident"
    assert res.metrics.n_finished == len(reqs)
    assert res.n_live_migrations >= 1
    assert not c._by_wid[0].active
    assert [r.generated for r in reqs] == want
    # the moved requests really decoded on both workers
    moved = [r for r in reqs if r.n_migrations > 0]
    assert moved and all(r.decode_worker == 1 for r in moved)
