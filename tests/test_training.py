"""Training substrate: optimizer, loop, checkpoint, crash/resume,
gradient compression math."""

import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.distributed.checkpoint import (
    all_steps,
    latest_step,
    load_checkpoint,
    load_latest,
    save_checkpoint,
)
from repro.distributed.fault_tolerance import (
    InjectedFailure,
    RunnerConfig,
    TrainRunner,
)
from repro.models import build_model
from repro.training.data import DataConfig, make_batch
from repro.training.optimizer import (
    AdamWConfig,
    adamw_init,
    adamw_update,
)
from repro.training.train_loop import TrainConfig, build_train_step


def test_adamw_optimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt, _ = adamw_update(cfg, grads, opt, params)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.05


def test_adamw_skips_anomalous_step():
    params = {"w": jnp.ones((4,))}
    opt = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, skip_anomalous=True, anomaly_factor=5.0,
                      warmup_steps=1)
    for _ in range(20):
        params, opt, _ = adamw_update(cfg, {"w": jnp.ones((4,))}, opt,
                                      params)
    before = params["w"].copy()
    params, opt, stats = adamw_update(
        cfg, {"w": 1e6 * jnp.ones((4,))}, opt, params
    )
    assert float(stats["skipped"]) == 1.0
    np.testing.assert_allclose(np.asarray(params["w"]),
                               np.asarray(before))


def test_micro_batching_matches_full_batch():
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    batch = {
        k: jnp.asarray(v)
        for k, v in make_batch(cfg, DataConfig(batch=4, seq_len=16),
                               0).items()
    }
    step1 = build_train_step(model, TrainConfig(micro_batches=1))
    step2 = build_train_step(model, TrainConfig(micro_batches=2))
    opt = adamw_init(params)
    p1, _, m1 = step1(params, opt, batch)
    p2, _, m2 = step2(params, opt, batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-3
    d = max(
        float(jnp.max(jnp.abs(a - b)))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2))
    )
    assert d < 1e-4


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 7, tree, extra={"note": "x"})
    assert latest_step(d) == 7
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, extra = load_checkpoint(d, 7, like)
    assert extra == {"note": "x"}
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]))


def test_checkpoint_gc_and_latest(tmp_path):
    d = str(tmp_path / "ckpt")
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(d, s, tree, keep_last=2)
    assert sorted(all_steps(d)) == [4, 5]


def test_checkpoint_shape_mismatch_rejected(tmp_path):
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 1, {"a": jnp.zeros((2,))})
    with pytest.raises(ValueError):
        load_checkpoint(d, 1, {"a": jnp.zeros((3,))})


def test_crash_resume_end_to_end(tmp_path):
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    d = str(tmp_path / "ckpt")
    dc = DataConfig(batch=2, seq_len=16)
    rc = RunnerConfig(total_steps=12, ckpt_every=4, ckpt_dir=d,
                      crash_after=6)
    with pytest.raises(InjectedFailure):
        TrainRunner(model, dc, TrainConfig(), rc).run(jax.random.key(0))
    assert latest_step(d) == 4
    rc2 = RunnerConfig(total_steps=12, ckpt_every=4, ckpt_dir=d)
    out = TrainRunner(model, dc, TrainConfig(), rc2).run(
        jax.random.key(0)
    )
    assert out["resumed_from"] == 4
    assert np.isfinite(out["final_loss"])


def test_data_pipeline_stateless_deterministic():
    cfg = get_smoke_config("qwen7b")
    dc = DataConfig(batch=4, seq_len=8, seed=3)
    b1 = make_batch(cfg, dc, 11)
    b2 = make_batch(cfg, dc, 11)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = make_batch(cfg, dc, 12)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_compressed_pod_grad_sync_subprocess():
    """Run the manual int8 pod-axis sync on an 8-device host mesh and
    compare against the uncompressed reference."""
    code = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.distributed.compression import pod_manual_value_and_grad

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
w = jnp.linspace(-1.0, 1.0, 16).reshape(4, 4)
x = jnp.arange(32.0).reshape(8, 4) / 32.0

def loss_fn(w, batch):
    return jnp.mean((batch @ w) ** 2)

xb = jax.device_put(x, NamedSharding(mesh, P(("pod", "data"), None)))
vg_c = pod_manual_value_and_grad(loss_fn, mesh, compress=True)
vg_r = pod_manual_value_and_grad(loss_fn, mesh, compress=False)
lc, gc = jax.jit(vg_c)(w, xb)
lr, gr = jax.jit(vg_r)(w, xb)
ref_l, ref_g = jax.value_and_grad(loss_fn)(w, x)
assert abs(float(lc) - float(ref_l)) < 1e-5
err_r = float(jnp.max(jnp.abs(gr - ref_g)))
err_c = float(jnp.max(jnp.abs(gc - ref_g)))
assert err_r < 1e-5, err_r
assert err_c < 5e-3, err_c  # int8 quantization error bound
print("OK", err_r, err_c)
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
