"""P/D disaggregation on the real engine plane (PR 3).

Export/import round-trip token-identity (prefill on engine A, decode on
engine B, compared against an unmigrated single-engine run) across page
and chunk sizes; migration mid-decode; the page-gather kernel vs its
oracle; and a full engine-backed P/D cluster run driven by the same
Dispatcher + Migrator + Scaler as the simulator.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.request import Request, RequestState
from repro.core.scaler import ScalerConfig
from repro.kernels import ref
from repro.kernels.page_gather import page_gather
from repro.models import build_model
from repro.serving.backend import EngineWorker
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.workload import poisson_workload

SMOKE = get_smoke_config("qwen7b")
_MODEL = build_model(SMOKE)
_PARAMS = _MODEL.init(jax.random.key(0))
_FN_CACHE: dict = {}   # shared jitted steps across every engine below


def _engine(page_size=8, chunk_size=16, n_slots=4, max_len=48):
    return InferenceEngine(
        _MODEL, _PARAMS,
        EngineConfig(n_slots=n_slots, max_len=max_len, prefill_batch=2,
                     page_size=page_size, chunk_size=chunk_size),
        fn_cache=_FN_CACHE,
    )


def _req(rid=0, l_in=20, max_new=8):
    prompt = (np.arange(l_in, dtype=np.int32) * 7 + rid) % SMOKE.vocab_size
    return Request.from_prompt(rid, prompt.astype(np.int32), max_new)


def _baseline_tokens(l_in=20, max_new=8, page_size=8, chunk_size=16):
    e = _engine(page_size=page_size, chunk_size=chunk_size)
    r = _req(l_in=l_in, max_new=max_new)
    e.submit(r)
    e.run_until_done()
    assert len(r.generated) == max_new
    return r.generated


# ---------------------------------------------------------------------------
# Tentpole: export/import round-trip token identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size,chunk_size", [(4, 8), (8, 16), (4, 16)])
def test_export_import_roundtrip_token_identity(page_size, chunk_size):
    """Acceptance: prefill on A, migrate, decode on B — byte-identical
    tokens to the unmigrated run, for multiple page/chunk sizes."""
    want = _baseline_tokens(page_size=page_size, chunk_size=chunk_size)

    a = _engine(page_size=page_size, chunk_size=chunk_size)
    a.park_on_prefill = True
    r = _req()
    a.submit(r)
    a.run_until_done()
    # prefill complete -> parked with first token generated, KV resident
    assert r.slot in a.parked and not a.active
    assert r.generated == want[:1]

    payload = a.export_kv(r.rid)
    assert payload.n_tokens == len(r.prompt)
    # measured costing figure == materialized payload size
    assert a.kv_bytes_of(r.rid) == payload.nbytes
    a.evict(r.slot)
    assert a.kv.n_free_pages == a.kv.n_pages  # nothing leaked

    b = _engine(page_size=page_size, chunk_size=chunk_size)
    assert b.import_kv(payload, r)
    b.run_until_done()
    assert r.generated == want
    assert r.state == RequestState.FINISHED


def test_export_import_across_different_page_sizes():
    """The payload is page-layout-free: a ps=4 prefill engine hands off
    to a ps=8 decode engine without retokenizing anything."""
    want = _baseline_tokens(page_size=8, chunk_size=16)
    a = _engine(page_size=4, chunk_size=16)
    a.park_on_prefill = True
    r = _req()
    a.submit(r)
    a.run_until_done()
    payload = a.export_kv(r.rid)
    a.evict(r.slot)
    b = _engine(page_size=8, chunk_size=16)
    assert b.import_kv(payload, r)
    b.run_until_done()
    assert r.generated == want


def test_migration_mid_decode():
    """A request already decoding migrates with its newest tokens: the
    destination continues the stream token-identically."""
    want = _baseline_tokens()
    a = _engine()
    r = _req()
    a.submit(r)
    # prefill + a few decode iterations on A
    while len(r.generated) < 3:
        a.step()
    assert r.slot in a.active
    payload = a.export_kv(r.rid)
    assert payload.n_tokens == len(r.prompt) + len(r.generated) - 1
    a.evict(r.slot)
    b = _engine()
    assert b.import_kv(payload, r)
    b.run_until_done()
    assert r.generated == want


def test_export_import_carries_ssm_state_rows():
    """Mamba/SSD state is O(1)-per-sequence and not paged: the payload
    carries it as bare slot rows, and the destination's recurrence
    continues token-identically."""
    cfg = get_smoke_config("mamba2-2.7b")
    model = build_model(cfg)
    assert model.supports_chunked
    params = model.init(jax.random.key(0))
    fc: dict = {}

    def eng(ps):
        return InferenceEngine(model, params, EngineConfig(
            n_slots=2, max_len=48, prefill_batch=2, page_size=ps,
            chunk_size=16), fn_cache=fc)

    prompt = ((np.arange(1, 21, dtype=np.int32) * 3)
              % cfg.vocab_size).astype(np.int32)
    c = eng(8)
    rc = Request.from_prompt(0, prompt, 6)
    c.submit(rc)
    c.run_until_done()

    a = eng(8)
    a.park_on_prefill = True
    r = Request.from_prompt(0, prompt, 6)
    a.submit(r)
    a.run_until_done()
    payload = a.export_kv(0)
    a.evict(r.slot)
    b = eng(4)  # page-size change must not disturb slot-row state
    assert b.import_kv(payload, r)
    b.run_until_done()
    assert r.generated == rc.generated


def test_export_rejects_incomplete_prefill_and_unknown_rid():
    a = _engine(chunk_size=4)
    r = _req(l_in=20)
    a.submit(r)
    a.step()  # one 4-token chunk: prefill incomplete
    assert r.slot in a.prefilling
    with pytest.raises(RuntimeError, match="prefill"):
        a.export_kv(r.rid)
    with pytest.raises(KeyError):
        a.export_kv(999)


def test_import_fails_cleanly_when_pool_exhausted():
    """A failed import must not leak slots or pages."""
    a = _engine(page_size=8)
    a.park_on_prefill = True
    r = _req()
    a.submit(r)
    a.run_until_done()
    payload = a.export_kv(r.rid)
    b = _engine(page_size=8, n_slots=1, max_len=16)  # 2 pages total
    free_before = b.kv.n_free_pages
    assert not b.import_kv(payload, _req(rid=1))  # needs 3 pages
    assert b.kv.n_free_pages == free_before
    assert b.slots.n_free == 1


# ---------------------------------------------------------------------------
# Page-gather kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("ps", [4, 8])
def test_page_gather_kernel_matches_oracle(ps):
    rng = np.random.default_rng(ps)
    n_pages, h, d = 12, 2, 16
    pages = jnp.asarray(
        rng.standard_normal((n_pages, h, ps, d)).astype(np.float32)
    )
    ids = jnp.asarray(np.array([3, 7, 1, 5], np.int32))
    want = ref.page_gather_ref(pages, ids)
    got = page_gather(pages, ids, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.shape == (h, 4 * ps, d)
    # linearization really is token-major: page 3 fills tokens [0, ps)
    np.testing.assert_array_equal(np.asarray(got[:, :ps]),
                                  np.asarray(pages[3]))


def test_page_gather_clamps_unallocated_entries():
    pages = jnp.arange(2 * 1 * 4 * 8, dtype=jnp.float32).reshape(2, 1, 4, 8)
    ids = jnp.asarray(np.array([1, -1], np.int32))
    got = page_gather(pages, ids, interpret=True)
    # -1 clamps to page 0 (callers slice to n_tokens, like kv_len masks)
    np.testing.assert_array_equal(np.asarray(got[:, 4:]),
                                  np.asarray(pages[0]))


# ---------------------------------------------------------------------------
# Engine-backed P/D cluster: Dispatcher + Migrator + Scaler end to end
# ---------------------------------------------------------------------------

def _pd_cluster_cfg(**kw):
    kw.setdefault("engine", EngineConfig.smoke())
    return ClusterConfig(model=SMOKE, backend="engine",
                         policy="hyperflexis", mode="pd", n_prefill=1,
                         n_decode=1, seed=0, **kw)


def _small_workload(n=8, seed=0):
    rng = np.random.default_rng(seed)
    reqs, t = [], 0.0
    for i in range(n):
        t += float(rng.exponential(0.05))
        reqs.append(Request(rid=i, task="chat" if i % 2 else "doc",
                            arrival=t, l_in=int(rng.integers(4, 14)),
                            l_out=int(rng.integers(2, 6)),
                            ttft_slo=2.0, tpot_slo=0.6))
    return reqs


def test_engine_pd_cluster_end_to_end():
    """Acceptance: Cluster(backend='engine', mode='pd') no longer
    raises; requests prefill on a prefill engine, the Migrator moves
    real KV payloads, and the decode engine finishes every stream."""
    cluster = Cluster(_pd_cluster_cfg(scaling=True,
                                      scaler=ScalerConfig(max_workers=2,
                                                          min_workers=1)))
    roles = [w.role for w in cluster.workers]
    assert roles == ["prefill", "decode"]
    assert all(isinstance(w, EngineWorker) for w in cluster.workers)
    assert cluster.workers[0].engine.park_on_prefill
    assert not cluster.workers[1].engine.park_on_prefill
    assert cluster.migrator is not None and cluster.scaler is not None

    reqs = _small_workload()
    res = cluster.run(reqs)
    m = res.metrics
    assert m.n_finished == m.n_total == len(reqs)
    assert res.kv_transfers >= 1
    # measured-bytes costing actually moved bytes over the TLManager
    assert cluster.tl.kv_bytes_moved > 0
    for r in reqs:
        assert r.state == RequestState.FINISHED
        assert len(r.generated) == r.l_out
        if r.l_out > 1:  # single-token requests finish at prefill
            assert r.decode_worker is not None
            assert r.decode_worker != r.prefill_worker


def test_engine_pd_fully_parked_prefill_engine_wakes_on_migration():
    """Regression: a prefill engine whose every slot is parked goes
    idle with prompts still queued; when a migration frees the slot,
    the source must be rescheduled — otherwise the queued prompts
    starve until drain_timeout and the run ends unfinished."""
    cluster = Cluster(_pd_cluster_cfg(
        engine=EngineConfig(n_slots=1, max_len=48, prefill_batch=1,
                            page_size=8, chunk_size=16),
        drain_timeout=10.0,
    ))
    reqs = _small_workload(4)
    res = cluster.run(reqs)
    assert res.metrics.n_finished == res.metrics.n_total == len(reqs)
    assert all(r.state == RequestState.FINISHED for r in reqs)


def test_engine_pd_tokens_identical_to_collocated():
    """Two-stage P/D must not change WHAT is generated, only where:
    greedy decode over migrated KV matches the collocated engine."""
    reqs_pd = _small_workload()
    Cluster(_pd_cluster_cfg()).run(reqs_pd)
    reqs_col = _small_workload()
    Cluster(ClusterConfig(
        model=SMOKE, backend="engine", policy="hyperflexis", n_workers=1,
        seed=0, engine=EngineConfig(n_slots=4, max_len=48,
                                    prefill_batch=2, page_size=8,
                                    chunk_size=16))).run(reqs_col)
    assert [r.generated for r in reqs_pd] == [r.generated for r in reqs_col]


def test_engine_pd_runmetrics_schema_matches_sim_pd():
    """Acceptance: the engine P/D plane emits the same RunMetrics
    schema as the sim P/D plane (shared compute_metrics)."""
    eng = Cluster(_pd_cluster_cfg()).run(_small_workload(6))
    sim = Cluster(ClusterConfig(
        model=get_config("qwen7b"), policy="hyperflexis", mode="pd",
        n_prefill=1, n_decode=1, seed=0)).run(
            poisson_workload(["gsm8k"], qps=16, n_per_task=5, seed=0))
    a = dataclasses.asdict(eng.metrics)
    b = dataclasses.asdict(sim.metrics)
    assert a.keys() == b.keys()
    assert set(eng.metrics.row()) == set(sim.metrics.row())


def test_engine_worker_role_flip_syncs_park_behavior():
    """Scaler role flips (tick_pd) drive the engine's park-on-prefill
    switch; P/D roles are rejected on the slot-plane fallback."""
    cluster = Cluster(_pd_cluster_cfg())
    w = cluster.workers[1]
    assert w.role == "decode" and not w.engine.park_on_prefill
    w.role = "prefill"
    assert w.engine.park_on_prefill
    w.role = "collocated"
    assert not w.engine.park_on_prefill

    slot_cluster = Cluster(ClusterConfig(
        model=SMOKE, backend="engine", n_workers=1, policy="hyperflexis",
        seed=0, engine=EngineConfig(n_slots=2, max_len=32,
                                    prefill_batch=1, paged=False)))
    with pytest.raises(ValueError, match="paged"):
        slot_cluster.workers[0].role = "prefill"


def test_engine_pd_requires_paged_plane():
    with pytest.raises(ValueError, match="paged"):
        Cluster(_pd_cluster_cfg(engine=EngineConfig(
            n_slots=2, max_len=32, prefill_batch=1, paged=False)))
