"""Fused multi-token decode blocks (device-resident decode).

A decode_block=K engine runs K greedy iterations per jitted dispatch
(`Model.decode_block` / `decode_block_slots` — a lax.scan with
on-device EOS / max-len / l_out stopping) and must be *token-identical*
to per-token stepping on both execution planes, through preemption,
P/D export of a partially-consumed stream, and EOS stopping mid-block;
profiler attribution stays per-iteration so the Eq. 2 fit is unchanged.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.request import Request, RequestState
from repro.models import build_model
from repro.serving.engine import EngineConfig, InferenceEngine

SMOKE = get_smoke_config("qwen7b")
_MODEL = build_model(SMOKE)
_PARAMS = _MODEL.init(jax.random.key(0))
_FN_CACHE: dict = {}   # shared jitted steps across every engine below


def _engine(decode_block, page_size=8, chunk_size=16, n_slots=2,
            max_len=48, model=_MODEL, params=_PARAMS, fn_cache=_FN_CACHE,
            **kw):
    return InferenceEngine(
        model, params,
        EngineConfig(n_slots=n_slots, max_len=max_len, prefill_batch=2,
                     page_size=page_size, chunk_size=chunk_size,
                     decode_block=decode_block, **kw),
        fn_cache=fn_cache,
    )


def _prompts(n=4, sizes=(5, 21, 11, 3)):
    rng = np.random.default_rng(7)
    return [rng.integers(0, SMOKE.vocab_size, size=s).astype(np.int32)
            for s in sizes[:n]]


def _run(eng, prompts, max_new=10):
    reqs = [Request.from_prompt(i, p, max_new=max_new)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.finish_time is not None for r in reqs)
    return reqs


# ---------------------------------------------------------------------------
# Token identity vs K=1, both planes, multiple chunk/page sizes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size,chunk_size", [(4, 8), (8, 16)])
def test_paged_blocks_token_identical_to_per_token(page_size, chunk_size):
    base = _run(_engine(1, page_size, chunk_size), _prompts())
    blk = _run(_engine(8, page_size, chunk_size), _prompts())
    assert [r.generated for r in blk] == [r.generated for r in base]
    # blocks actually ran fused (pure-decode phases exist with 2 slots)
    eng = _engine(8, page_size, chunk_size)
    reqs = _run(eng, _prompts(2, (5, 7)), max_new=12)
    assert any(k > 1 for k in eng.decode_block_hist), eng.decode_block_hist
    assert eng.kv.n_free_pages == eng.kv.n_pages
    assert all(len(r.generated) == 12 for r in reqs)


def test_slot_plane_blocks_token_identical():
    base = _run(_engine(1, paged=False), _prompts())
    blk = _run(_engine(8, paged=False), _prompts())
    assert [r.generated for r in blk] == [r.generated for r in base]


def test_mamba_blocks_token_identical():
    """SSM state carry through the fused scan (conv + SSD state ride
    the carry, frozen rows hold their state)."""
    cfg = get_smoke_config("mamba2-2.7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    cache: dict = {}

    def run(k):
        eng = _engine(k, model=model, params=params, fn_cache=cache)
        return _run(eng, _prompts(2, (5, 9)), max_new=8)

    assert ([r.generated for r in run(8)]
            == [r.generated for r in run(1)])


# ---------------------------------------------------------------------------
# EOS stopping mid-block (partially-consumed block)
# ---------------------------------------------------------------------------

def test_eos_stops_mid_block():
    base = _run(_engine(1, n_slots=1), _prompts(1, (9,)), max_new=12)
    tokens = base[0].generated
    eos = tokens[5]
    stop = tokens.index(eos)  # first emission of the eos value
    want = tokens[: stop + 1]

    outs = {}
    for k in (1, 8):
        eng = _engine(k, n_slots=1, eos_token=int(eos))
        (r,) = _run(eng, _prompts(1, (9,)), max_new=12)
        outs[k] = r.generated
        assert r.generated[-1] == eos
        assert eng.kv.n_free_pages == eng.kv.n_pages
        if k == 8 and len(want) > 1:
            # the block overshoots the stream's end: lanes after EOS
            # come back invalid, and the finish stamp interpolates to
            # the emitting lane, strictly inside the block wall
            assert r.finish_time < eng.clock
    assert outs[8] == outs[1] == want


# ---------------------------------------------------------------------------
# Preemption under page pressure with blocks on
# ---------------------------------------------------------------------------

def test_preemption_under_page_pressure_with_blocks():
    """An oversubscribed pool shrinks K (page pre-reservation) and
    falls back to recompute preemption at K=1 — outputs stay
    token-exact vs a roomy pool."""
    prompts = _prompts(2, (10, 10))

    def run(decode_block, **kw):
        eng = _engine(decode_block, page_size=4, chunk_size=8,
                      max_len=16, **kw)
        reqs = _run(eng, [p.copy() for p in prompts], max_new=6)
        assert eng.kv.n_free_pages == eng.kv.n_pages
        return [r.generated for r in reqs]

    base = run(1)
    assert run(8) == base
    for n_pages in (4, 5):   # prefill- and decode-time preemption
        assert run(8, n_pages=n_pages) == base, n_pages


# ---------------------------------------------------------------------------
# P/D hand-off of a stream advanced by fused blocks
# ---------------------------------------------------------------------------

def test_pd_export_after_partial_blocks():
    """Host pos/last_token must stay exact through device-resident
    blocks: park on a prefill engine, decode with K=8 blocks on a
    second, export MID-STREAM, finish on a third (per-token, different
    page size) — token-identical to the unmigrated run."""
    base = _run(_engine(1, n_slots=1, max_len=64), _prompts(1, (12,)),
                max_new=16)
    want = base[0].generated

    a = _engine(8, n_slots=1, max_len=64)
    a.park_on_prefill = True
    r = Request.from_prompt(0, _prompts(1, (12,))[0], max_new=16)
    a.submit(r)
    a.run_until_done()
    assert r.slot in a.parked
    pay = a.export_kv(r.rid)
    a.evict(r.slot)

    b = _engine(8, n_slots=1, max_len=64)
    assert b.import_kv(pay, r)
    assert b._slot_of(r.rid) == r.slot
    while len(r.generated) < 9:   # a couple of fused blocks
        b.step()
    assert any(k > 1 for k in b.decode_block_hist), b.decode_block_hist
    assert r.generated == want[: len(r.generated)]
    pay2 = b.export_kv(r.rid)
    assert pay2.n_tokens == int(b.pos[r.slot])
    b.evict(r.slot)
    assert b.kv.n_free_pages == b.kv.n_pages

    c = _engine(1, n_slots=1, max_len=64, page_size=4)
    assert c.import_kv(pay2, r)
    c.run_until_done()
    assert r.generated == want
    assert r.state == RequestState.FINISHED


# ---------------------------------------------------------------------------
# Profiler: per-iteration attribution inside a block
# ---------------------------------------------------------------------------

def test_profiler_per_iteration_attribution():
    """A K-block contributes K Eq. 2 samples of wall/K each at the
    interpolated lengths — same sample stream per-token stepping
    produces, so the Appendix-A fit is block-size independent."""
    eng = _engine(4, n_slots=1)
    (r,) = _run(eng, _prompts(1, (8,)), max_new=9)
    samples = eng.profiler._d_samples
    # 8 decode tokens (first came from prefill) -> 8 samples, batch 1
    assert len(samples) == 8
    assert all(b == 1.0 for _, b, _ in samples)
    # lengths advance by one per iteration, across block boundaries
    lens = [s for s, _, _ in samples]
    assert lens == [lens[0] + i for i in range(8)]
    # two blocks of 4 -> times equal within each block
    assert eng.decode_block_hist.get(4) == 2
    t = [x for _, _, x in samples]
    assert t[0] == t[1] == t[2] == t[3] and t[4] == t[5] == t[6] == t[7]


# ---------------------------------------------------------------------------
# Satellites: rid->slot index, device-resident page table
# ---------------------------------------------------------------------------

def test_rid_slot_index_tracks_lifecycle():
    eng = _engine(8)
    reqs = _run(eng, _prompts(), max_new=6)
    assert eng._rid_slot == {}          # all retired
    assert eng._slot_of(reqs[0].rid) is None
    assert eng.kv_bytes_of(reqs[0].rid) is None

    a = _engine(8, n_slots=1)
    a.park_on_prefill = True
    r = Request.from_prompt(9, _prompts(1, (6,))[0], max_new=4)
    a.submit(r)
    a.run_until_done()
    assert a._slot_of(9) == r.slot      # parked: index live, O(1)
    assert a.kv_bytes_of(9) == a.export_kv(9).nbytes
    a.evict(r.slot)
    assert a._rid_slot == {}


def test_device_table_reuploads_only_on_allocation_change():
    from repro.serving.kv_manager import PagedKVManager

    kv = PagedKVManager(n_slots=2, max_len=32, page_size=8)
    t0 = kv.device_table()
    assert t0 is kv.device_table()      # clean: same resident buffer
    assert kv.ensure(0, 9)              # grows -> dirty
    t1 = kv.device_table()
    assert t1 is not t0
    assert np.array_equal(np.asarray(t1), kv.table)
    assert kv.ensure(0, 9)              # no growth -> still clean
    assert kv.device_table() is t1
    kv.release(0)
    t2 = kv.device_table()
    assert t2 is not t1
    assert (np.asarray(t2) == -1).all()
