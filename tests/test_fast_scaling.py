"""Fast scaling on the engine plane (PR 6): per-replica weight
ownership via WeightManager, the three Table-2 provisioning transports
(d2d / cpu / disk) as real transfers, measured costs feeding the
TLManager model, and the Cluster's scale-out/scale-in commit paths."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.request import Request
from repro.core.scaler import ScaleAction, ScalerConfig
from repro.core.tlmanager import TLManager
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.weights import STRATEGIES, WeightManager

SMOKE = get_smoke_config("qwen7b")


@pytest.fixture(scope="module")
def stack():
    from repro.models import build_model

    model = build_model(SMOKE)
    params = model.init(jax.random.key(0))
    return model, params


def _prompt(n=10):
    return (np.arange(1, n + 1, dtype=np.int32) * 3) % SMOKE.vocab_size


def _generate(model, params, fn_cache, max_new=5):
    eng = InferenceEngine(model, params, EngineConfig.smoke(),
                          fn_cache=fn_cache)
    r = Request.from_prompt(0, _prompt(), max_new=max_new)
    eng.submit(r)
    eng.run_until_done()
    return list(r.generated)


def _distinct_buffers(a, b):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    for x, y in zip(la, lb):
        if x is y:
            return False
        try:
            if x.unsafe_buffer_pointer() == y.unsafe_buffer_pointer():
                return False
        except (AttributeError, ValueError):
            pass
    return True


# ---------------------------------------------------------------------------
# WeightManager: ownership + the three transports
# ---------------------------------------------------------------------------

def test_provision_all_strategies_token_identical(stack):
    """Every Table-2 transport materializes a replica-owned tree whose
    buffers are distinct from the donor's AND whose engine generates
    exactly the seed replica's tokens."""
    model, params = stack
    wm = WeightManager(params, tl=TLManager())
    wm.adopt(0, params)
    fn_cache: dict = {}
    ref = _generate(model, params, fn_cache)
    assert ref  # the smoke model really decoded something
    for wid, strategy in enumerate(STRATEGIES, start=1):
        got, dt = wm.provision(
            wid, strategy, donor=0 if strategy == "d2d" else None
        )
        assert dt > 0.0
        assert wm.owns(wid)
        assert _distinct_buffers(params, got), strategy
        for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert _generate(model, got, fn_cache) == ref, strategy


def test_adopt_and_release_track_ownership(stack):
    model, params = stack
    wm = WeightManager(params)
    assert not wm.owns(0) and wm.donors() == []
    wm.adopt(0, params)
    assert wm.owns(0) and wm.donors() == [0]
    with pytest.raises(ValueError):
        wm.adopt(0, params)  # double-adopt is a bookkeeping bug
    wm.release(0)
    assert not wm.owns(0)


def test_d2d_requires_live_donor(stack):
    """Scale-from-zero: no live donor -> d2d must fail loudly (the
    Scaler/Cluster fall back to disk, they never alias a dead tree)."""
    model, params = stack
    wm = WeightManager(params)
    with pytest.raises(ValueError):
        wm.provision(1, "d2d")
    wm.adopt(0, params)
    p1, _ = wm.provision(1, "d2d", donor=0)
    wm.release(0)
    with pytest.raises(ValueError):
        wm.provision(2, "d2d", donor=0)  # donor scaled in since
    with pytest.raises(ValueError):
        wm.provision(1, "cpu")  # wid already owns a tree
    with pytest.raises(ValueError):
        wm.provision(3, "nvlink")  # unknown strategy


def test_disk_strategy_round_trips_the_checkpoint(stack):
    """The disk transport really loads from the on-disk checkpoint the
    manager wrote at init (scale-from-zero survives donor loss)."""
    model, params = stack
    wm = WeightManager(params)
    from repro.distributed.checkpoint import checkpoint_nbytes

    assert checkpoint_nbytes(wm.ckpt_dir, 0) == wm.nbytes
    got, _ = wm.provision(7, "disk")
    for x, y in zip(jax.tree.leaves(params), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# Measured transfers feed the TLManager cost model
# ---------------------------------------------------------------------------

def test_measured_transfers_feed_cost_model(stack):
    model, params = stack
    tl = TLManager()
    wm = WeightManager(params, tl=tl)
    wm.adopt(0, params)
    for wid, s in enumerate(STRATEGIES, start=1):
        wm.provision(wid, s, donor=0 if s == "d2d" else None)
        bw = tl.measured_weight_bw(s)
        assert bw is not None and bw > 0
        # the measured bandwidth now drives weight_load_time for this
        # strategy (prediction == nbytes / observed bw)
        t = tl.weight_load_time(SMOKE, s, nbytes=wm.nbytes, record=False)
        assert t == pytest.approx(wm.nbytes / bw)
    assert tl.n_weight_loads == len(STRATEGIES)


def test_weight_byte_accounting_all_strategies():
    """Satellite bugfix: every strategy moves bytes — d2d over ICI,
    cpu/disk through the host path — and record=False probes (strategy
    selection) must not inflate the counters."""
    tl = TLManager()
    n = SMOKE.param_count() * 2
    tl.weight_load_time(SMOKE, "d2d")
    assert tl.weight_bytes_moved == n
    assert tl.weight_bytes_ici == n and tl.weight_bytes_host == 0
    tl.weight_load_time(SMOKE, "cpu")
    tl.weight_load_time(SMOKE, "disk")
    assert tl.weight_bytes_moved == 3 * n
    assert tl.weight_bytes_ici == n and tl.weight_bytes_host == 2 * n
    tl.weight_load_time(SMOKE, "d2d", record=False)
    assert tl.weight_bytes_moved == 3 * n  # probe left no trace


# ---------------------------------------------------------------------------
# Cluster integration: the engine scale-out/scale-in commit paths
# ---------------------------------------------------------------------------

def _engine_cluster(**scaler_kw):
    scaler_kw.setdefault("weight_strategy", "d2d")
    return Cluster(ClusterConfig(
        model=SMOKE, n_workers=1, backend="engine",
        engine=EngineConfig.smoke(), scaling=True,
        scaler=ScalerConfig(max_workers=3, **scaler_kw),
    ))


def _force_actions(c, actions, now=1.0):
    """Drive the Cluster's commit path with canned scaler actions."""
    c.scaler.tick = lambda *a, **k: actions
    c._scaler_tick(now, c._by_wid)


def test_engine_replicas_own_their_weights(stack):
    """Tentpole ownership model: the initial replica's params tree is
    its OWN (provisioned through a transport), not an alias of the
    cluster's seed tree."""
    c = _engine_cluster()
    w0 = c.workers[0]
    assert c.weights is not None and c.weights.owns(0)
    assert w0.engine.params is not c._engine_params
    assert _distinct_buffers(c._engine_params, w0.engine.params)


def test_engine_scale_out_d2d_and_scale_in_release(stack):
    """A committed d2d scale-out provisions the new replica from the
    live donor and the new engine is token-identical to the seed; a
    committed scale-in releases the owned tree and drops the engine's
    params so it stops being a donor."""
    c = _engine_cluster()
    _force_actions(c, [ScaleAction("out", "any", 0.2, strategy="d2d",
                                   warm=True)])
    assert len(c.workers) == 2
    new = c.workers[1]
    assert c.weights.owns(new.wid)
    assert _distinct_buffers(c.workers[0].engine.params,
                             new.engine.params)
    ev = [e for _, wid, e in c.timeline if wid == new.wid]
    assert any(e.startswith("scale_out:d2d") for e in ev)
    # measured provision wall time became the cold-start delay
    assert c._provision_s is not None and c._provision_s > 0

    # token identity seed vs scaled-out replica (shared jit cache)
    ref = _generate(c._engine_model, c.workers[0].engine.params,
                    c._fn_cache)
    got = _generate(c._engine_model, new.engine.params, c._fn_cache)
    assert got == ref

    # scale the new replica back in: weights reclaimed
    new.activate(1.5, "collocated")
    _force_actions(c, [ScaleAction("in", "any", 0.0,
                                   worker_id=new.wid)], now=2.0)
    assert not c.weights.owns(new.wid)
    assert new.engine.params is None
    assert c._pick_donor() == 0  # only the seed replica donates now


def test_engine_scale_from_zero_falls_back_to_disk(stack):
    """Commit-time donor re-check: the scaler may have planned d2d, but
    with every owning replica gone the Cluster provisions from disk."""
    c = _engine_cluster()
    w0 = c.workers[0]
    w0.deactivate(0.0)
    c.weights.release(0)
    w0.engine.release_weights()
    assert c._pick_donor() is None
    _force_actions(c, [ScaleAction("out", "any", 0.2, strategy="d2d",
                                   warm=True)])
    new = c.workers[1]
    assert c.weights.owns(new.wid)
    ev = [e for _, wid, e in c.timeline if wid == new.wid]
    assert any(e.startswith("scale_out:disk") for e in ev)


def test_release_weights_refuses_undrained_engine(stack):
    model, params = stack
    eng = InferenceEngine(model, params, EngineConfig.smoke(),
                          fn_cache={})
    eng.submit(Request.from_prompt(0, _prompt(), max_new=3))
    with pytest.raises(RuntimeError):
        eng.release_weights()
    eng.run_until_done()
    eng.release_weights()
    assert eng.params is None


def test_pick_donor_prefers_least_loaded(stack):
    c = _engine_cluster()
    _force_actions(c, [ScaleAction("out", "any", 0.1, strategy="cpu",
                                   warm=True)])
    new = c.workers[1]
    new.activate(1.5, "collocated")
    # load the seed replica's queue; the idle new replica donates
    c.workers[0].engine.queue.append(
        Request.from_prompt(9, _prompt(), max_new=2))
    assert c._pick_donor() == new.wid
