"""End-to-end cluster simulation: every policy completes the workload;
HyperFlexis dominates RR in the paper's regime; P/D and scaling work."""

import pytest

from repro.configs import get_config
from repro.core.request import FOUR_TASK_SET, TASKS, TWO_TASK_SET
from repro.core.scaler import ScalerConfig
from repro.core.slo_mapper import PrioritySLOMapper, bands_from_tasks
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import (
    poisson_workload,
    ramp_workload,
    single_task_workload,
)

MODEL = get_config("qwen7b")


def _run(policy="hyperflexis", qps=64, n=40, seed=0, **kw):
    reqs = poisson_workload(FOUR_TASK_SET, qps=qps, n_per_task=n,
                            seed=seed)
    cfg = ClusterConfig(model=MODEL, n_workers=2, policy=policy,
                        seed=seed, **kw)
    return Cluster(cfg).run(reqs)


@pytest.mark.parametrize("policy", ["hyperflexis", "rr", "scorpio",
                                    "aladdin", "sa"])
def test_all_policies_complete(policy):
    res = _run(policy=policy, qps=32, n=25)
    m = res.metrics
    assert m.n_finished == m.n_total
    assert m.cost_units > 0
    assert m.makespan > 0


def test_hfx_beats_rr_under_load():
    # average over seeds at a load near the knee
    seeds = [0, 1, 2]
    hfx = sum(_run("hyperflexis", qps=80, n=60, seed=s).metrics.attainment
              for s in seeds) / len(seeds)
    rr = sum(_run("rr", qps=80, n=60, seed=s).metrics.attainment
             for s in seeds) / len(seeds)
    assert hfx > rr


def test_light_load_everyone_attains():
    for policy in ("hyperflexis", "rr"):
        m = _run(policy=policy, qps=8, n=25).metrics
        assert m.attainment > 0.95


def test_scaling_improves_attainment_with_bounded_cost():
    base = _run("hyperflexis", qps=110, n=60)
    scaled = _run("hyperflexis", qps=110, n=60, scaling=True,
                  scaler=ScalerConfig(max_workers=4))
    assert scaled.metrics.attainment >= base.metrics.attainment
    assert scaled.n_scale_out >= 1


def test_pd_two_stage_beats_one_shot():
    def run_pd(one_shot, policy, seed):
        reqs = poisson_workload(FOUR_TASK_SET, qps=128, n_per_task=60,
                                seed=seed)
        cfg = ClusterConfig(model=MODEL, policy=policy, mode="pd",
                            n_prefill=2, n_decode=2,
                            one_shot_pd=one_shot, seed=seed)
        return Cluster(cfg).run(reqs).metrics
    seeds = (0, 1, 2)
    two_stage = [run_pd(False, "hyperflexis", s) for s in seeds]
    one_shot = [run_pd(True, "rr", s) for s in seeds]
    assert all(m.n_finished == m.n_total for m in two_stage)
    mean = lambda ms: sum(m.attainment for m in ms) / len(ms)  # noqa
    assert mean(two_stage) > mean(one_shot)


def test_pd_kv_transfers_happen():
    reqs = poisson_workload(TWO_TASK_SET, qps=16, n_per_task=20, seed=0)
    cfg = ClusterConfig(model=MODEL, policy="hyperflexis", mode="pd",
                        n_prefill=1, n_decode=1, seed=0)
    res = Cluster(cfg).run(reqs)
    assert res.kv_transfers > 0
    assert res.metrics.n_finished == res.metrics.n_total


def test_priority_mapping_runs():
    mapper = PrioritySLOMapper(
        bands_from_tasks([TASKS[t] for t in FOUR_TASK_SET])
    )
    reqs = poisson_workload(FOUR_TASK_SET, qps=48, n_per_task=30, seed=0,
                            use_priority=True)
    cfg = ClusterConfig(model=MODEL, n_workers=2, policy="hyperflexis",
                        seed=0, slo_mapper=mapper)
    res = Cluster(cfg).run(reqs)
    m = res.metrics
    assert m.n_finished == m.n_total
    # mapped SLOs stay inside the configured bands
    for r in res.requests:
        band = mapper.bands[r.priority]
        assert band.min_ttft - 1e-9 <= r.ttft_slo <= band.max_ttft + 1e-9


def test_determinism_same_seed():
    a = _run("hyperflexis", qps=48, n=30, seed=7).metrics
    b = _run("hyperflexis", qps=48, n=30, seed=7).metrics
    assert a.attainment == b.attainment
    assert a.mean_e2e == b.mean_e2e


def test_single_task_workload_runs():
    reqs = single_task_workload("wikisql", qps=20, n=60)
    cfg = ClusterConfig(model=MODEL, n_workers=2, policy="hyperflexis")
    m = Cluster(cfg).run(reqs).metrics
    assert m.n_finished == m.n_total


def test_ramp_workload_structure():
    reqs = ramp_workload(FOUR_TASK_SET, qps_per_class=15.0,
                         join_every=20.0, n_per_class=50)
    # lowest-priority class arrives first
    first = reqs[0]
    assert first.priority == max(r.priority for r in reqs)
    assert min(r.arrival for r in reqs) >= 0.0


def test_chunked_prefill_completes_and_bounds_decode_stall():
    """Chunked sim plane: the workload still completes, and a long
    prompt's prefill no longer head-of-line-blocks in-flight decodes —
    short-request TPOT improves vs monolithic prefill."""
    from repro.core.request import Request

    def mixed():
        reqs = [Request(rid=i, task="chat", arrival=i * 0.05, l_in=64,
                        l_out=60, ttft_slo=2.0, tpot_slo=0.2)
                for i in range(20)]
        reqs += [Request(rid=100 + i, task="doc", arrival=0.2 + i * 0.2,
                         l_in=8000, l_out=20, ttft_slo=30.0, tpot_slo=1.0)
                 for i in range(4)]
        return sorted(reqs, key=lambda r: r.arrival)

    def run(chunk):
        cfg = ClusterConfig(model=MODEL, n_workers=1, policy="hyperflexis",
                            seed=3, chunk_tokens=chunk)
        return Cluster(cfg).run(mixed())

    mono = run(None)
    chunked = run(512)
    for res in (mono, chunked):
        assert res.metrics.n_finished == res.metrics.n_total
    def max_chat_tpot(res):
        return max(r.tpot for r in res.requests if r.task == "chat")
    assert max_chat_tpot(chunked) < max_chat_tpot(mono)
    # every chunked request fully prefilled exactly once
    for r in chunked.requests:
        assert r.prefill_progress == r.l_in


def test_role_flip_aborts_when_work_lands_during_transition():
    """The scaler flips only drained workers, but a dispatch can land
    during the role_transition_time window; the commit re-checks and
    aborts (a sim prefill worker flipped to decode would never drain
    its waiting queue)."""
    from repro.core.request import Request

    cfg = ClusterConfig(model=MODEL, mode="pd", n_prefill=2, n_decode=1,
                        seed=0)
    cluster = Cluster(cfg)
    w = cluster.workers[0]
    assert w.role == "prefill"
    w.waiting.append(Request(rid=0, task="t", arrival=0.0, l_in=10,
                             l_out=5, ttft_slo=1.0, tpot_slo=0.5))
    assert not cluster._apply_role_flip(w, "decode", 1.0)
    assert w.role == "prefill"
    assert (1.0, w.wid, "role_flip_skipped:decode") in cluster.timeline

    w.waiting.clear()
    assert cluster._apply_role_flip(w, "decode", 2.0)
    assert w.role == "decode"
    assert (2.0, w.wid, "role:prefill->decode") in cluster.timeline
