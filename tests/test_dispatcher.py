"""Algorithm 1 behaviors: queues, budget gating, admission, maturity."""

import numpy as np
import pytest

from repro.core.dispatcher import Dispatcher, DispatcherConfig
from repro.core.latency_model import (
    FittedLatencyModel,
    LatencyCoeffs,
    LatencyModel,
)
from repro.core.monitor import Monitor
from repro.core.queues import RequestPriorityQueue, WorkerPriorityQueue
from repro.core.request import Request
from repro.serving.worker import SimWorker

COEFFS = LatencyCoeffs(a=0.003, b=1.5e-4, c=0.0, a_d=0.02, b_d=8e-7,
                       c_d=1e-4)


def _model():
    return LatencyModel(COEFFS)


def _req(rid, arrival=0.0, l_in=100, l_out=20, ttft=0.7, tpot=0.5):
    return Request(rid=rid, task="t", arrival=arrival, l_in=l_in,
                   l_out=l_out, ttft_slo=ttft, tpot_slo=tpot)


def _worker(wid=0, kv=100_000):
    return SimWorker(wid, "collocated", _model(), kv,
                     np.random.default_rng(0), noise=0.0)


def _dispatcher(workers, **kw):
    mon = Monitor(0.05)
    disp = Dispatcher(_model(), mon, DispatcherConfig(**kw))
    dispatched = []
    disp.on_dispatch = lambda w, rs, now: (
        dispatched.append((w.wid, [r.rid for r in rs])),
        w.waiting.extend(rs),
    )
    for w in workers:
        disp.add_worker(w, 0.0)
    return disp, mon, dispatched


def test_queue_order_tpot_then_arrival():
    q = RequestPriorityQueue()
    q.add(_req(0, arrival=1.0, tpot=0.9))
    q.add(_req(1, arrival=0.5, tpot=0.2))
    q.add(_req(2, arrival=0.1, tpot=0.9))
    assert [r.rid for r in q.scan()] == [1, 2, 0]


def test_worker_queue_maturity_order():
    q = WorkerPriorityQueue()
    q.push("a", 2.0)
    q.push("b", 1.0)
    w, m = q.pop()
    assert w == "b" and m == 1.0


def test_dispatch_admits_fresh_request():
    w = _worker()
    disp, mon, out = _dispatcher([w])
    disp.on_request_arrive(_req(0))
    disp.dispatch_pass(0.0)
    assert out and out[0][1] == [0]
    assert disp.pending() == 0


def test_budget_excludes_oversized_batch():
    """Eq. 5 caps admitted prompt tokens."""
    w = _worker()
    disp, mon, out = _dispatcher([w])
    # tight SLOs -> small budget; many large prompts
    for i in range(50):
        disp.on_request_arrive(
            _req(i, l_in=2000, ttft=0.7, tpot=0.5)
        )
    disp.dispatch_pass(0.0)
    admitted = sum(len(rs) for _, rs in out)
    budget = disp.get_ntoken(disp.shadows[0])
    assert admitted * 2000 <= budget + 2000
    assert admitted < 50


def test_rejects_hopeless_then_overdue_fill():
    w = _worker()
    disp, mon, out = _dispatcher([w])
    r_dead = _req(0, arrival=-10.0, ttft=0.5)     # long overdue
    r_live = _req(1, arrival=0.0, ttft=0.7)
    disp.on_request_arrive(r_dead)
    disp.on_request_arrive(r_live)
    disp.dispatch_pass(0.0)
    ids = [rid for _, rs in out for rid in rs]
    assert set(ids) == {0, 1}  # both admitted (overdue fills leftover)


def test_calculate_p_monotone_in_slack():
    w = _worker()
    disp, mon, _ = _dispatcher([w])
    shadow = disp.shadows[0]
    p_fresh = disp.calculate_p(_req(0, arrival=0.0, ttft=1.0), shadow, 0.0)
    p_late = disp.calculate_p(_req(1, arrival=-0.9, ttft=1.0), shadow, 0.0)
    assert p_fresh > p_late


def test_maturity_blocks_until_corrected():
    w = _worker()
    disp, mon, out = _dispatcher([w])
    disp.on_request_arrive(_req(0, l_in=1000))
    disp.dispatch_pass(0.0)
    assert len(out) == 1
    nxt = disp.next_wakeup()
    assert nxt is not None and nxt > 0.0
    # before maturity nothing new dispatches
    disp.on_request_arrive(_req(1))
    disp.dispatch_pass(nxt / 2)
    assert len(out) == 1
    # maturity correction pulls it in
    disp.notify_worker_free(0, nxt / 2)
    disp.dispatch_pass(nxt / 2)
    assert len(out) == 2


def test_kv_capacity_respected():
    w = _worker(kv=1500)
    disp, mon, out = _dispatcher([w])
    for i in range(5):
        disp.on_request_arrive(_req(i, l_in=1000, ttft=20.0, tpot=1.0))
    disp.dispatch_pass(0.0)
    admitted = sum(len(rs) for _, rs in out)
    assert admitted == 1  # only one 1000-token prompt fits in 1500
