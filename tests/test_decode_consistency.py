"""Incremental decode must reproduce the full forward pass — validates
KV caches, local-window ring buffers, SSD state carry, and shared-block
caches for every architecture family."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model

FAMS = ["qwen2.5-14b", "gemma3-4b", "mamba2-2.7b", "zamba2-7b",
        "olmoe-1b-7b", "chameleon-34b", "command-r-plus-104b"]


def _nodrop(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    return cfg


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name):
    cfg = _nodrop(get_smoke_config(name))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, s, t = 2, 20, 6
    toks = jax.random.randint(jax.random.key(2), (b, s + t), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    lens = jnp.full((b,), s, jnp.int32)
    lp, caches = model.prefill(params, toks[:, :s], lens, cache_len=s + t)
    errs = [float(jnp.max(jnp.abs(lp - full[:, s - 1])))]
    for i in range(t):
        lg, caches = model.decode_step(
            params, caches, toks[:, s + i], lens + i
        )
        errs.append(float(jnp.max(jnp.abs(lg - full[:, s + i]))))
    assert max(errs) < 1e-4, errs


def test_ragged_prefill_lengths():
    """Per-sequence lens: padding rows must not leak into attention."""
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    toks = jax.random.randint(jax.random.key(3), (2, 16), 0,
                              cfg.vocab_size)
    lens = jnp.array([16, 9], jnp.int32)
    lp, _ = model.prefill(params, toks, lens)
    # row 1's last-token logits must equal an unpadded 9-token prefill
    lp_short, _ = model.prefill(
        params, toks[1:2, :9], jnp.array([9], jnp.int32)
    )
    assert float(jnp.max(jnp.abs(lp[1] - lp_short[0]))) < 1e-4
