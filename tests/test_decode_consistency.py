"""Incremental decode must reproduce the full forward pass — validates
KV caches, local-window ring buffers, SSD state carry, and shared-block
caches for every architecture family."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_smoke_config
from repro.models import build_model

FAMS = ["qwen2.5-14b", "gemma3-4b", "mamba2-2.7b", "zamba2-7b",
        "olmoe-1b-7b", "chameleon-34b", "command-r-plus-104b"]


def _nodrop(cfg):
    if cfg.moe is not None:
        return dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0)
        )
    return cfg


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name):
    cfg = _nodrop(get_smoke_config(name))
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    b, s, t = 2, 20, 6
    toks = jax.random.randint(jax.random.key(2), (b, s + t), 0,
                              cfg.vocab_size)
    full = model.forward(params, {"tokens": toks})
    lens = jnp.full((b,), s, jnp.int32)
    lp, caches = model.prefill(params, toks[:, :s], lens, cache_len=s + t)
    errs = [float(jnp.max(jnp.abs(lp - full[:, s - 1])))]
    for i in range(t):
        lg, caches = model.decode_step(
            params, caches, toks[:, s + i], lens + i
        )
        errs.append(float(jnp.max(jnp.abs(lg - full[:, s + i]))))
    assert max(errs) < 1e-4, errs


CHUNK_FAMS = ["qwen2.5-14b", "mamba2-2.7b", "zamba2-7b", "olmoe-1b-7b"]


@pytest.mark.parametrize("name", CHUNK_FAMS)
@pytest.mark.parametrize("chunk", [3, 8])
def test_chunked_prefill_matches_monolithic_logits(name, chunk):
    """Model-level: prefilling in chunks through the paged plane must
    reproduce monolithic prefill's last-token logits, then decode
    identically, for every chunk-capable family (dense / SSM / grouped
    shared-attn / MoE)."""
    cfg = _nodrop(get_smoke_config(name))
    model = build_model(cfg)
    assert model.supports_chunked
    params = model.init(jax.random.key(1))
    b, s, max_len, ps = 2, 13, 32, 4
    toks = jax.random.randint(jax.random.key(4), (b, s), 0, cfg.vocab_size)
    lens = jnp.full((b,), s, jnp.int32)
    want, _ = model.prefill(params, toks, lens, cache_len=max_len)

    from repro.serving.kv_manager import PagedKVManager
    kv = PagedKVManager(b, max_len, ps)
    for i in range(b):
        assert kv.ensure(i, s)
    caches = model.init_paged_cache(b, max_len, ps, kv.n_pages)
    pt = jnp.asarray(kv.table)
    logits = None
    for start in range(0, s, chunk):
        c = min(chunk, s - start)
        tk = jnp.zeros((b, chunk), jnp.int32)
        tk = tk.at[:, :c].set(toks[:, start: start + c])
        logits, caches = model.chunk_step(
            params, caches, pt, tk,
            jnp.full((b,), start, jnp.int32),
            jnp.full((b,), c, jnp.int32),
        )
    assert float(jnp.max(jnp.abs(logits - want))) < 1e-4


def test_engine_chunked_tokens_identical_to_monolithic():
    """Engine-level: the chunked/paged plane must generate
    token-for-token what the monolithic slot plane generates, for every
    tested chunk size — and reclaim every page."""
    from repro.core.request import Request
    from repro.serving.engine import EngineConfig, InferenceEngine
    import numpy as np

    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 21, 11, 3)]

    def run(paged, chunk):
        reqs = [Request.from_prompt(i, p, max_new=4)
                for i, p in enumerate(prompts)]
        eng = InferenceEngine(model, params, EngineConfig(
            n_slots=2, max_len=48, prefill_batch=2, paged=paged,
            chunk_size=chunk, page_size=4))
        for r in reqs:
            eng.submit(r)
        eng.run_until_done()
        assert all(r.finish_time is not None for r in reqs)
        if paged:
            assert eng.kv.n_free_pages == eng.kv.n_pages
        return [r.generated for r in reqs]

    base = run(paged=False, chunk=32)
    for chunk in (5, 32):
        assert run(paged=True, chunk=chunk) == base, chunk


def test_ragged_prefill_lengths():
    """Per-sequence lens: padding rows must not leak into attention."""
    cfg = get_smoke_config("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.key(1))
    toks = jax.random.randint(jax.random.key(3), (2, 16), 0,
                              cfg.vocab_size)
    lens = jnp.array([16, 9], jnp.int32)
    lp, _ = model.prefill(params, toks, lens)
    # row 1's last-token logits must equal an unpadded 9-token prefill
    lp_short, _ = model.prefill(
        params, toks[1:2, :9], jnp.array([9], jnp.int32)
    )
    assert float(jnp.max(jnp.abs(lp[1] - lp_short[0]))) < 1e-4
