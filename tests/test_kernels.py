"""Pallas kernel sweeps (interpret mode) vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rmsnorm import rmsnorm
from repro.kernels.ssd import ssd


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else (
        dict(rtol=2e-5, atol=2e-5)
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,d,bq,bk", [
    (128, 64, 64, 64),
    (256, 64, 64, 128),
    (256, 128, 128, 64),
    (512, 32, 128, 128),
])
@pytest.mark.parametrize("causal,window", [
    (True, 0), (False, 0), (True, 64),
])
def test_flash_attention_sweep(s, d, bq, bk, causal, window, dtype):
    k0, k1, k2 = jax.random.split(jax.random.key(0), 3)
    shape = (2, 3, s, d)
    q = jax.random.normal(k0, shape, dtype)
    k = jax.random.normal(k1, shape, dtype)
    v = jax.random.normal(k2, shape, dtype)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=bq, block_k=bk)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,d,bk", [(256, 64, 64), (512, 128, 128),
                                    (1024, 64, 256)])
def test_decode_attention_sweep(s, d, bk, dtype):
    k0, k1, k2 = jax.random.split(jax.random.key(1), 3)
    b, h = 3, 4
    q = jax.random.normal(k0, (b, h, d), dtype)
    kc = jax.random.normal(k1, (b, h, s, d), dtype)
    vc = jax.random.normal(k2, (b, h, s, d), dtype)
    kv_len = jnp.array([s, s // 2, 7][:b])
    got = decode_attention(q, kc, vc, kv_len, block_k=bk)
    want = ref.decode_attention_ref(q, kc, vc, kv_len)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


@pytest.mark.parametrize("s,h,p,n,chunk", [
    (128, 2, 16, 32, 32),
    (256, 4, 16, 32, 64),
    (256, 4, 32, 64, 128),
])
def test_ssd_sweep(s, h, p, n, chunk):
    ks = jax.random.split(jax.random.key(2), 5)
    b = 2
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.5)
    bm = jax.random.normal(ks[3], (b, s, n))
    cm = jax.random.normal(ks[4], (b, s, n))
    y1, s1 = ssd(x, dt, a, bm, cm, chunk=chunk)
    y2, s2 = ref.ssd_ref(x, dt, a, bm, cm)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("rows,d,br", [(128, 64, 32), (256, 512, 256),
                                       (64, 128, 64)])
def test_rmsnorm_sweep(rows, d, br, dtype):
    k0, k1 = jax.random.split(jax.random.key(3))
    x = jax.random.normal(k0, (rows, d), dtype)
    sc = jax.random.normal(k1, (d,)) * 0.1
    got = rmsnorm(x, sc, block_rows=br)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **_tol(dtype),
    )


def test_flash_attention_long_context_memory_shape():
    """Blocked kernel output matches shapes on longer sequences."""
    q = jax.random.normal(jax.random.key(4), (1, 2, 1024, 64))
    out = flash_attention(q, q, q, causal=True, block_q=256, block_k=256)
    assert out.shape == q.shape
