"""SLO-customized speculative decoding over paged KV.

A spec_decode engine drafts tokens with a model-free n-gram /
prompt-lookup drafter, verifies the whole proposal in ONE forward pass
over the paged cache (`Model.spec_decode_block`), accepts the longest
matching prefix, and rolls back rejected lanes as page-table
truncation (`PagedKVManager.truncate`).  Greedy acceptance makes the
output stream *token-identical* to plain greedy decode — across page /
chunk sizes, mid-stream P/D export, and live migration of a
speculating request.  Per-lane speculation depth comes from the TPOT
slack of each request's SLO (Eq. 5 family), so tiers with tight TPOT
speculate shallower than loose ones.
"""

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.latency_model import (
    FittedLatencyModel,
    LatencyCoeffs,
    LatencyModel,
)
from repro.core.request import Request, RequestState
from repro.models import build_model
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.kv_manager import PagedKVManager
from repro.serving.prefix_cache import PrefixCache
from repro.serving.spec_decode import (
    NGramDrafter,
    SpecConfig,
    expected_emitted,
    slo_spec_len,
)
from repro.serving.worker import SimWorker

SMOKE = get_smoke_config("qwen7b")
_MODEL = build_model(SMOKE)
_PARAMS = _MODEL.init(jax.random.key(0))
_FN_CACHE: dict = {}   # shared jitted steps across every engine below


def _engine(decode_block=1, page_size=8, chunk_size=16, n_slots=4,
            max_len=48, model=_MODEL, params=_PARAMS,
            fn_cache=_FN_CACHE, **kw):
    return InferenceEngine(
        model, params,
        EngineConfig(n_slots=n_slots, max_len=max_len, prefill_batch=2,
                     page_size=page_size, chunk_size=chunk_size,
                     decode_block=decode_block, **kw),
        fn_cache=fn_cache,
    )


def _spec_engine(page_size=8, chunk_size=16, n_slots=4, max_len=48,
                 max_spec_len=4, **kw):
    return _engine(1, page_size, chunk_size, n_slots, max_len,
                   spec_decode=True, max_spec_len=max_spec_len, **kw)


def _rep_prompts():
    """Prompts with enough self-repetition for the drafter to fire
    (plus one fully random control)."""
    rng = np.random.default_rng(7)
    return [
        np.array([3, 5, 7, 11] * 3, np.int32),
        np.array([2, 4] * 5, np.int32),
        np.array([9] * 8, np.int32),
        rng.integers(0, SMOKE.vocab_size, size=9).astype(np.int32),
    ]


def _run(eng, prompts, max_new=10, **req_kw):
    reqs = [Request.from_prompt(i, p, max_new=max_new, **req_kw)
            for i, p in enumerate(prompts)]
    for r in reqs:
        eng.submit(r)
    eng.run_until_done()
    assert all(r.finish_time is not None for r in reqs)
    return reqs


# ---------------------------------------------------------------------------
# Drafter: deterministic, longest-n-gram + latest-occurrence preference
# ---------------------------------------------------------------------------

def test_drafter_deterministic_latest_occurrence():
    d = NGramDrafter(max_ngram=3, min_ngram=1)
    h = [1, 2, 3, 9, 1, 2, 3, 7, 1, 2, 3]
    # the trailing 3-gram (1,2,3) occurs at 0 and 4; the LATEST match
    # wins, so the continuation comes from position 4+3
    assert d.propose(h, 3) == [7, 1, 2]
    # deterministic: same history -> same proposal, every call
    for _ in range(3):
        assert d.propose(list(h), 3) == [7, 1, 2]
    # k truncates the continuation
    assert d.propose(h, 1) == [7]
    assert d.propose(h, 0) == []


def test_drafter_prefers_longer_ngram():
    d = NGramDrafter(max_ngram=3, min_ngram=1)
    # trailing 2-gram (4,5) matches at 0 -> continuation 9; a 1-gram
    # match on 5 alone (latest at index 3 -> continuation 4) must lose
    assert d.propose([4, 5, 9, 5, 4, 5], 1) == [9]


def test_drafter_no_match_and_degenerate_histories():
    d = NGramDrafter()
    assert d.propose([], 4) == []
    assert d.propose([1], 4) == []
    assert d.propose([5, 6, 7, 8], 4) == []  # no repeated n-gram


# ---------------------------------------------------------------------------
# SLO controller: depth from TPOT slack (Eq. 5 family)
# ---------------------------------------------------------------------------

def test_slo_spec_len_controller():
    cfg = SpecConfig(max_spec_len=8, unfitted_default=2)
    # unfitted profiler: conservative fixed default
    assert slo_spec_len(0.5, FittedLatencyModel(), [10], cfg) == 2
    # fitted: K = slack / b, floored, clamped to [0, max_spec_len]
    # (binary-exact coeffs so int() truncation is deterministic)
    m = LatencyModel(LatencyCoeffs(a=0.0, b=0.5, c=0.0,
                                   a_d=1.0, b_d=0.0, c_d=0.0))
    assert slo_spec_len(2.0, m, [10], cfg) == 2    # slack 1.0 / b 0.5
    assert slo_spec_len(1.5, m, [10], cfg) == 1
    assert slo_spec_len(0.5, m, [10], cfg) == 0    # no slack at all
    assert slo_spec_len(100.0, m, [10], cfg) == 8  # clamped at max
    # monotone: looser TPOT never speculates shallower
    ks = [slo_spec_len(t, m, [10], cfg) for t in (1.0, 1.5, 2.5, 4.0)]
    assert ks == sorted(ks)


def test_expected_emitted_and_spec_step_time():
    assert expected_emitted(0, 0.7) == 1.0
    assert expected_emitted(4, 0.0) == 1.0
    assert expected_emitted(3, 1.0) == pytest.approx(4.0)
    # geometric acceptance: 1 + a + a^2 for k=2
    assert expected_emitted(2, 0.5) == pytest.approx(1.75)
    m = LatencyModel(LatencyCoeffs(0.0, 0.5, 0.0, 1.0, 0.0, 0.0))
    # verify lanes priced at the prefill per-token rate
    assert m.spec_step_time([10], 4) == pytest.approx(3.0)
    assert m.spec_step_time([10], 0) == pytest.approx(
        m.decode_step_time([10]))


# ---------------------------------------------------------------------------
# Satellite: decode-block profiler attributes wall time to accepted
# tokens only (trailing rejected lanes trimmed)
# ---------------------------------------------------------------------------

def test_observe_decode_block_trims_trailing_empty_iterations():
    m = FittedLatencyModel()
    # 4 lanes dispatched, last 2 fully rejected: wall time divides over
    # the 2 iterations that emitted, not 4
    m.observe_decode_block([[10, 12], [11], [], []], 0.4)
    assert len(m._d_samples) == 2
    assert all(t == pytest.approx(0.2) for _, _, t in m._d_samples)
    # fully-rejected dispatch contributes nothing
    m2 = FittedLatencyModel()
    m2.observe_decode_block([[], [], []], 1.0)
    assert not m2._d_samples
    # interior empties still absorb their share (engine overhead) but
    # carry no sample — only TRAILING empties are trimmed
    m3 = FittedLatencyModel()
    m3.observe_decode_block([[5], [], [7], []], 0.3)
    assert len(m3._d_samples) == 2
    assert all(t == pytest.approx(0.1) for _, _, t in m3._d_samples)


# ---------------------------------------------------------------------------
# Rollback-as-truncation: PagedKVManager invariants
# ---------------------------------------------------------------------------

def test_truncate_basic():
    kv = PagedKVManager(n_slots=2, max_len=32, page_size=4)
    assert kv.ensure(0, 14)              # 4 pages
    assert kv.truncate(0, 9) == 1        # 3 pages cover 9 tokens
    assert kv.n_pages_held(0) == 3
    assert kv.truncate(0, 9) == 0        # idempotent
    assert kv.truncate(0, 12) == 0       # same page count: no-op
    assert kv.truncate(0, 0) == 3        # full rollback
    assert kv.pages_of(0) == []
    assert (kv.table[0] == -1).all()
    assert kv.n_free_pages == kv.n_pages


def test_truncate_invalidates_device_table():
    kv = PagedKVManager(n_slots=1, max_len=32, page_size=4)
    kv.ensure(0, 12)
    t0 = kv.device_table()
    kv.truncate(0, 12)                   # no-op: same buffer
    assert kv.device_table() is t0
    kv.truncate(0, 4)                    # shrinks: re-upload
    t1 = kv.device_table()
    assert t1 is not t0
    assert np.array_equal(np.asarray(t1), kv.table)


# ---------------------------------------------------------------------------
# Rollback over a shared cached prefix: refcounts stay exact
# (the hypothesis generalization of this lives in test_properties.py)
# ---------------------------------------------------------------------------

def test_truncate_prefix_refcounts():
    """A slot speculating on top of a shared cached prefix: rollback
    must deref shared pages through the cache (never hand a pinned
    page to the allocator) and keep every refcount exact."""
    steps = [(4, 2), (6, 0), (1, 1), (5, 5), (3, 0), (6, 4)]
    kv = PagedKVManager(n_slots=2, max_len=256, page_size=4)
    pc = PrefixCache(kv.alloc, 4)
    kv.attach_prefix_cache(pc)

    toks = list(range(13))
    assert kv.ensure(0, len(toks))
    assert kv.publish_prefix(0, toks) == 3     # 3 full pages cached

    hit = kv.lookup_prefix(1, toks + [50, 51, 52])
    assert hit == 12
    shared = kv.pages_of(1)
    assert len(shared) == 3
    pos = hit + 1                               # first private token
    assert kv.ensure(1, pos)

    for k, acc in steps:
        acc = min(acc, k)
        if pos + k + 1 > 256:
            break
        # speculate: grow to cover the proposal, then roll back to the
        # accepted prefix — an arbitrary accept/reject outcome
        assert kv.ensure(1, pos + k + 1)
        pos += acc + 1
        kv.truncate(1, pos)
        assert kv.n_pages_held(1) == -(-pos // 4)
        # shared span never truncated (engine floor: resident pos)
        assert kv.pages_of(1)[:3] == shared
        for p in shared:
            assert pc.refs(p) == 2              # publisher + this slot
        # conservation incl. the shared pages counted once
        held = kv.n_pages_held(0) + kv.n_pages_held(1) - len(shared)
        assert kv.alloc.n_used == held
        assert pc.n_reclaimable == 0            # everything pinned

    kv.release(1)
    for p in shared:
        assert pc.refs(p) == 1                  # publisher still holds
    kv.release(0)
    assert pc.n_reclaimable == 3                # unpinned, resident
    assert pc.evict(3) == 3
    assert kv.n_free_pages == kv.n_pages


# ---------------------------------------------------------------------------
# Token identity: --spec-decode vs plain greedy decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("page_size,chunk_size", [(4, 8), (8, 16)])
def test_spec_token_identical_to_plain(page_size, chunk_size):
    base = _run(_engine(1, page_size, chunk_size), _rep_prompts(),
                max_new=12)
    eng = _spec_engine(page_size, chunk_size)
    spec = _run(eng, _rep_prompts(), max_new=12)
    assert [r.generated for r in spec] == [r.generated for r in base]
    # speculation actually fired (repetitive prompts guarantee
    # proposals on the first decode steps) and accounting balances
    assert eng.n_spec_dispatches > 0
    assert eng.n_spec_proposed >= eng.n_spec_accepted >= 0
    assert eng.kv.n_free_pages == eng.kv.n_pages


def test_spec_identical_to_fixed_k_blocks():
    """Same stream whether the engine runs fused K-blocks or
    propose-verify dispatches — both are greedy."""
    blk = _run(_engine(8), _rep_prompts(), max_new=12)
    spec = _run(_spec_engine(), _rep_prompts(), max_new=12)
    assert [r.generated for r in spec] == [r.generated for r in blk]


def test_spec_eos_stops_identically():
    base = _run(_engine(1, n_slots=1), _rep_prompts()[:1], max_new=12)
    tokens = base[0].generated
    eos = tokens[5]
    want = tokens[: tokens.index(eos) + 1]
    eng = _spec_engine(n_slots=1, eos_token=int(eos))
    (r,) = _run(eng, _rep_prompts()[:1], max_new=12)
    assert r.generated == want
    assert eng.kv.n_free_pages == eng.kv.n_pages


def test_spec_depth_follows_tpot_slack():
    """With a FITTED profiler (the Appendix-A estimator, not bare
    coefficients), a tight-TPOT tier speculates shallower than a loose
    one — the per-tier depth split BENCH_spec measures end-to-end."""
    truth = LatencyModel(LatencyCoeffs(0.0, 0.01, 0.0, 0.05, 0.0, 0.0))
    prof = FittedLatencyModel()
    for lens in ([4], [8], [4, 8], [16], [8, 16], [32], [4, 32], [64]):
        prof.observe_prefill(lens, truth.prefill_time(lens))
        prof.observe_decode(lens, truth.decode_step_time(lens))
    assert prof.fit()
    cfg = SpecConfig(max_spec_len=8)
    e_d = prof.decode_step_time([24])
    tight = slo_spec_len(e_d + 2.0 * prof.b, prof, [24], cfg)
    loose = slo_spec_len(e_d + 100.0 * prof.b, prof, [24], cfg)
    assert 1 <= tight <= 2
    assert loose == cfg.max_spec_len
    assert tight < loose
    assert slo_spec_len(e_d * 0.5, prof, [24], cfg) == 0


# ---------------------------------------------------------------------------
# Mid-stream P/D export + live migration of a speculating request
# ---------------------------------------------------------------------------

def test_spec_pd_export_and_migration_identity():
    """Park on a spec prefill engine, migrate, SPECULATE on the
    destination (different page size), export mid-stream, finish on a
    per-token engine — token-identical to the unmigrated plain run."""
    prompt = _rep_prompts()[0]
    base = _run(_engine(1, n_slots=1, max_len=64), [prompt.copy()],
                max_new=16)
    want = base[0].generated

    a = _spec_engine(n_slots=1, max_len=64)
    a.park_on_prefill = True
    r = Request.from_prompt(0, prompt.copy(), max_new=16)
    a.submit(r)
    a.run_until_done()
    assert r.slot in a.parked
    pay = a.export_kv(r.rid)
    a.evict(r.slot)

    b = _spec_engine(n_slots=1, max_len=64, page_size=4)
    assert b.import_kv(pay, r)
    while len(r.generated) < 15:
        b.step()
    # the output stream develops repeats, so the drafter fired and at
    # least one proposal survived verification before the export
    assert b.n_spec_dispatches > 0
    assert b.n_spec_accepted > 0
    assert r.generated == want[: len(r.generated)]
    # host pos stays exact through accept/rollback: the payload covers
    # exactly the accepted tokens
    pay2 = b.export_kv(r.rid)
    assert pay2.n_tokens == int(b.pos[r.slot])
    b.evict(r.slot)
    assert b.kv.n_free_pages == b.kv.n_pages

    c = _engine(1, n_slots=1, max_len=64)
    assert c.import_kv(pay2, r)
    c.run_until_done()
    assert r.generated == want
    assert r.state == RequestState.FINISHED


# ---------------------------------------------------------------------------
# Refusals + warm buckets
# ---------------------------------------------------------------------------

def test_spec_decode_refuses_slot_plane():
    with pytest.raises(ValueError, match="paged"):
        _spec_engine(paged=False)


def test_spec_decode_refuses_ssm_architectures():
    cfg = get_smoke_config("mamba2-2.7b")
    model = build_model(cfg)
    assert not model.supports_spec_decode
    assert _MODEL.supports_spec_decode
    params = model.init(jax.random.key(0))
    with pytest.raises(ValueError, match="spec_decode"):
        _engine(1, model=model, params=params, fn_cache={},
                spec_decode=True)


def test_warm_decode_blocks_covers_spec_buckets():
    eng = _spec_engine(max_spec_len=4)
    eng.warm_decode_blocks()
    # pow2 verify-width buckets up to max_spec_len precompiled
    assert {1, 2, 4} <= set(eng._spec_fns)


# ---------------------------------------------------------------------------
# Sim plane mirrors acceptance-rate-scaled decode ticks
# ---------------------------------------------------------------------------

def _sim_worker(accept_rate):
    truth = LatencyModel(LatencyCoeffs(0.0, 0.5, 0.0, 1.0, 0.0, 0.0))
    return SimWorker(0, "collocated", truth, 10**9,
                     np.random.default_rng(0), noise=0.0,
                     spec_decode=True, max_spec_len=4,
                     spec_accept_rate=accept_rate)


def _sim_drain(w, r):
    now, steps = 0.0, []
    w.submit([r], now)
    while r.state != RequestState.FINISHED:
        out = w.run_step(now)
        assert out is not None
        now += out.duration
        w.finish_step(out, now)
        steps.append((out.kind, out.duration))
    return steps


def test_sim_worker_spec_mirror():
    # tpot_slo 2.0 against e_d=1.0, b=0.5 -> the controller plans k=2;
    # full acceptance emits 3 tokens per dispatch
    r = Request(rid=0, l_in=4, l_out=10, tpot_slo=2.0)
    w = _sim_worker(1.0)
    steps = _sim_drain(w, r)
    decs = [d for kind, d in steps if kind == "decode"]
    assert len(decs) == 3                       # 9 decode tokens / 3
    assert all(d == pytest.approx(2.0) for d in decs)  # 1.0 + 0.5*2
    assert w.spec_dispatches == 3
    assert w.spec_proposed == 6
    assert w.spec_accepted == 6

    # zero acceptance degenerates to one token per step — never fewer
    r0 = Request(rid=1, l_in=4, l_out=10, tpot_slo=2.0)
    w0 = _sim_worker(0.0)
    steps0 = _sim_drain(w0, r0)
    assert len([1 for kind, _ in steps0 if kind == "decode"]) == 9
    assert w0.spec_accepted == 0
    assert r0.tokens_done == 10
