"""Closed-loop online serving client over the ServingSession front door.

Each of N clients keeps exactly one request in flight: it submits,
consumes the typed event stream (ADMITTED -> FIRST_TOKEN -> TOKEN... ->
FINISHED) as the tokens are generated, and only then submits its next
round — arrival stamped at the previous response's finish time, i.e. a
genuine closed loop over the cluster's clock.  Contrast with the
open-loop Poisson replays the benchmarks use: here the offered load
*reacts* to serving latency, which is what a live traffic source does.

    PYTHONPATH=src python examples/online_serving.py            # sim
    PYTHONPATH=src python examples/online_serving.py --smoke    # engine

``--smoke`` runs the reduced CPU engine (the CI configuration).
"""

from __future__ import annotations

import argparse

import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.request import TASKS
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.session import EventKind, ServingSession


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "engine"])
    ap.add_argument("--model", default="qwen7b")
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--admission", default="reject",
                    choices=["none", "reject", "degrade"])
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced CPU engine config (CI smoke run)")
    args = ap.parse_args()

    if args.smoke:
        args.backend = "engine"
        args.clients = min(args.clients, 2)
        args.rounds = min(args.rounds, 2)

    engine_cfg = None
    if args.backend == "engine":
        from repro.serving.engine import EngineConfig

        engine_cfg = EngineConfig.smoke()
        model = get_smoke_config(args.model)
    else:
        model = get_config(args.model)
    cfg = ClusterConfig(model=model, backend=args.backend,
                        engine=engine_cfg, n_workers=1, seed=args.seed)
    session = ServingSession(Cluster(cfg), admission=args.admission)

    rng = np.random.default_rng(args.seed)
    specs = [TASKS["gsm8k"], TASKS["sharegpt"]]

    def submit(cid: int):
        spec = specs[cid % len(specs)]
        if args.backend == "engine":
            l_in = int(rng.integers(4, 16))
            l_out = int(rng.integers(2, 6))
        else:
            l_in, l_out = spec.sample_lengths(rng)
        return session.submit(
            l_in=l_in, l_out=l_out, task=spec.name,
            ttft_slo=spec.ttft_slo, tpot_slo=spec.tpot_slo,
        )  # arrival=None -> stamped "now": the closed loop

    active = {cid: submit(cid) for cid in range(args.clients)}
    rounds_left = {cid: args.rounds - 1 for cid in active}
    n_rejected = 0
    while active:
        for cid in list(active):
            h = active[cid]
            n_tok = 0
            for ev in h.events():     # drives the event loop
                if ev.kind in (EventKind.FIRST_TOKEN, EventKind.TOKEN):
                    n_tok += 1
            r = h.request
            if h.rejected:
                n_rejected += 1
                print(f"client {cid}: REJECTED "
                      f"({h.log[-1].data.get('reason', '?')})")
            elif not h.done:
                # stream ended without a terminal event: the drain
                # deadline expired with the request still unplaced
                print(f"client {cid}: STALLED ({r.task}, never served)")
                del active[cid]
                continue
            else:
                print(f"client {cid}: {r.task:9s} {n_tok:3d} tokens  "
                      f"ttft={r.ttft:.4f}s  e2e={r.e2e:.4f}s  "
                      f"attained={r.attained()}")
            if rounds_left[cid] > 0:
                rounds_left[cid] -= 1
                active[cid] = submit(cid)
            else:
                del active[cid]

    session.drain()
    res = session.close()
    m = res.metrics
    print(f"\n{args.clients} clients x {args.rounds} rounds "
          f"(backend={args.backend}, admission={args.admission}):")
    print(f"  attainment {m.attainment:.3f}  finished {m.n_finished}/"
          f"{m.n_total}  rejected {m.n_rejected}")
    s = session.streaming.row()
    print(f"  TTFB mean={s['mean_ttfb']}s p99={s['p99_ttfb']}s   "
          f"ITL mean={s['mean_itl']}s p99={s['p99_itl']}s")
    assert m.n_finished + m.n_rejected == m.n_total


if __name__ == "__main__":
    main()
