"""End-to-end driver: serve a small model with batched requests.

Runs the real JAX engine (continuous batching, paged KV cache with
chunked prefill — or slot-based fallback for ring-cache archs, greedy
sampling) over a Poisson request stream with heterogeneous SLOs, using
the Eq. 5 token-budget admission fit live from the engine's own
profiler — the full HyperFlexis loop on actual model computation.
Final metrics come from the same `compute_metrics` the simulator uses
(unified Request lifecycle).

    PYTHONPATH=src python examples/serve_engine_e2e.py --arch gemma3-4b
"""

import argparse

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.core.request import TASKS, Request
from repro.models import build_model
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.serving.metrics import compute_metrics


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen7b")
    ap.add_argument("--n-requests", type=int, default=24)
    ap.add_argument("--slots", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(
        model, params,
        EngineConfig(n_slots=args.slots, max_len=96, prefill_batch=2,
                     slo_aware=True),
    )
    rng = np.random.default_rng(0)
    tasks = list(TASKS.values())[:4]
    reqs = []
    for i in range(args.n_requests):
        spec = tasks[i % len(tasks)]
        l_in = max(2, min(32, int(rng.normal(12, 4))))
        reqs.append(Request.from_prompt(
            i,
            rng.integers(0, cfg.vocab_size, size=l_in).astype(np.int32),
            max_new=int(rng.integers(4, 12)),
            task=spec.name, ttft_slo=spec.ttft_slo,
            tpot_slo=spec.tpot_slo,
        ))
    for r in reqs:
        engine.submit(r)
    steps = 0
    while engine.queue or engine.prefilling or engine.active:
        info = engine.step()
        steps += 1
        if steps % 20 == 0:
            print(f"  step {steps}: {info['kind']} "
                  f"active={len(engine.active)} "
                  f"queued={len(engine.queue)} "
                  f"clock={engine.clock:.2f}s")
        if steps % 25 == 0:
            engine.fit_profiler()  # refresh Eq.1/2 online
    done = [r for r in reqs if r.finish_time is not None]
    print(f"served {len(done)}/{len(reqs)} in {steps} steps, "
          f"clock={engine.clock:.2f}s")
    ttfts = [r.ttft for r in done]
    print(f"TTFT: mean={np.mean(ttfts):.3f}s p99="
          f"{np.percentile(ttfts, 99):.3f}s")
    tok = sum(len(r.generated) for r in done)
    print(f"throughput: {tok/engine.clock:.1f} tok/s (virtual clock)")
    # shared metrics path: identical RunMetrics schema to the simulator
    m = compute_metrics(reqs, cost_units=engine.clock, makespan=engine.clock)
    for task, v in m.per_task.items():
        print(f"  {task:20s} att={v['attainment']:.2f} "
              f"(ttft {v['ttft_attainment']:.2f} / "
              f"tpot {v['tpot_attainment']:.2f}) n={v['n']}")


if __name__ == "__main__":
    main()
