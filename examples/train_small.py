"""Train a ~100M-param dense LM for a few hundred steps on CPU, with
checkpointing + auto-resume (kill it mid-run and start again).

    PYTHONPATH=src python examples/train_small.py --steps 200
"""

import argparse
import dataclasses
import time

import jax

from repro.configs.base import ModelConfig
from repro.distributed.fault_tolerance import RunnerConfig, TrainRunner
from repro.models.build import build_model
from repro.training.data import DataConfig
from repro.training.optimizer import AdamWConfig
from repro.training.train_loop import TrainConfig

# ~100M params: 8L d512 8H ff2048 vocab 32k
SMALL = ModelConfig(
    name="small-100m", family="dense", n_layers=8, d_model=512,
    n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=32_000,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_small_train")
    args = ap.parse_args()

    model = build_model(SMALL, remat=True)
    print(f"params: {SMALL.param_count()/1e6:.1f}M")
    runner = TrainRunner(
        model,
        DataConfig(batch=args.batch, seq_len=args.seq),
        TrainConfig(adamw=AdamWConfig(lr=3e-4, warmup_steps=50),
                    micro_batches=2),
        RunnerConfig(total_steps=args.steps, ckpt_every=50,
                     ckpt_dir=args.ckpt_dir, log_every=20),
    )
    t0 = time.time()
    out = runner.run(jax.random.key(0))
    for h in out["history"]:
        print(f"  step {h['step']:4d} loss={h['loss']:.4f} "
              f"|g|={h['grad_norm']:.3f}")
    n = args.steps - out["resumed_from"]
    print(f"{n} steps in {time.time()-t0:.1f}s "
          f"(resumed from {out['resumed_from']}); "
          f"final loss {out['final_loss']:.4f}")


if __name__ == "__main__":
    main()
