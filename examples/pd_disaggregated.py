"""P/D-disaggregated serving with two-stage scheduling + fast scaling.

Shows the paper's core systems story end to end:
- Dispatcher schedules prefill instances (Algorithm 1);
- the Migrator picks decode instances *after* prefill completes and the
  TLManager moves the KV cache over D2D links;
- the Scaler grows/shrinks pools, flips worker roles, and provisions new
  instances via Fast Scaling (D2D weight pull) vs disk loading.

    PYTHONPATH=src python examples/pd_disaggregated.py
"""

from repro.configs import get_config
from repro.core.request import FOUR_TASK_SET
from repro.core.scaler import ScalerConfig
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import poisson_workload


def run(label, **kw):
    reqs = poisson_workload(FOUR_TASK_SET, qps=96, n_per_task=100,
                            seed=3)
    cfg = ClusterConfig(model=get_config("qwen7b"), mode="pd",
                        n_prefill=2, n_decode=2, seed=3, **kw)
    res = Cluster(cfg).run(reqs)
    m = res.metrics
    print(f"{label:28s} att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s "
          f"cost={m.cost_units:.0f} kv_transfers={res.kv_transfers} "
          f"role_flips={res.n_role_flips} scale_out={res.n_scale_out}")
    for t, wid, ev in res.timeline[:6]:
        print(f"    t={t:7.2f}s worker{wid}: {ev}")
    return m


def main():
    print("== one-shot RR-PD (the anti-pattern §5.1 fixes)")
    run("rr-pd one-shot", policy="rr", one_shot_pd=True)
    print("== HyperFlexis-PD (two-stage Dispatcher + Migrator)")
    run("hfx-pd", policy="hyperflexis")
    print("== HyperFlexis-PD + scaling (fast D2D weight transfer)")
    run("hfx-pd-scaling d2d", policy="hyperflexis", scaling=True,
        scaler=ScalerConfig(max_workers=8, weight_strategy="d2d"))
    print("== same but disk cold-start (slow scaling)")
    run("hfx-pd-scaling disk", policy="hyperflexis", scaling=True,
        scaler=ScalerConfig(max_workers=8, weight_strategy="disk"))


if __name__ == "__main__":
    main()
