"""P/D-disaggregated serving with two-stage scheduling + fast scaling.

Shows the paper's core systems story end to end:
- Dispatcher schedules prefill instances (Algorithm 1);
- the Migrator picks decode instances *after* prefill completes and the
  TLManager moves the KV cache over D2D links;
- the Scaler grows/shrinks pools, flips worker roles, and provisions new
  instances via Fast Scaling (D2D weight pull) vs disk loading.

Two execution planes behind the same control plane:

    # discrete-event simulator (paper-scale workloads)
    PYTHONPATH=src python examples/pd_disaggregated.py

    # real JAX engines: prefill on engine A, paged KV exported,
    # installed on engine B, decode continues token-identically
    PYTHONPATH=src python examples/pd_disaggregated.py \
        --backend engine --smoke
"""

import argparse

from repro.configs import get_config, get_smoke_config
from repro.core.request import FOUR_TASK_SET
from repro.core.scaler import ScalerConfig
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.workload import poisson_workload


def run(label, smoke=False, **kw):
    if smoke:
        reqs = poisson_workload(FOUR_TASK_SET, qps=96, n_per_task=3,
                                seed=3)
    else:
        reqs = poisson_workload(FOUR_TASK_SET, qps=96, n_per_task=100,
                                seed=3)
    cfg = ClusterConfig(model=get_config("qwen7b"), mode="pd",
                        n_prefill=2, n_decode=2, seed=3, **kw)
    res = Cluster(cfg).run(reqs)
    m = res.metrics
    print(f"{label:28s} att={m.attainment:.3f} e2e={m.mean_e2e:.2f}s "
          f"cost={m.cost_units:.0f} kv_transfers={res.kv_transfers} "
          f"role_flips={res.n_role_flips} scale_out={res.n_scale_out}")
    for t, wid, ev in res.timeline[:6]:
        print(f"    t={t:7.2f}s worker{wid}: {ev}")
    return m


def run_engine(smoke=True):
    """Engine plane: the Migrator moves REAL paged KV between
    InferenceEngine replicas (export_kv -> TLManager-costed transfer
    -> import_kv), measured payload bytes and all."""
    from repro.serving.engine import EngineConfig
    from repro.serving.workload import engine_smoke_workload

    reqs = engine_smoke_workload(n=8 if smoke else 24, seed=3)
    cfg = ClusterConfig(
        model=get_smoke_config("qwen7b"), backend="engine",
        policy="hyperflexis", mode="pd", n_prefill=1, n_decode=1,
        seed=3, engine=EngineConfig.smoke(),
    )
    cluster = Cluster(cfg)
    res = cluster.run(reqs)
    m = res.metrics
    print(f"{'engine-pd':28s} finished={m.n_finished}/{m.n_total} "
          f"kv_transfers={res.kv_transfers} "
          f"kv_bytes={cluster.tl.kv_bytes_moved:.0f}")
    moved = [r for r in reqs if r.decode_worker is not None
             and r.decode_worker != r.prefill_worker]
    print(f"    {len(moved)} requests prefilled on worker 0, decoded on "
          f"worker 1 after a real paged-KV hand-off")
    assert m.n_finished == m.n_total
    return m


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sim", choices=["sim", "engine"])
    ap.add_argument("--smoke", action="store_true",
                    help="small workload (CI / CPU-sized)")
    args = ap.parse_args()

    if args.backend == "engine":
        print("== engine-plane P/D (real paged-KV migration)")
        run_engine(smoke=args.smoke)
        return
    print("== one-shot RR-PD (the anti-pattern §5.1 fixes)")
    run("rr-pd one-shot", smoke=args.smoke, policy="rr", one_shot_pd=True)
    print("== HyperFlexis-PD (two-stage Dispatcher + Migrator)")
    run("hfx-pd", smoke=args.smoke, policy="hyperflexis")
    print("== HyperFlexis-PD + scaling (fast D2D weight transfer)")
    run("hfx-pd-scaling d2d", smoke=args.smoke, policy="hyperflexis",
        scaling=True,
        scaler=ScalerConfig(max_workers=8, weight_strategy="d2d"))
    print("== same but disk cold-start (slow scaling)")
    run("hfx-pd-scaling disk", smoke=args.smoke, policy="hyperflexis",
        scaling=True,
        scaler=ScalerConfig(max_workers=8, weight_strategy="disk"))


if __name__ == "__main__":
    main()
