"""Quickstart: the layers of the framework in ~80 lines.

1. Build a (reduced) model from the architecture registry and serve a
   few batched requests through the REAL JAX inference engine
   (continuous batching + paged KV cache + Eq.5 admission).
2. Fit the Eq.1/Eq.2 latency predictor from the engine's measured step
   times (the paper's profiler).
3. Run the multi-SLO cluster simulation with the HyperFlexis scheduler.
4. Run the SAME control plane (Dispatcher, Algorithm 1) engine-backed:
   `Cluster(backend="engine")` drives real jitted compute.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.request import FOUR_TASK_SET, Request
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.engine import EngineConfig, InferenceEngine
from repro.models import build_model
from repro.serving.workload import poisson_workload


def main():
    # --- 1. real engine on a reduced qwen7b ------------------------------
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(
        model, params, EngineConfig(n_slots=4, max_len=64,
                                    prefill_batch=2)
    )
    rng = np.random.default_rng(0)
    reqs = [
        Request.from_prompt(
            i,
            rng.integers(0, cfg.vocab_size,
                         size=int(rng.integers(4, 16))).astype(np.int32),
            max_new=8, ttft_slo=1.0, tpot_slo=0.5)
        for i in range(8)
    ]
    for r in reqs:
        engine.submit(r)
    engine.run_until_done()
    print(f"engine: served {len(reqs)} requests, "
          f"virtual clock {engine.clock:.2f}s")
    print(f"  first generation: {reqs[0].generated}")

    # --- 2. latency predictor from measured steps -------------------------
    engine.fit_profiler()
    c = engine.profiler.coeffs
    print(f"fitted Eq.1/2: E_p = {c.a:.4f} + {c.b:.2e}*sum(l) "
          f"+ {c.c:.2e}*sum(l^2);  E_d = {c.a_d:.4f} + "
          f"{c.b_d:.2e}*sum(l_cur) + {c.c_d:.2e}*B")

    # --- 3. multi-SLO cluster with Algorithm 1 ----------------------------
    workload = poisson_workload(FOUR_TASK_SET, qps=64, n_per_task=50,
                                seed=0)
    res = Cluster(ClusterConfig(model=get_config("qwen7b"),
                                n_workers=2,
                                policy="hyperflexis")).run(workload)
    m = res.metrics
    print(f"cluster[sim]: attainment={m.attainment:.3f} "
          f"mean_e2e={m.mean_e2e:.2f}s cost={m.cost_units:.0f} units")

    # --- 4. the same control plane over the REAL engine -------------------
    ereqs = []
    t = 0.0
    for i in range(10):
        t += float(rng.exponential(0.05))
        ereqs.append(Request(
            rid=i, task="chat" if i % 2 == 0 else "doc", arrival=t,
            l_in=int(rng.integers(4, 14)), l_out=int(rng.integers(2, 6)),
            ttft_slo=0.8 if i % 2 == 0 else 4.0,
            tpot_slo=0.3 if i % 2 == 0 else 0.8,
        ))
    res = Cluster(ClusterConfig(
        model=cfg, backend="engine", n_workers=1, policy="hyperflexis",
        engine=EngineConfig(n_slots=4, max_len=48, prefill_batch=2),
    )).run(ereqs)
    m = res.metrics
    print(f"cluster[engine]: served {m.n_finished}/{m.n_total} "
          f"attainment={m.attainment:.3f} makespan={m.makespan:.2f}s")
    for task, v in m.per_task.items():
        print(f"    {task:6s} ttft_att={v['ttft_attainment']:.2f} "
              f"tpot_att={v['tpot_attainment']:.2f}")


if __name__ == "__main__":
    main()
