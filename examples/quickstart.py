"""Quickstart: the three layers of the framework in ~60 lines.

1. Build a (reduced) model from the architecture registry and serve a
   few batched requests through the REAL JAX inference engine
   (continuous batching + slot KV cache + Eq.5 admission).
2. Fit the Eq.1/Eq.2 latency predictor from the engine's measured step
   times (the paper's profiler).
3. Run the multi-SLO cluster simulation with the HyperFlexis scheduler.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.request import FOUR_TASK_SET
from repro.models import build_model
from repro.serving.cluster import Cluster, ClusterConfig
from repro.serving.engine import EngineConfig, EngineRequest, InferenceEngine
from repro.serving.workload import poisson_workload


def main():
    # --- 1. real engine on a reduced qwen7b ------------------------------
    cfg = get_smoke_config("qwen7b")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    engine = InferenceEngine(
        model, params, EngineConfig(n_slots=4, max_len=64,
                                    prefill_batch=2)
    )
    rng = np.random.default_rng(0)
    reqs = [
        EngineRequest(rid=i,
                      prompt=rng.integers(0, cfg.vocab_size,
                                          size=int(rng.integers(4, 16))
                                          ).astype(np.int32),
                      max_new=8, ttft_slo=1.0, tpot_slo=0.5)
        for i in range(8)
    ]
    for r in reqs:
        engine.submit(r)
    engine.run_until_done()
    print(f"engine: served {len(reqs)} requests, "
          f"virtual clock {engine.clock:.2f}s")
    print(f"  first generation: {reqs[0].generated}")

    # --- 2. latency predictor from measured steps -------------------------
    engine.fit_profiler()
    c = engine.profiler.coeffs
    print(f"fitted Eq.1/2: E_p = {c.a:.4f} + {c.b:.2e}*sum(l) "
          f"+ {c.c:.2e}*sum(l^2);  E_d = {c.a_d:.4f} + "
          f"{c.b_d:.2e}*sum(l_cur) + {c.c_d:.2e}*B")

    # --- 3. multi-SLO cluster with Algorithm 1 ----------------------------
    workload = poisson_workload(FOUR_TASK_SET, qps=64, n_per_task=50,
                                seed=0)
    res = Cluster(ClusterConfig(model=get_config("qwen7b"),
                                n_workers=2,
                                policy="hyperflexis")).run(workload)
    m = res.metrics
    print(f"cluster: attainment={m.attainment:.3f} "
          f"mean_e2e={m.mean_e2e:.2f}s cost={m.cost_units:.0f} units")


if __name__ == "__main__":
    main()
